"""repro — Field of Groves (FoG) reproduction + TPU-pod framework.

Layers: core/ (the paper's algorithms), forest/ (tensorized RF + CART),
baselines/, kernels/ (Pallas TPU), models/ (assigned LM architectures),
configs/, data/, optim/, train/, serve/, launch/ (mesh, dry-run, drivers).
"""

__version__ = "1.0.0"
