"""Design-time topology search (Figure 4) and EDP budgeting.

The paper sweeps (n_groves x trees_per_grove) topologies of a fixed forest,
evaluates accuracy and EDP on validation data, and picks the min-EDP design
at maximum accuracy; the threshold then becomes the run-time knob (Fig 5).

Every sweep point is a :class:`~repro.core.policy.FogPolicy` — the same
runtime-knob object the engine, the serving path and the sklearn facade
consume — so a sweep's winning point can be handed directly to
``FogEngine.eval(..., policy=point)`` or ``FogClassifier(policy=point)``
without translating loose floats.

Energy comes straight from the engine's :class:`~repro.core.engine.
EvalReport` telemetry (no separate ``HopMeter`` + ``fog_energy`` rederiving)
and is reported in **nJ/classification** (``EnergyReport.per_example_nj``)
everywhere — sweep rows, ``TopologyPoint.__str__`` and frontier logs share
one unit.  The point-selection rules (min-EDP within an accuracy slack,
accuracy-optimal threshold) are the generic implementations in
:mod:`repro.core.frontier`; richer budget questions — the full Pareto
frontier over every runtime knob, ``auto_policy`` under an nJ budget —
live there too.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.core import frontier as _frontier
from repro.core.engine import FogEngine
from repro.core.grove import split
from repro.core.policy import FogPolicy
from repro.forest.tree import TensorForest


@dataclasses.dataclass(frozen=True)
class TopologyPoint:
    n_groves: int
    grove_size: int
    threshold: float
    accuracy: float
    energy_nj: float     # mean energy per classification
    delay: float         # mean hops (ring latency proxy, cycles ~ hops * grove latency)
    edp: float           # energy * delay
    policy: FogPolicy = dataclasses.field(default=FogPolicy(), compare=False)

    def __str__(self) -> str:
        return (f"{self.n_groves}x{self.grove_size} thr={self.threshold:.2f} "
                f"acc={self.accuracy:.3f} E={self.energy_nj:.3f}nJ "
                f"D={self.delay:.2f} EDP={self.edp:.3f}")


def _as_policy(policy) -> FogPolicy:
    """Accept a FogPolicy or a bare threshold float (legacy call sites)."""
    if isinstance(policy, FogPolicy):
        return policy
    return FogPolicy(threshold=float(policy))


def evaluate_topology(forest: TensorForest, grove_size: int,
                      x_val: np.ndarray, y_val: np.ndarray,
                      policy: FogPolicy | float, max_hops: int | None = None,
                      seed: int = 0, backend: str = "reference",
                      ) -> TopologyPoint:
    """Accuracy / energy / EDP of one (topology, policy) design point.

    ``policy`` is the runtime-knob contract; a bare float is accepted as a
    scalar threshold for backward compatibility (``max_hops`` then caps the
    loop as before).  ``backend`` picks the engine backend the sweep runs
    on ("fused" makes wide sweeps one kernel launch per point); a policy's
    own ``backend`` knob still wins when set.
    """
    pol = _as_policy(policy)
    if max_hops is not None and pol.max_hops is None:
        pol = pol.replace(max_hops=max_hops)
    gc = split(forest, grove_size)
    engine = FogEngine(gc, backend=backend)
    res = engine.eval(jax.numpy.asarray(x_val), jax.random.key(seed),
                      policy=pol)
    acc = float(np.mean(np.asarray(res.label) == y_val))
    hops = np.asarray(res.hops)
    # the EvalReport's own EnergyModel priced this evaluation (at the
    # precision it actually ran — int8 packs read fewer SRAM bytes per
    # node), so a sweep over FogPolicy(precision=...) grids maps the full
    # dtype x threshold plane without re-deriving anything here
    e_nj = res.energy_report().per_example_nj
    delay = float(hops.mean())
    thresh_scalar = float(np.asarray(pol.threshold, np.float64).mean())
    return TopologyPoint(gc.n_groves, grove_size, thresh_scalar, acc,
                         e_nj, delay, e_nj * delay, policy=pol)


def policy_sweep(forest: TensorForest, grove_size: int,
                 x_val: np.ndarray, y_val: np.ndarray,
                 policies: Iterable[FogPolicy],
                 seed: int = 0, backend: str = "reference",
                 ) -> list[TopologyPoint]:
    """Evaluate a grid of FogPolicy design points on a fixed topology."""
    return [evaluate_topology(forest, grove_size, x_val, y_val, p, seed=seed,
                              backend=backend)
            for p in policies]


def topology_sweep(forest: TensorForest, x_val: np.ndarray, y_val: np.ndarray,
                   policy: FogPolicy | float = 0.3,
                   backend: str = "reference") -> list[TopologyPoint]:
    """Figure 4: every (groves x grove_size) factorization of the forest."""
    pol = _as_policy(policy)
    t = forest.n_trees
    points = []
    for k in range(1, t + 1):
        if t % k == 0:
            points.append(evaluate_topology(forest, k, x_val, y_val, pol,
                                            backend=backend))
    return points


def select_min_edp(points: list[TopologyPoint],
                   accuracy_slack: float = 0.02) -> TopologyPoint:
    """Min-EDP point whose accuracy is within ``slack`` of the best
    (delegates to the generic rule in :mod:`repro.core.frontier`)."""
    return _frontier.select_min_edp(points, accuracy_slack)


def threshold_sweep(forest: TensorForest, grove_size: int,
                    x_val: np.ndarray, y_val: np.ndarray,
                    thresholds: Sequence[float] | np.ndarray | None = None,
                    ) -> list[TopologyPoint]:
    """Figure 5: run-time tunability curve for a fixed topology (a
    FogPolicy grid varying only the threshold knob)."""
    if thresholds is None:
        thresholds = np.asarray([0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.0])
    return policy_sweep(forest, grove_size, x_val, y_val,
                        [FogPolicy(threshold=float(t)) for t in thresholds])


def find_opt_threshold(points: list[TopologyPoint],
                       tolerance: float = 0.005) -> TopologyPoint:
    """FoG_opt: the accuracy-optimal threshold — smallest threshold above
    which accuracy stops increasing (paper §4.2; delegates to the generic
    rule in :mod:`repro.core.frontier`)."""
    return _frontier.find_opt_threshold(points, tolerance)
