from repro.core.confidence import maxdiff, maxdiff_multioutput, top2
from repro.core.grove import GroveCollection, gc_train, split, grove_predict_proba
from repro.core.policy import (BACKENDS, NO_BUDGET, PRECISIONS, FogPolicy,
                               assemble)
from repro.core.engine import (EvalReport, FogEngine, FogResult, HopMeter,
                               TableCache, confidence_margin, hop_update,
                               sample_starts)
from repro.forest.pack import ForestPack
from repro.core.fog_eval import fog_eval, fog_eval_lazy, fog_eval_multioutput
from repro.core.energy import (
    AffineEnergy, EnergyModel, EnergyReport, fog_energy, rf_report, dt_energy_pj,
    rf_energy_pj, grove_energy_pj, svm_lr_energy_pj, svm_rbf_energy_pj,
    mlp_energy_pj, cnn_energy_pj,
)
from repro.core.budget import (
    TopologyPoint, evaluate_topology, policy_sweep, topology_sweep,
    select_min_edp, threshold_sweep, find_opt_threshold,
)
from repro.core.frontier import (
    Frontier, FrontierPoint, auto_policy, build_frontier, default_grid,
    sweep_policies,
)

__all__ = [
    "maxdiff", "maxdiff_multioutput", "top2",
    "GroveCollection", "gc_train", "split", "grove_predict_proba",
    "BACKENDS", "NO_BUDGET", "PRECISIONS", "FogPolicy", "assemble",
    "EvalReport", "FogEngine", "FogResult", "HopMeter", "TableCache",
    "ForestPack", "confidence_margin", "hop_update", "sample_starts",
    "fog_eval", "fog_eval_lazy", "fog_eval_multioutput",
    "AffineEnergy", "EnergyModel", "EnergyReport", "fog_energy", "rf_report",
    "dt_energy_pj",
    "rf_energy_pj", "grove_energy_pj", "svm_lr_energy_pj",
    "svm_rbf_energy_pj", "mlp_energy_pj", "cnn_energy_pj",
    "TopologyPoint", "evaluate_topology", "policy_sweep", "topology_sweep",
    "select_min_edp", "threshold_sweep", "find_opt_threshold",
    "Frontier", "FrontierPoint", "auto_policy", "build_frontier",
    "default_grid", "sweep_policies",
]
