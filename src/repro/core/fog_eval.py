"""Algorithm 2 — evaluating the Field of Groves classifier (batched).

The ASIC processes examples as queue entries hopping grove-to-grove with a
req/ack handshake.  On a SIMD machine the identical math is a batched
fixed-point: at step j every *live* example evaluates grove
(start + j) mod n_groves (gathered node tables), accumulates the probability
array, and dies once MaxDiff(prob / (j+1)) >= thresh.  Hop counts — and
therefore the energy accounting — are bit-identical to the sequential queue
semantics; only the execution order differs (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.confidence import maxdiff
from repro.core.grove import GroveCollection, grove_predict_proba


@partial(jax.tree_util.register_dataclass,
         data_fields=("proba", "label", "hops"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class FogResult:
    proba: jax.Array   # [B, C] final normalized probability array
    label: jax.Array   # [B]    argmax label
    hops: jax.Array    # [B]    number of groves that processed each example
    # hops is 1-based: hops == j+1 groves contributed (paper's `hops` counts
    # the forwards, i.e. groves-1; we report groves-used, the energy quantity)


@partial(jax.jit, static_argnames=("max_hops",))
def fog_eval(gc: GroveCollection, x: jax.Array, key: jax.Array,
             thresh: float | jax.Array, max_hops: int) -> FogResult:
    """GCEval(X, thresh, max_hops) — Algorithm 2.

    x: [B, F].  ``key`` seeds the random start grove (line 3, "start at
    random grove to avoid bias").  ``max_hops`` is static (it bounds the
    unrolled/scan trip count); ``thresh`` may be a traced scalar so the
    run-time tunability of §3.2.2 is a cheap re-dispatch, not a recompile.
    """
    B = x.shape[0]
    G = gc.n_groves
    start = jax.random.randint(key, (B,), 0, G)                  # line 3

    def body(carry, j):
        prob, live, hops = carry
        g_idx = (start + j) % G                                   # line 6
        contrib = grove_predict_proba(gc, g_idx, x)               # line 7
        prob = prob + jnp.where(live[:, None], contrib, 0.0)
        hops = hops + live.astype(jnp.int32)
        prob_norm = prob / jnp.maximum(hops, 1)[:, None]          # line 8
        confident = maxdiff(prob_norm) >= thresh                  # line 9
        live = live & ~confident
        return (prob, live, hops), None

    prob0 = jnp.zeros((B, gc.n_classes), jnp.float32)             # line 4
    live0 = jnp.ones((B,), bool)
    hops0 = jnp.zeros((B,), jnp.int32)
    (prob, _, hops), _ = jax.lax.scan(
        body, (prob0, live0, hops0), jnp.arange(max_hops))
    prob_norm = prob / jnp.maximum(hops, 1)[:, None]
    return FogResult(proba=prob_norm,
                     label=jnp.argmax(prob_norm, axis=-1).astype(jnp.int32),
                     hops=hops)


@partial(jax.jit, static_argnames=("max_hops",))
def fog_eval_multioutput(gcs, x: jax.Array, key: jax.Array,
                         thresh: float | jax.Array, max_hops: int) -> FogResult:
    """Algorithm 2 for MULTI-OUTPUT classification (paper footnote 1):
    one grove collection per output head; confidence = Min over outputs of
    the per-output MaxDiff ("minimum difference of the maximum values"), so
    an input keeps hopping until EVERY output is confident.

    gcs: tuple of GroveCollection with identical (n_groves, grove_size).
    Returns FogResult with proba [B, O, C] and label [B, O].
    """
    from repro.core.confidence import maxdiff_multioutput
    G = gcs[0].n_groves
    C = gcs[0].n_classes
    O = len(gcs)
    B = x.shape[0]
    start = jax.random.randint(key, (B,), 0, G)

    def body(carry, j):
        prob, live, hops = carry                    # prob [B, O, C]
        g_idx = (start + j) % G
        contrib = jnp.stack(
            [grove_predict_proba(gc, g_idx, x) for gc in gcs], axis=1)
        prob = prob + jnp.where(live[:, None, None], contrib, 0.0)
        hops = hops + live.astype(jnp.int32)
        prob_norm = prob / jnp.maximum(hops, 1)[:, None, None]
        confident = maxdiff_multioutput(prob_norm) >= thresh
        live = live & ~confident
        return (prob, live, hops), None

    prob0 = jnp.zeros((B, O, C), jnp.float32)
    (prob, _, hops), _ = jax.lax.scan(
        body, (prob0, jnp.ones((B,), bool), jnp.zeros((B,), jnp.int32)),
        jnp.arange(max_hops))
    prob_norm = prob / jnp.maximum(hops, 1)[:, None, None]
    return FogResult(proba=prob_norm,
                     label=jnp.argmax(prob_norm, axis=-1).astype(jnp.int32),
                     hops=hops)


@partial(jax.jit, static_argnames=("max_hops",))
def fog_eval_lazy(gc: GroveCollection, x: jax.Array, key: jax.Array,
                  thresh: float | jax.Array, max_hops: int) -> FogResult:
    """Early-terminating variant: a ``while_loop`` that stops as soon as the
    whole batch is confident.  Same results as :func:`fog_eval`; saves wall
    clock (not modeled energy) when the batch is easy."""
    B = x.shape[0]
    G = gc.n_groves
    start = jax.random.randint(key, (B,), 0, G)

    def cond(state):
        j, _, live, _ = state
        return (j < max_hops) & live.any()

    def body(state):
        j, prob, live, hops = state
        g_idx = (start + j) % G
        contrib = grove_predict_proba(gc, g_idx, x)
        prob = prob + jnp.where(live[:, None], contrib, 0.0)
        hops = hops + live.astype(jnp.int32)
        prob_norm = prob / jnp.maximum(hops, 1)[:, None]
        live = live & (maxdiff(prob_norm) < thresh)
        return (j + 1, prob, live, hops)

    state0 = (jnp.zeros((), jnp.int32),
              jnp.zeros((B, gc.n_classes), jnp.float32),
              jnp.ones((B,), bool),
              jnp.zeros((B,), jnp.int32))
    _, prob, _, hops = jax.lax.while_loop(cond, body, state0)
    prob_norm = prob / jnp.maximum(hops, 1)[:, None]
    return FogResult(proba=prob_norm,
                     label=jnp.argmax(prob_norm, axis=-1).astype(jnp.int32),
                     hops=hops)
