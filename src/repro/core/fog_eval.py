"""Algorithm 2 — legacy entry points, now thin shims over ``FogEngine``.

.. deprecated::
    The hop-until-confident loop lives in :mod:`repro.core.engine`; these
    wrappers exist so the original ``fog_eval*`` call sites keep working —
    each emits a real ``DeprecationWarning``.  New code should build a
    ``FogEngine`` and call ``eval(x, key, policy=FogPolicy(...))`` (which
    also exposes the pallas fused-update and mesh-ring backends, per-lane
    thresholds, and per-lane hop budgets) instead.

The ASIC processes examples as queue entries hopping grove-to-grove with a
req/ack handshake.  On a SIMD machine the identical math is a batched
fixed-point: at step j every *live* example evaluates grove
(start + j) mod n_groves (gathered node tables), accumulates the probability
array, and dies once MaxDiff(prob / (j+1)) >= thresh.  Hop counts — and
therefore the energy accounting — are bit-identical to the sequential queue
semantics; only the execution order differs (see README §Design).
"""
from __future__ import annotations

import warnings

import jax

from repro.core.engine import FogEngine, FogResult  # noqa: F401  (re-export)
from repro.core.grove import GroveCollection
from repro.core.policy import FogPolicy


def _warn(name: str, hint: str) -> None:
    warnings.warn(
        f"{name} is deprecated; use {hint}",
        DeprecationWarning, stacklevel=3)


def fog_eval(gc: GroveCollection, x: jax.Array, key: jax.Array,
             thresh: float | jax.Array, max_hops: int) -> FogResult:
    """GCEval(X, thresh, max_hops) — deprecated shim for the reference
    backend; use ``FogEngine(gc).eval(x, key, policy=FogPolicy(...))``."""
    _warn("fog_eval",
          "FogEngine(gc).eval(x, key, policy=FogPolicy(threshold=thresh, "
          "max_hops=max_hops))")
    return FogEngine(gc, backend="reference").eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=max_hops))


def fog_eval_multioutput(gcs, x: jax.Array, key: jax.Array,
                         thresh: float | jax.Array, max_hops: int) -> FogResult:
    """Multi-output Algorithm 2 (paper footnote 1) — deprecated shim; use
    ``FogEngine(tuple_of_gcs)``.  Confidence is the Min over outputs of the
    per-output MaxDiff, so an input hops until EVERY head is confident."""
    _warn("fog_eval_multioutput",
          "FogEngine(tuple_of_gcs).eval(x, key, policy=FogPolicy(...))")
    return FogEngine(tuple(gcs), backend="reference").eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=max_hops))


def fog_eval_lazy(gc: GroveCollection, x: jax.Array, key: jax.Array,
                  thresh: float | jax.Array, max_hops: int) -> FogResult:
    """Early-terminating variant — deprecated shim for
    ``FogEngine(gc, lazy=True)``: a ``while_loop`` that stops as soon as the
    whole batch is confident.  Same results as :func:`fog_eval`; saves wall
    clock (not modeled energy) when the batch is easy."""
    _warn("fog_eval_lazy",
          "FogEngine(gc, lazy=True).eval(x, key, policy=FogPolicy(...))")
    return FogEngine(gc, backend="reference", lazy=True).eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=max_hops))
