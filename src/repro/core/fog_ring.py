"""Distributed FoG — the paper's grove ring mapped onto a TPU mesh.

The ASIC pins grove g to a physical PE and forwards uncertain inputs over a
req/ack handshake to PE g+1 (Figure 3).  The TPU-native equivalent pins
grove g to mesh shard g and forwards the queue entry {Input Payload,
Probability Array, hops} with ``jax.lax.ppermute`` — the handshake becomes a
neighbor-only collective, the cheapest traffic pattern on a torus (no
all-to-all, no all-gather; each hop crosses one ICI link).

Each shard holds:
  * its own grove's node tables (grove-parallel: tables are *partitioned*,
    never replicated or gathered), and
  * a slice of the batch ("its queue").

Per round every shard evaluates ITS grove on the live lanes it currently
holds, then the whole lane state rotates one step around the ring.  After j
rounds a lane that started at shard s has been processed by groves
s, s+1, ..., s+j — exactly Algorithm 2's (start + j) mod n_groves with
start == the initial shard, randomized by shuffling the batch before entry.
Confident lanes die in place (their rotation continues but costs no
evaluation energy), matching the ASIC's completed-entry drain.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.confidence import maxdiff
from repro.core.grove import GroveCollection
from repro.forest.tree import _traverse


def _eval_local_grove(feature, threshold, leaf, x, use_kernels: bool):
    """Bundle evaluation of this shard's grove: [b, F] -> [b, C].

    ``use_kernels=True`` runs the Pallas tree-traversal PE
    (kernels/tree_traverse.py — node tables VMEM-resident, batch tiled);
    the jnp path is the oracle-equivalent fallback."""
    if use_kernels:
        from repro.kernels import ops
        b = x.shape[0]
        blk = b if b <= 128 else 128
        while b % blk:
            blk -= 1
        return ops.tree_traverse(feature[0], threshold[0], leaf[0], x,
                                 block_b=blk)
    per_tree = _traverse(feature[0], threshold[0], leaf[0], x)   # [b, k, C]
    return per_tree.mean(axis=1)


def make_fog_ring(mesh: Mesh, axis: str, max_hops: int,
                  use_kernels: bool = False):
    """Build the jitted ring evaluator for ``mesh`` (grove axis = ``axis``).

    Returns fn(gc_arrays, x, thresh) -> (proba, hops), where the grove
    collection's leading G axis and the batch are both sharded over ``axis``.
    """
    n_shards = mesh.shape[axis]

    def ring(feature, threshold, leaf, x, thresh):
        # Everything here is per-shard: feature [1, k, nodes], x [b, F].
        b = x.shape[0]
        prob = jnp.zeros((b, leaf.shape[-1]), jnp.float32)
        hops = jnp.zeros((b,), jnp.int32)
        live = jnp.ones((b,), bool)
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

        def body(carry, _):
            x, prob, hops, live = carry
            contrib = _eval_local_grove(feature, threshold, leaf, x,
                                        use_kernels)
            prob = prob + jnp.where(live[:, None], contrib, 0.0)
            hops = hops + live.astype(jnp.int32)
            prob_norm = prob / jnp.maximum(hops, 1)[:, None]
            live = live & (maxdiff(prob_norm) < thresh)
            # the handshake: rotate the queue entries to the next grove
            x = jax.lax.ppermute(x, axis, perm)
            prob = jax.lax.ppermute(prob, axis, perm)
            hops = jax.lax.ppermute(hops, axis, perm)
            live = jax.lax.ppermute(live, axis, perm)
            return (x, prob, hops, live), None

        (x, prob, hops, live), _ = jax.lax.scan(
            body, (x, prob, hops, live), None, length=max_hops)
        prob_norm = prob / jnp.maximum(hops, 1)[:, None]
        return prob_norm, hops

    gspec = P(axis)  # grove tables partitioned over the ring, dim 0
    fn = shard_map(
        ring, mesh=mesh,
        in_specs=(gspec, gspec, gspec, P(axis), P()),
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )
    return jax.jit(fn)


def fog_ring_eval(gc: GroveCollection, x: jax.Array, key: jax.Array,
                  thresh, max_hops: int, mesh: Mesh, axis: str = "grove",
                  use_kernels: bool = False):
    """Shuffle the batch (random start grove), run the ring, unshuffle."""
    B = x.shape[0]
    perm = jax.random.permutation(key, B)
    inv = jnp.argsort(perm)
    fn = make_fog_ring(mesh, axis, max_hops, use_kernels=use_kernels)
    proba, hops = fn(gc.feature, gc.threshold, gc.leaf, x[perm],
                     jnp.asarray(thresh, jnp.float32))
    return proba[inv], hops[inv]
