"""Distributed FoG — the paper's grove ring mapped onto a TPU mesh.

The ASIC pins grove g to a physical PE and forwards uncertain inputs over a
req/ack handshake to PE g+1 (Figure 3).  The TPU-native equivalent pins
groves to mesh shards and forwards the queue entry {Input Payload,
Probability Array, hops, grove index} with ``jax.lax.ppermute`` — the
handshake becomes a neighbor-only collective, the cheapest traffic pattern
on a torus (no all-to-all, no all-gather; each hop crosses one ICI link).

Grove placement is STRIDED: with n shards and G groves (G % n == 0), shard
s hosts groves {s, s+n, s+2n, ...}.  Grove g+1 therefore always lives on
shard (g+1) % n — one ring step from grove g's shard — so every lane
rotates exactly one neighbor per round regardless of how many groves each
shard holds.  With n == G this degenerates to the classic one-grove-per-PE
ring; with n == 1 the "ring" is a self-permute and the evaluation is
bit-identical to the batched reference path (same starts, same update).

Each shard holds:
  * the node tables of ITS groves (grove-parallel: tables are *partitioned*,
    never replicated or gathered), and
  * a slice of the batch ("its queue") — lanes are placed on the shard that
    owns their start grove.

Confident lanes die in place (their rotation continues but costs no
evaluation energy), matching the ASIC's completed-entry drain.  The per-hop
update is the shared ``kernels.ref.grove_aggregate_ref`` — the same math
every FogEngine backend runs — so hop counts (the energy quantity) are
bit-identical to Algorithm 2's sequential queue semantics.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.grove import GroveCollection
from repro.forest.tree import _traverse
from repro.kernels import ref


def _grove_order(G: int, n_shards: int) -> np.ndarray:
    """Reorder groves so shard s's contiguous block is {s, s+n, s+2n, ...}.

    shard_map partitions dim 0 in contiguous blocks; after this reorder,
    grove g sits on shard g % n at local offset g // n.
    """
    m = G // n_shards
    return np.arange(G).reshape(m, n_shards).T.reshape(-1)


def _eval_block_grove(feature, threshold, leaf, thr_scale, leaf_scale, x,
                      use_kernels: bool):
    """One grove per shard: whole-block bundle eval [b, F] -> [b, C].

    Tables arrive packed (fp32/bf16/int8 + per-tree scales, the shard's
    slice of a ``ForestPack`` ring layout).  ``use_kernels=True`` runs the
    Pallas tree-traversal PE (kernels/tree_traverse.py — packed node tables
    VMEM-resident, dequantized in-kernel, batch tiled); the jnp path
    dequantizes up front and is the oracle-equivalent fallback."""
    if use_kernels:
        from repro.kernels import ops
        b = x.shape[0]
        blk = b if b <= 128 else 128
        while b % blk:
            blk -= 1
        return ops.tree_traverse(feature[0], threshold[0], leaf[0], x,
                                 thr_scale[0], leaf_scale[0], block_b=blk)
    thr, lf = ref.dequantize_tables(threshold[0], leaf[0], thr_scale[0],
                                    leaf_scale[0])
    per_tree = _traverse(feature[0], thr, lf, x)                 # [b, k, C]
    return per_tree.mean(axis=1)


def _eval_gather_grove(feature, threshold, leaf, thr_scale, leaf_scale, x,
                       local_idx):
    """Multiple groves per shard: per-lane gathered bundle eval.

    feature [m, k, nodes]; local_idx [b] selects each lane's grove — the
    same packed gather + dequantize + walk as ``ForestPack.predict_proba``,
    restricted to this shard's table slice."""
    feat = feature[local_idx]
    thr, lf = ref.dequantize_tables(threshold[local_idx], leaf[local_idx],
                                    thr_scale[local_idx],
                                    leaf_scale[local_idx])

    def one(feat_b, thr_b, leaf_b, x_b):
        per_tree = _traverse(feat_b, thr_b, leaf_b, x_b[None])   # [1, k, C]
        return per_tree[0].mean(axis=0)

    return jax.vmap(one)(feat, thr, lf, x)


@lru_cache(maxsize=64)
def make_fog_ring(mesh: Mesh, axis: str, max_hops: int, n_groves: int,
                  use_kernels: bool = False):
    """Build the jitted ring evaluator for ``mesh`` (grove axis = ``axis``).

    Returns fn(feature, threshold, leaf, thr_scale, leaf_scale, x, start,
    thresh, budget) -> (proba, hops) where the packed grove tables
    (strided-reordered, see ``_grove_order``; fp32/bf16/int8 + per-tree
    dequant scales) and the batch are sharded over ``axis``, ``start`` is
    each lane's global start grove (lane already placed on shard
    start % n_shards), and ``thresh`` / ``budget`` are per-lane [B] vectors
    (a lane's confidence gate and hop budget travel with its queue entry —
    every queue field of the ASIC handshake, including the QoS contract,
    crosses the same ICI link).
    """
    n_shards = mesh.shape[axis]
    assert n_groves % n_shards == 0, (n_groves, n_shards)

    def ring(feature, threshold, leaf, thr_scale, leaf_scale, x, start,
             thresh, budget):
        # Per-shard views: feature [m, k, nodes], x [b, F], start [b].
        b = x.shape[0]
        m = feature.shape[0]
        prob = jnp.zeros((b, leaf.shape[-1]), jnp.float32)
        hops = jnp.zeros((b,), jnp.int32)
        live = jnp.ones((b,), bool)
        gidx = start                          # lane's current global grove
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

        def body(carry, _):
            x, prob, hops, live, gidx, thresh, budget = carry
            if m == 1:
                contrib = _eval_block_grove(feature, threshold, leaf,
                                            thr_scale, leaf_scale, x,
                                            use_kernels)
            else:
                contrib = _eval_gather_grove(feature, threshold, leaf,
                                             thr_scale, leaf_scale, x,
                                             gidx // n_shards)
            prob, hops, live, _ = ref.grove_aggregate_ref(
                prob, contrib, live, hops, thresh)
            live = live & (hops < budget)     # per-lane energy cap
            # the handshake: rotate queue entries to the next grove's shard
            gidx = (gidx + 1) % n_groves
            carry = tuple(jax.lax.ppermute(v, axis, perm)
                          for v in (x, prob, hops, live, gidx, thresh,
                                    budget))
            return carry, None

        (x, prob, hops, live, gidx, thresh, budget), _ = jax.lax.scan(
            body, (x, prob, hops, live, gidx, thresh, budget), None,
            length=max_hops)
        # after max_hops rotations a lane's state sits max_hops shards
        # downstream of where it entered; rotate it back so the gathered
        # output rows line up with the input batch order (identity permute
        # when n_shards divides max_hops)
        back = [(i, (i - max_hops) % n_shards) for i in range(n_shards)]
        prob = jax.lax.ppermute(prob, axis, back)
        hops = jax.lax.ppermute(hops, axis, back)
        prob_norm = prob / jnp.maximum(hops, 1)[:, None]
        return prob_norm, hops

    gspec = P(axis)  # grove tables partitioned over the ring, dim 0
    fn = shard_map(
        ring, mesh=mesh,
        in_specs=(gspec, gspec, gspec, gspec, gspec,
                  P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )
    return jax.jit(fn)


def reorder_tables(gc: GroveCollection, n_shards: int):
    """Strided-reordered fp32 (feature, threshold, leaf) ready to shard over
    the ring.  Legacy helper: packed callers get the same reorder — scales
    included, any dtype — from ``ForestPack.layout("ring", n_shards)``
    (cached per pack; the engine's TableCache serves it)."""
    order = _grove_order(gc.n_groves, n_shards)
    return gc.feature[order], gc.threshold[order], gc.leaf[order]


def _normalize_tables(tables):
    """Accept a legacy 3-tuple (fp32 tables) or a packed 5-tuple with
    per-tree dequant scales; return the 5-tuple form."""
    if len(tables) == 5:
        return tables
    feature, threshold, leaf = tables
    G, k = feature.shape[:2]
    return (feature, threshold, leaf,
            jnp.ones((G, k, 1), jnp.float32),
            jnp.ones((G, k, 1, 1), jnp.float32))


def ring_eval(gc: GroveCollection, x: jax.Array, start: jax.Array,
              thresh, max_hops: int, mesh: Mesh, axis: str = "grove",
              use_kernels: bool = False, tables=None, hop_budget=None):
    """Run the ring with explicit per-lane start groves.

    ``start`` must contain exactly B/n_shards lanes per residue class
    (start % n_shards) — ``engine.sample_starts`` produces such draws.
    Lanes are placed on their start grove's shard, evaluated, and returned
    in the original batch order.  ``thresh`` and ``hop_budget`` may be
    scalars or per-lane [B] vectors (FogPolicy's mixed-QoS contract);
    ``tables`` is an optional precomputed ring layout — either the legacy
    fp32 3-tuple (``reorder_tables(gc, n_shards)``) or the packed 5-tuple
    with dequant scales (``ForestPack.layout("ring", n_shards)``).
    """
    from repro.core.policy import NO_BUDGET
    B = x.shape[0]
    G = gc.n_groves
    n_shards = mesh.shape[axis]
    if B % n_shards:
        raise ValueError(
            f"batch B={B} must divide over {n_shards} ring shards")
    if not isinstance(start, jax.core.Tracer):
        # each shard's queue slice must be exactly B/n lanes or shard_map's
        # positional split would hand lanes the wrong grove tables
        counts = np.bincount(np.asarray(start) % n_shards,
                             minlength=n_shards)
        if not (counts == B // n_shards).all():
            raise ValueError(
                f"start groves not stratified over {n_shards} shards "
                f"(per-shard lane counts {counts.tolist()}); draw them "
                "with engine.sample_starts(key, B, G, n_shards)")
    feature, threshold, leaf, thr_scale, leaf_scale = _normalize_tables(
        tables if tables is not None else reorder_tables(gc, n_shards))
    thresh = jnp.broadcast_to(jnp.asarray(thresh, jnp.float32), (B,))
    if hop_budget is None:
        hop_budget = NO_BUDGET
    budget = jnp.broadcast_to(jnp.asarray(hop_budget, jnp.int32), (B,))
    # stable sort by owning shard -> contiguous equal-size per-shard queues
    perm = jnp.argsort(start % n_shards, stable=True)
    inv = jnp.argsort(perm)
    fn = make_fog_ring(mesh, axis, max_hops, G, use_kernels=use_kernels)
    proba, hops = fn(feature, threshold, leaf, thr_scale, leaf_scale,
                     x[perm], start[perm], thresh[perm], budget[perm])
    return proba[inv], hops[inv]


def fog_ring_eval(gc: GroveCollection, x: jax.Array, key: jax.Array,
                  thresh, max_hops: int, mesh: Mesh, axis: str = "grove",
                  use_kernels: bool = False):
    """Legacy entry point: draw stratified random starts, run the ring.

    .. deprecated::
        Use ``FogEngine(gc, backend="ring", mesh=mesh).eval(x, key,
        policy=FogPolicy(...))`` — this shim remains for callers that
        manage their own meshes.
    """
    import warnings
    warnings.warn(
        "fog_ring_eval is deprecated; use FogEngine(gc, backend='ring', "
        "mesh=mesh).eval(x, key, policy=FogPolicy(threshold=..., "
        "max_hops=...)) instead",
        DeprecationWarning, stacklevel=2)
    from repro.core.engine import sample_starts
    start = sample_starts(key, x.shape[0], gc.n_groves,
                          mesh.shape[axis])
    return ring_eval(gc, x, start, thresh, max_hops, mesh, axis,
                     use_kernels=use_kernels)
