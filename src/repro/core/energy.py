"""Energy model — per-classification energy accounting.

The paper extracts per-block PPA from Cadence/Aladdin at 40 nm and sums
per-op energies over each classifier's evaluation path.  Offline we do the
same arithmetic with published 40/45 nm per-op energies (Horowitz, ISSCC'14
"Computing's energy problem"), counting ops *exactly* from the algorithms:

FoG energy is owned by :class:`EnergyModel` — a frozen dataclass whose
per-classification cost is a *pure function* of (pack precision, topology,
hops): ``lane_pj(hops)`` is affine in the hop count
(``hops * per_hop_pj + (hops-1) * transfer_pj``), so the same object serves
post-hoc reports (:meth:`EnergyModel.report`, float64 — ``fp32`` reproduces
the pre-EnergyModel ``fog_energy`` numbers bit-for-bit), live per-lane
telemetry inside :class:`~repro.core.engine.EvalReport` (``lane_pj`` on
device arrays), and the governor's inverse question (:meth:`hops_within` —
the largest hop budget affordable under a pJ budget).  ``fog_energy``
remains as a thin wrapper.

Op-count recipes:

  DT       : d node-reads + d feature-reads + d comparisons (visited path only)
  RF       : t * DT + majority vote (t int adds)
  grove    : k * DT + prob accumulate (C fp adds) + MaxDiff (C comparisons)
  FoG      : sum over inputs of hops * grove + hop transfer (queue-entry
             copy over the handshake: Gamma bytes SRAM write + read)
  SVM_lr   : C*F MACs
  SVM_rbf  : n_sv * (F dist-MACs + exp) + n_sv MACs
  MLP/CNN  : layer MACs + activation evals

Energy ratios between classifiers — the paper's claims — depend only on op
counts and these constants, not on our container's hardware.

Table precision: the FoG paths take the :mod:`repro.forest.pack` precision
("fp32" | "bf16" | "int8") and scale SRAM read energy by the *actual bytes
per node* — a node entry is {feature idx 2B, threshold 4/2/1B, offset 2B} —
and shrink the SRAM array capacity term accordingly (per-access energy grows
~sqrt(capacity)), so quantized packs show up directly in the fog_energy
report.  ``fp32`` reproduces the original accounting exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.pack import PRECISION_BYTES

# ---- per-op energies, picojoules (Horowitz ISSCC'14, 45nm; paper: 40nm) ----
E_INT8_ADD = 0.03
E_INT32_ADD = 0.1
E_FP32_ADD = 0.9
E_INT8_MULT = 0.2
E_FP32_MULT = 3.7
E_FP32_MAC = E_FP32_ADD + E_FP32_MULT          # 4.6
E_CMP8 = 0.03                                   # 8-bit comparator (DT node, byte features)
E_CMP32 = 0.1
E_EXP = 20.0                                    # LUT + interpolation mult
E_SRAM_R32 = 5.0                                # local SRAM read, per 32b word
E_SRAM_W32 = 5.0
PJ = 1e-12
NJ = 1e-9


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    total_pj: float
    per_example_pj: float

    @property
    def per_example_nj(self) -> float:
        return self.per_example_pj * 1e-3

    def __str__(self) -> str:
        # the human-facing unit is nJ/classification everywhere (frontier
        # logs, sweep rows, bench output) — never raw pJ totals
        return f"{self.per_example_nj:.3f} nJ/example"


# ---------------------------------------------------------------- trees ----
def _sram_scale(capacity_bytes: float) -> float:
    """Per-access energy grows ~sqrt(capacity) (bitline/wordline length);
    E_SRAM_R32 is calibrated for an 8 KB array."""
    return max(1.0, np.sqrt(capacity_bytes / 8192.0))


def tree_bytes(depth: int, n_classes: int, precision: str = "fp32") -> float:
    """Node table {feature idx 2B, threshold 4/2/1B, offset 2B} + byte
    leaves.  ``precision`` is the packed threshold width (forest.pack);
    the paper's byte-addressable leaves are byte-wide at every precision."""
    node_bytes = 4.0 + PRECISION_BYTES[precision]
    return (2**depth - 1) * node_bytes + 2**depth * n_classes


def dt_energy_pj(depth: int, n_classes: int = 10,
                 precision: str = "fp32") -> float:
    """One decision tree, one example: the visited root-to-leaf path.
    SRAM access energy scales with the tree's table size (a depth-12
    ISOLET tree needs a ~140 KB array, not the 8 KB baseline) and with the
    actual bytes per node entry — an int8-threshold node reads 5 of the
    fp32 entry's 8 bytes, and its array is smaller."""
    s = _sram_scale(tree_bytes(depth, n_classes, precision))
    # node read: {feature idx, threshold, offset} = 4 + threshold bytes
    # (fp32: 8 B = 2 words, the original accounting); feature read: 1 word
    node_words = (4.0 + PRECISION_BYTES[precision]) / 4.0
    per_node = (node_words * E_SRAM_R32) * s + E_SRAM_R32 + E_CMP8
    return depth * per_node


def rf_energy_pj(n_trees: int, depth: int, n_classes: int) -> float:
    vote = n_trees * E_INT32_ADD + n_classes * E_CMP32
    return n_trees * dt_energy_pj(depth, n_classes) + vote


def grove_energy_pj(grove_size: int, depth: int, n_classes: int,
                    precision: str = "fp32") -> float:
    # the data queue stores one BYTE per class (§3.2.2 footnote: byte-
    # addressable Probability Array) -> int8 accumulate, word-packed SRAM
    words = max(1, (n_classes + 3) // 4)
    agg = n_classes * E_INT8_ADD + words * (E_SRAM_R32 + E_SRAM_W32)
    conf = n_classes * E_CMP8 + E_INT8_ADD                     # MaxDiff pass
    return (grove_size * dt_energy_pj(depth, n_classes, precision)
            + agg + conf)


def hop_transfer_energy_pj(n_features: int, n_classes: int) -> float:
    """Queue-entry copy over the handshake: Gamma = 1 + F + 1 + C bytes."""
    gamma_words = int(np.ceil((1 + n_features + 1 + n_classes) / 4))
    return gamma_words * (E_SRAM_R32 + E_SRAM_W32)


# ---------------------------------------------------------- EnergyModel ----
class AffineHopCost:
    """Shared hops -> pJ arithmetic: anything exposing ``per_hop_pj`` and
    ``transfer_pj`` prices a hop vector the same affine way.  Mixed into
    :class:`EnergyModel` (tree-topology pricing) and :class:`AffineEnergy`
    (raw per-hop costs, e.g. the LM layer-grove gate)."""

    def lane_pj(self, hops):
        """Per-example pJ for a [B] hop vector — dtype-generic: a jnp array
        stays on device (EvalReport telemetry), a numpy array stays host."""
        xp = jnp if isinstance(hops, jax.Array) else np
        h = xp.asarray(hops)
        return (h * self.per_hop_pj
                + xp.maximum(h - 1, 0) * self.transfer_pj)

    def report(self, hops) -> EnergyReport:
        """Float64 post-hoc report — the original ``fog_energy`` arithmetic,
        bit-for-bit."""
        per_ex = self.lane_pj(np.asarray(hops, np.float64))
        return EnergyReport(float(per_ex.sum()), float(per_ex.mean()))

    def mean_pj(self, mean_hops: float) -> float:
        """Expected pJ/classification at a mean hop count (affinity in hops
        makes the mean exact for any hop distribution with that mean, as
        long as every example hops at least once — which Algorithm 2
        guarantees)."""
        return (mean_hops * self.per_hop_pj
                + max(mean_hops - 1.0, 0.0) * self.transfer_pj)

    def hops_within(self, budget_pj: float) -> int:
        """Largest per-example hop budget whose worst-case cost fits
        ``budget_pj`` (>= 1: the first hop is always spent — a budget below
        one hop's cost still buys one hop, matching FogPolicy.hop_budget's
        floor)."""
        per_extra = self.per_hop_pj + self.transfer_pj
        return max(1, int((budget_pj - self.per_hop_pj) // per_extra) + 1)


@dataclasses.dataclass(frozen=True)
class AffineEnergy(AffineHopCost):
    """Affine hops -> pJ pricing from raw per-hop costs, for evaluation
    paths with no tree topology — the LM layer-grove early-exit gate prices
    a "hop" as one layer-block's MACs.  Same contract as
    :class:`EnergyModel` (``lane_pj`` / ``report`` / ``hops_within``), so
    the serving governor accepts either."""

    per_hop_pj: float
    transfer_pj: float = 0.0
    precision: str = "fp32"


@dataclasses.dataclass(frozen=True)
class EnergyModel(AffineHopCost):
    """Per-classification FoG energy as a pure function of (precision,
    topology, hops).

    One frozen, hashable object per (topology, precision) pair: the engine
    stamps it on every :class:`~repro.core.engine.EvalReport`, the frontier
    builder prices policy grids with it, and the serving governor inverts it
    (:meth:`hops_within`) to turn an nJ budget into a hop budget.  The cost
    is affine in hops::

        pJ(example) = hops * per_hop_pj + max(hops - 1, 0) * transfer_pj

    (the first grove receives its input from the processor, so an example
    pays one fewer handshake transfer than it pays grove evaluations).
    ``fp32`` reproduces the pre-EnergyModel ``fog_energy`` accounting
    bit-for-bit.
    """

    grove_size: int
    depth: int
    n_classes: int
    n_features: int
    precision: str = "fp32"

    @property
    def per_hop_pj(self) -> float:
        """One grove evaluation: k tree walks + accumulate + MaxDiff."""
        return grove_energy_pj(self.grove_size, self.depth, self.n_classes,
                               self.precision)

    @property
    def transfer_pj(self) -> float:
        """One queue-entry handshake copy between groves."""
        return hop_transfer_energy_pj(self.n_features, self.n_classes)

    @classmethod
    def from_pack(cls, pack, n_features: int) -> "EnergyModel":
        """Model of a :class:`~repro.forest.pack.ForestPack`'s geometry at
        the pack's own precision."""
        return cls(pack.grove_size, pack.depth, pack.n_classes,
                   int(n_features), pack.precision)


def fog_energy(hops: np.ndarray, grove_size: int, depth: int,
               n_classes: int, n_features: int,
               precision: str = "fp32") -> EnergyReport:
    """hops: [B] groves-used per example (FogResult.hops); ``precision`` is
    the packed-table dtype the evaluation ran at (scales the per-node SRAM
    bytes — the paper's dominant energy term).  Thin wrapper over
    :meth:`EnergyModel.report`."""
    return EnergyModel(grove_size, depth, n_classes, n_features,
                       precision).report(hops)


def rf_report(batch: int, n_trees: int, depth: int, n_classes: int) -> EnergyReport:
    e = rf_energy_pj(n_trees, depth, n_classes)
    return EnergyReport(e * batch, e)


# ------------------------------------------------------------ baselines ----
def svm_lr_energy_pj(n_features: int, n_classes: int) -> float:
    return n_classes * n_features * (E_FP32_MAC + E_SRAM_R32)


def svm_rbf_energy_pj(n_features: int, n_classes: int, n_sv: int) -> float:
    per_sv = n_features * (E_FP32_ADD + E_FP32_MULT + E_SRAM_R32) + E_EXP + E_FP32_MAC
    return n_sv * per_sv


def mlp_energy_pj(layer_sizes: list[int]) -> float:
    """layer_sizes: [F, h1, ..., C]."""
    e = 0.0
    for a, b in zip(layer_sizes[:-1], layer_sizes[1:]):
        e += a * b * (E_FP32_MAC + E_SRAM_R32) + b * E_EXP   # matmul + activation
    return e


def cnn_energy_pj(conv_macs: int, dense_macs: int, activations: int) -> float:
    return (conv_macs + dense_macs) * (E_FP32_MAC + E_SRAM_R32) + activations * E_EXP
