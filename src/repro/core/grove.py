"""Algorithm 1 — constructing the Field of Groves classifier.

GCTrain(n, k, X, y): pre-train a conventional RF of n trees, then Split it
into groves of k trees each.  The grove collection is a single
``TensorForest`` reshaped to [n_groves, k, ...], so each grove's
``predict_proba`` is a tensorized bundle evaluation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.tree import TensorForest, _traverse
from repro.forest.train import TrainConfig, train_random_forest


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GroveCollection:
    """Split grove ensemble GC: [n_groves, k] trees."""

    feature: jax.Array    # int32   [G, k, 2**d - 1]
    threshold: jax.Array  # float32 [G, k, 2**d - 1]
    leaf: jax.Array       # float32 [G, k, 2**d, C]

    def tree_flatten(self):
        return (self.feature, self.threshold, self.leaf), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_groves(self) -> int:
        return self.feature.shape[0]

    @property
    def grove_size(self) -> int:
        return self.feature.shape[1]

    @property
    def depth(self) -> int:
        return int(np.log2(self.leaf.shape[2]) + 0.5)

    @property
    def n_classes(self) -> int:
        return self.leaf.shape[3]

    def grove(self, g: int) -> TensorForest:
        return TensorForest(self.feature[g], self.threshold[g], self.leaf[g])

    def as_forest(self) -> TensorForest:
        """Undo the split (for the FoG_max == RF equivalence checks)."""
        g, k = self.feature.shape[:2]
        return TensorForest(
            self.feature.reshape(g * k, -1),
            self.threshold.reshape(g * k, -1),
            self.leaf.reshape(g * k, *self.leaf.shape[2:]),
        )


def split(forest: TensorForest, k: int) -> GroveCollection:
    """Split(RF, k) — Algorithm 1 lines 5-15.  Trees [i..i+k) -> grove i/k."""
    stacked = forest.stack_groves(k)
    return GroveCollection(stacked.feature, stacked.threshold, stacked.leaf)


def gc_train(n: int, k: int, x: np.ndarray, y: np.ndarray, n_classes: int,
             train_cfg: TrainConfig | None = None) -> GroveCollection:
    """GCTrain(n, k, X, y) — Algorithm 1 lines 1-4."""
    cfg = dataclasses.replace(train_cfg or TrainConfig(), n_trees=n)
    rf = train_random_forest(x, y, n_classes, cfg)
    return split(rf, k)


def grove_predict_proba(gc: GroveCollection, g_idx: jax.Array,
                        x: jax.Array) -> jax.Array:
    """Grove(index).predict_prob(x) for a *batch* with per-example grove ids.

    g_idx: int32 [B]; x: [B, F]  ->  [B, C]

    Gathers each example's grove node tables then runs the bundle walk.  This
    is the batched equivalent of routing example b to physical grove g_idx[b].
    """
    feat = gc.feature[g_idx]      # [B, k, nodes]
    thr = gc.threshold[g_idx]
    leaf = gc.leaf[g_idx]

    def one(feat_b, thr_b, leaf_b, x_b):
        per_tree = _traverse(feat_b, thr_b, leaf_b, x_b[None])   # [1, k, C]
        return per_tree[0].mean(axis=0)

    return jax.vmap(one)(feat, thr, leaf, x)
