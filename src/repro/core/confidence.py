"""MaxDiff confidence (Algorithm 2, subroutine lines 16-19).

Confidence = |top1 - top2| of the (normalized) probability array.  For
multi-output classification the paper takes the Min over outputs of the
per-output margins ("minimum difference of the maximum values").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def top2(ar: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Two largest values along ``axis`` without a full sort (single pass)."""
    m1 = jnp.max(ar, axis=axis)
    # mask out ONE occurrence of the max, then take the max again
    is_max = ar == jnp.expand_dims(m1, axis)
    first_max = jnp.cumsum(is_max.astype(jnp.int32), axis=axis) == 1
    masked = jnp.where(is_max & first_max, -jnp.inf, ar)
    m2 = jnp.max(masked, axis=axis)
    return m1, m2


def maxdiff(ar: jax.Array, axis: int = -1) -> jax.Array:
    """MaxDiff(ar) = |max1 - max2| along ``axis``."""
    m1, m2 = top2(ar, axis=axis)
    return jnp.abs(m1 - m2)


def maxdiff_multioutput(ar: jax.Array) -> jax.Array:
    """Multi-output rule: ar is [..., n_outputs, C]; Min over outputs."""
    return jnp.min(maxdiff(ar, axis=-1), axis=-1)
