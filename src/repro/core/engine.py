"""FogEngine — the single owner of Algorithm 2 with pluggable backends.

The paper's hop-until-confident loop used to live in four divergent copies
(``fog_eval``, ``fog_eval_multioutput``, ``fog_eval_lazy`` and the ring in
``fog_ring.py``).  This module collapses them into one state machine whose
*per-hop update* — masked accumulate, hop count, normalize, MaxDiff gate —
is a pluggable backend:

==============  =============================================================
backend         per-hop update implementation
==============  =============================================================
``reference``   pure jnp (``kernels.ref.grove_aggregate_ref``), the oracle
``pallas``      fused VMEM hop-update kernel (``kernels.ops.grove_aggregate``);
                interpreted on CPU, Mosaic-compiled on TPU — one launch
                per hop
``fused``       the ENTIRE Algorithm-2 loop in one Pallas launch
                (``kernels.ops.fused_fog``): every grove table VMEM-pinned,
                the early-exit loop runs inside the kernel — the TPU
                analogue of the paper's PE
``ring``        ``shard_map`` + ``ppermute`` mesh ring (``fog_ring``) — the
                grove tables are partitioned over devices and queue entries
                rotate one ICI hop per round
==============  =============================================================

Every runtime knob — threshold (scalar or per-lane ``[B]``), hop caps and
per-lane hop budgets, backend selection, tiling, table precision — is owned
by a :class:`repro.core.policy.FogPolicy`; the canonical evaluation call is

    engine.eval(x, key, policy=FogPolicy(threshold=0.3))

(the old positional ``eval(x, key, thresh, max_hops)`` survives as a
deprecated shim).  Every backend runs the *identical* update math, so labels
and — critically — per-example hop counts (the paper's energy quantity) are
bit-identical across backends for the same starting groves, including under
per-lane thresholds and budgets.  ``sample_starts`` is the one place start
groves are drawn: on a single shard it reproduces the legacy ``fog_eval``
draw exactly; on an n-shard ring it stratifies starts so each shard begins
with an equal slice of the queue.

Grove tables are owned by a :class:`TableCache`: one packed
:class:`~repro.forest.pack.ForestPack` per precision ("fp32" | "bf16" |
"int8"), with the derived layouts (ring strided reorder, fused head-stack)
cached inside each pack.  Every backend evaluates the pack — the fused
kernel pins the packed bytes whole in VMEM, the per-hop backends gather and
dequantize per-lane slices — so switching ``FogPolicy(precision=...)``
swaps table dtypes without rebuilding the engine.  (The former
``engine.ring_tables`` / ``engine.fused_tables`` attributes are gone; use
``engine.tables.get(layout, precision)``.)

Batches larger than VMEM are evaluated in fixed-size chunks (``chunk_b``)
with one compiled program reused across chunks; per-lane policy vectors are
dead-padded alongside the inputs.  ``chunk_b="auto"`` (the fused backend's
default) only chunks when the packed tables + whole-batch footprint exceed
the VMEM budget, sizing chunks from the pack's per-chunk footprint.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidence import maxdiff
from repro.core.energy import EnergyModel, EnergyReport
from repro.core.grove import GroveCollection
from repro.core.policy import BACKENDS, PRECISIONS, FogPolicy
from repro.forest.pack import ForestPack
from repro.kernels import ops, ref

# batch tile when nothing chooses one: per-hop backends always use it;
# the fused backend only falls back here when the autotuner has no
# feasible block (tables alone over the VMEM budget — the kernel's
# ValueError then explains the remedies)
DEFAULT_BLOCK_B = 256


@partial(jax.tree_util.register_dataclass,
         data_fields=("proba", "label", "hops"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class FogResult:
    """The one result contract every backend returns.

    proba: [B, C] (or [B, O, C] multi-output) final normalized probabilities
    label: [B]    (or [B, O]) argmax labels
    hops:  [B]    groves that processed each example, 1-based — the energy
                  quantity (the paper's `hops` counts forwards = groves-1)
    """
    proba: jax.Array
    label: jax.Array
    hops: jax.Array


@partial(jax.tree_util.register_dataclass,
         data_fields=("proba", "label", "hops", "energy_pj"),
         meta_fields=("model",))
@dataclasses.dataclass(frozen=True)
class EvalReport(FogResult):
    """What ``FogEngine.eval`` returns: the FogResult contract plus the
    energy telemetry every consumer used to re-derive by hand
    (``HopMeter`` + ``fog_energy``).

    energy_pj: [B] estimated pJ per example — ``model.lane_pj(hops)``,
               computed on device alongside the evaluation outputs
    model:     the :class:`~repro.core.energy.EnergyModel` the estimate was
               priced with (topology + the precision the evaluation actually
               ran at) — callers can re-price or invert budgets without
               reaching back into the engine
    """
    energy_pj: jax.Array = None
    model: EnergyModel = None

    @property
    def precision(self) -> str:
        return self.model.precision

    @property
    def mean_energy_pj(self) -> float:
        return float(np.asarray(self.energy_pj).mean())

    @property
    def mean_energy_nj(self) -> float:
        return self.mean_energy_pj * 1e-3

    def energy_report(self) -> EnergyReport:
        """Float64 post-hoc report over this evaluation's hops —
        bit-identical to the legacy ``fog_energy(hops, ...)`` call."""
        return self.model.report(np.asarray(self.hops))


def sample_starts(key: jax.Array, B: int, G: int,
                  n_shards: int = 1) -> jax.Array:
    """Random start grove per example (Algorithm 2 line 3).

    ``n_shards == 1`` reproduces the legacy ``fog_eval`` draw bit-exactly.
    For an n-shard ring the draw is stratified — exactly B/n lanes start in
    each shard residue class (start % n == shard) so the queue slices are
    equal-sized — while staying uniform over all G groves marginally.
    """
    if n_shards == 1:
        return jax.random.randint(key, (B,), 0, G)
    if B % n_shards or G % n_shards:
        raise ValueError(
            f"batch B={B} and n_groves G={G} must both divide over "
            f"{n_shards} ring shards")
    kp, ko = jax.random.split(key)
    shard = jax.random.permutation(
        kp, jnp.tile(jnp.arange(n_shards), B // n_shards))
    offset = jax.random.randint(ko, (B,), 0, G // n_shards)
    return shard + n_shards * offset


def hop_update(prob, contrib, live, hops, thresh, *, backend: str = "reference",
               block_b: int = 256):
    """One Algorithm-2 hop update (lines 7-11), dispatched by backend.

    ``thresh`` is a scalar or per-lane ``[B]`` vector.  Returns
    (prob, hops, live, margin).  This is the single shared update both
    FogEngine loops and the distributed ring build on.
    """
    _check_step_backend(backend)
    if backend == "pallas":
        return ops.grove_aggregate(prob, contrib, live, hops, thresh,
                                   block_b=block_b)
    return ref.grove_aggregate_ref(prob, contrib, live, hops, thresh)


def confidence_margin(probs: jax.Array, *, backend: str = "reference",
                      block_b: int = 256) -> jax.Array:
    """MaxDiff margin [..., C] -> [...]; pallas routes the top-2 kernel."""
    _check_step_backend(backend)
    if backend == "pallas" and probs.ndim == 2:
        return ops.top2_confidence(probs, block_b=min(block_b, probs.shape[0]))
    return maxdiff(probs)


def _check_step_backend(backend: str) -> None:
    # the per-step primitives have no ring variant (the ring composes them)
    if backend not in ("reference", "pallas"):
        raise ValueError(f"unknown step backend {backend!r}; "
                         "pick 'reference' or 'pallas'")


# --------------------------------------------------------------------------
# jitted evaluation cores (reference / pallas).  Multi-output heads are
# flattened to [B*O, C] so the same fused update serves both; the min-over-
# outputs confidence rule (paper footnote 1) is applied on the margins.
# --------------------------------------------------------------------------

def _contrib(pack: ForestPack, g_idx, x):
    """Per-hop grove contribution from packed tables, flattened over output
    heads: [B*O, C].  Gathers stay at the pack's dtype; the gathered slices
    dequantize to fp32 before the walk (bit-identical to the legacy
    GroveCollection path for an fp32 pack)."""
    if pack.n_heads == 1:
        return pack.predict_proba(0, g_idx, x)
    rows = [pack.predict_proba(o, g_idx, x) for o in range(pack.n_heads)]
    return jnp.stack(rows, axis=1).reshape(-1, pack.n_classes)


def _repeat_lanes(v, n_out):
    """[B] lane state -> [B*O] (each head shares its lane's liveness)."""
    return v if n_out == 1 else jnp.repeat(v, n_out)


def _step(pack, x, start, thresh, budget, j, prob, live, hops, backend,
          block_b):
    """Shared hop body: returns updated (prob, live, hops) for [B*O, C].

    ``thresh`` is per-lane [B] float32; ``budget`` per-lane [B] int32 — a
    lane that has consumed its hop budget dies even while unconfident.
    """
    O = pack.n_heads
    G = pack.n_groves
    g_idx = (start + j) % G
    contrib = _contrib(pack, g_idx, x)
    prob, hops_f, live_f, margin = hop_update(
        prob, contrib, _repeat_lanes(live, O), _repeat_lanes(hops, O),
        _repeat_lanes(thresh, O), backend=backend, block_b=block_b)
    if O == 1:
        return prob, live_f & (hops_f < budget), hops_f
    # min-over-outputs rule: a lane stays live until EVERY head is confident
    margin = margin.reshape(-1, O).min(axis=1)
    hops = hops_f.reshape(-1, O)[:, 0]
    live = live & (margin < thresh) & (hops < budget)
    return prob, live, hops


@partial(jax.jit, static_argnames=("max_hops", "backend", "block_b", "lazy",
                                   "compact", "interpret"))
def _eval_core(pack: ForestPack, x, start, thresh, budget, max_hops: int,
               backend: str, block_b: int, lazy: bool,
               compact: bool = True, interpret: bool | None = None):
    B = x.shape[0]
    O = pack.n_heads
    C = pack.n_classes
    if block_b is None:  # external positional callers (serving plane)
        block_b = DEFAULT_BLOCK_B
    thresh = jnp.broadcast_to(jnp.asarray(thresh, jnp.float32), (B,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (B,))

    if backend == "fused":
        # the whole early-exit state machine runs inside ONE kernel launch;
        # `lazy` is moot (the in-kernel while_loop always exits early).
        # The pack's canonical storage IS the head-stacked [O, G, ...]
        # layout, pinned in VMEM at its packed width, so one launch serves
        # the min-over-outputs rule and every precision alike.
        feat, thr_tab, leaf, ts, ls = pack.layout("fused")
        proba, hops = ops.fused_fog(
            feat, thr_tab, leaf,
            x, start, thresh, budget, ts, ls,
            max_hops=max_hops, block_b=block_b, compact=compact,
            interpret=interpret)
        if O == 1:
            proba = proba[:, 0]
        return FogResult(proba=proba,
                         label=jnp.argmax(proba, axis=-1).astype(jnp.int32),
                         hops=hops)
    prob0 = jnp.zeros((B * O, C), jnp.float32)
    live0 = jnp.ones((B,), bool)
    hops0 = jnp.zeros((B,), jnp.int32)

    if lazy:
        def cond(state):
            j, _, live, _ = state
            return (j < max_hops) & live.any()

        def body(state):
            j, prob, live, hops = state
            prob, live, hops = _step(pack, x, start, thresh, budget, j, prob,
                                     live, hops, backend, block_b)
            return (j + 1, prob, live, hops)

        _, prob, _, hops = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), prob0, live0, hops0))
    else:
        def body(carry, j):
            prob, live, hops = carry
            prob, live, hops = _step(pack, x, start, thresh, budget, j, prob,
                                     live, hops, backend, block_b)
            return (prob, live, hops), None

        (prob, _, hops), _ = jax.lax.scan(
            body, (prob0, live0, hops0), jnp.arange(max_hops))

    denom = jnp.maximum(_repeat_lanes(hops, O), 1)[:, None]
    prob_norm = prob / denom
    if O > 1:
        prob_norm = prob_norm.reshape(B, O, C)
    return FogResult(proba=prob_norm,
                     label=jnp.argmax(prob_norm, axis=-1).astype(jnp.int32),
                     hops=hops)


# --------------------------------------------------------------------------
# device-resident lane state (the serving plane's donated splice path)
# --------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _splice(buf, idx, vals):
    # mode="drop": padding indices point one past the end and fall away,
    # so every splice width compiles once per power-of-two pad size
    return buf.at[idx].set(vals, mode="drop")


@jax.jit
def _splice_copy(buf, idx, vals):
    return buf.at[idx].set(vals, mode="drop")


def splice_lanes(buf: jax.Array, idx, vals, *,
                 donate: bool = True) -> jax.Array:
    """In-place row update of a device-resident lane buffer.

    With ``donate=True`` (the default) ``buf`` is DONATED: the caller must
    replace its reference with the return value
    (``buf = splice_lanes(buf, idx, vals)``).  Pass ``donate=False`` when
    an in-flight async computation may still be READING ``buf`` — donating
    a buffer with live readers stalls the enqueue until they drain, which
    serializes a double-buffered dispatch pipeline; the copying splice
    keeps the enqueue non-blocking and costs one buffer copy (trivial at
    per-span row-buffer sizes).

    ``idx`` / the leading axis of ``vals`` are padded to the next power of
    two (capped at the buffer length) with out-of-range indices that
    ``mode="drop"`` discards, so admit/retire bursts of any size reuse a
    handful of compiled splice programs instead of one per burst width.
    """
    idx = np.asarray(idx, np.int32).reshape(-1)
    n = int(buf.shape[0])
    vals = np.asarray(vals)
    if idx.size == 0:
        return buf
    width = min(n, 1 << max(0, int(idx.size - 1).bit_length()))
    pad = width - idx.size
    if pad > 0:
        idx = np.concatenate([idx, np.full((pad,), n, np.int32)])
        vals = np.concatenate(
            [vals, np.zeros((pad,) + vals.shape[1:], vals.dtype)])
    elif pad < 0:
        raise ValueError(
            f"splice of {idx.size} lanes into a {n}-lane buffer")
    fn = _splice if donate else _splice_copy
    return fn(buf, idx, vals.astype(buf.dtype, copy=False))


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _splice3(x, thr, bud, idx, rows, t, b):
    return (x.at[idx].set(rows, mode="drop"),
            thr.at[idx].set(t, mode="drop"),
            bud.at[idx].set(b, mode="drop"))


@jax.jit
def _splice3_copy(x, thr, bud, idx, rows, t, b):
    return (x.at[idx].set(rows, mode="drop"),
            thr.at[idx].set(t, mode="drop"),
            bud.at[idx].set(b, mode="drop"))


def splice_slot_state(x: jax.Array, thr: jax.Array, bud: jax.Array,
                      idx, rows, t, b, *,
                      donate: bool = True):
    """Fused :func:`splice_lanes` over a replica's THREE slot buffers
    (feature rows, thresholds, hop budgets) sharing ONE lane index set —
    a refill burst costs a single jitted launch instead of three.  Same
    power-of-two padding / ``mode="drop"`` program reuse and the same
    donation contract: with ``donate=True`` all three buffers are donated
    and must be rebound to the returned triple; ``donate=False`` copies,
    for callers whose previous dispatch may still be reading them."""
    idx = np.asarray(idx, np.int32).reshape(-1)
    if idx.size == 0:
        return x, thr, bud
    n = int(x.shape[0])
    rows = np.asarray(rows)
    t = np.asarray(t)
    b = np.asarray(b)
    width = min(n, 1 << max(0, int(idx.size - 1).bit_length()))
    pad = width - idx.size
    if pad > 0:
        idx = np.concatenate([idx, np.full((pad,), n, np.int32)])
        rows = np.concatenate(
            [rows, np.zeros((pad,) + rows.shape[1:], rows.dtype)])
        t = np.concatenate([t, np.zeros((pad,), t.dtype)])
        b = np.concatenate([b, np.zeros((pad,), b.dtype)])
    elif pad < 0:
        raise ValueError(
            f"splice of {idx.size} lanes into a {n}-lane buffer")
    fn = _splice3 if donate else _splice3_copy
    return fn(x, thr, bud, idx,
              rows.astype(x.dtype, copy=False),
              t.astype(thr.dtype, copy=False),
              b.astype(bud.dtype, copy=False))


# --------------------------------------------------------------------------
# packed-table ownership
# --------------------------------------------------------------------------

class TableCache:
    """One :class:`ForestPack` per precision, derived layouts cached inside.

    Replaces the engine's former ad-hoc ``_ring_tables`` / ``_fused_tables``
    pair: every evaluation path asks this cache for its (layout, dtype)
    view, so a given precision's tables are packed once per engine and the
    ring reorder / head-stack are computed once per pack.
    """

    def __init__(self, gcs_fn):
        # a zero-arg callable, not the groves themselves: an engine seeded
        # with a loaded pack serves it without ever materializing fp32
        # tables — groves are only realized if ANOTHER precision is asked
        self._gcs_fn = gcs_fn
        self._packs: dict[str, ForestPack] = {}

    def seed(self, pack: ForestPack) -> None:
        """Adopt an existing pack (e.g. a loaded model artifact) as the
        cached entry for its precision."""
        self._packs[pack.precision] = pack

    def pack(self, precision: str) -> ForestPack:
        """The canonical packed tables at ``precision`` (built on first use)."""
        if precision not in self._packs:
            gcs = self._gcs_fn()
            gc = gcs if len(gcs) > 1 else gcs[0]
            self._packs[precision] = ForestPack.from_groves(gc, precision)
        return self._packs[precision]

    def get(self, layout: str, precision: str, n_shards: int = 1):
        """Table tuple for one (layout, dtype) pair — see
        :meth:`ForestPack.layout`."""
        return self.pack(precision).layout(layout, n_shards)


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------

class FogEngine:
    """Owns the Algorithm-2 state machine; backends plug in the hop update.

    gc:        GroveCollection, a tuple of them (multi-output heads with
               identical (n_groves, grove_size)), or a
               :class:`~repro.forest.pack.ForestPack` (e.g. a loaded model
               artifact — the pack is adopted into the table cache and its
               precision becomes the engine default).
    policy:    default :class:`FogPolicy` applied when ``eval`` is called
               without one.  A per-call policy REPLACES it — the traced
               knobs (threshold, hop_budget) come wholly from the policy
               you pass; only its None-valued static knobs (max_hops,
               backend, block_b, chunk_b, lazy, precision) fall back to the
               engine defaults.
    precision: default packed-table dtype ("fp32" | "bf16" | "int8") for
               policies that leave ``precision`` None; defaults to "fp32"
               (or the adopted pack's precision).
    mesh/axis: required for the ring backend; n_groves % mesh.shape[axis]
               must be 0 (each shard hosts a strided subset of groves).
    use_kernels: ring only — run the Pallas tree-traversal PE per shard.

    ``backend`` / ``block_b`` / ``chunk_b`` / ``lazy`` / ``compact`` /
    ``interpret`` kwargs remain as engine-level defaults for any policy
    that leaves them None; packed tables live in ``self.tables`` (a
    :class:`TableCache`).  ``block_b=None`` (the default) lets the fused
    backend consult the :mod:`~repro.kernels.autotune` best-config table
    per (precision, field size) — a measured winner when one is cached,
    the analytic VMEM-model seed otherwise — while per-hop backends use
    ``DEFAULT_BLOCK_B``.
    """

    def __init__(self, gc, *, backend: str = "reference",
                 block_b: int | None = None,
                 chunk_b: int | str | None = None,
                 mesh=None, axis: str = "grove", use_kernels: bool = False,
                 lazy: bool = False, policy: FogPolicy | None = None,
                 precision: str | None = None,
                 compact: bool | None = None,
                 interpret: bool | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
        self._seed_pack = gc if isinstance(gc, ForestPack) else None
        if self._seed_pack is not None:
            # groves realize lazily (to_groves dequantizes to fp32): an
            # int8 artifact serves from its packed bytes alone
            self._gcs = None
        else:
            self._gcs = (tuple(gc) if isinstance(gc, (tuple, list))
                         else (gc,))
            g0 = self._gcs[0]
            for g in self._gcs[1:]:
                if (g.n_groves, g.grove_size) != (g0.n_groves,
                                                  g0.grove_size):
                    raise ValueError(
                        "multi-output heads need identical (n_groves, "
                        f"grove_size); got {(g.n_groves, g.grove_size)} vs "
                        f"{(g0.n_groves, g0.grove_size)}")
        if precision is None:
            precision = (self._seed_pack.precision
                         if self._seed_pack is not None else "fp32")
        if precision not in PRECISIONS:
            raise ValueError(f"unknown precision {precision!r}; "
                             f"pick from {PRECISIONS}")
        self.backend = backend
        self.block_b = block_b
        self.chunk_b = chunk_b
        self.precision = precision
        self.compact = compact
        self.interpret = interpret
        self.mesh = mesh
        self.axis = axis
        self.use_kernels = use_kernels
        self.lazy = lazy
        self.policy = policy if policy is not None else FogPolicy()
        self.tables = TableCache(lambda: self.gcs)
        self._energy_models: dict[tuple[str, int], EnergyModel] = {}
        self._n_features: int | None = None
        if self._seed_pack is not None:
            self.tables.seed(self._seed_pack)
        if use_kernels and backend != "ring":
            raise ValueError("use_kernels applies to the ring backend only "
                             "(the pallas backend always runs the fused "
                             "hop-update kernel)")
        if backend == "ring":
            self._check_ring_config(lazy=lazy, chunk_b=chunk_b)

    def _check_ring_config(self, *, lazy: bool, chunk_b: int | None) -> None:
        if self.mesh is None:
            raise ValueError("ring backend needs a mesh")
        if self.multi_output:
            raise NotImplementedError("ring backend is single-output")
        if lazy or chunk_b is not None:
            raise ValueError("lazy/chunk_b are not supported on the "
                             "ring backend (the ring always runs the "
                             "fixed max_hops rotation schedule)")
        n_shards = self.mesh.shape[self.axis]
        if self.n_groves % n_shards:
            raise ValueError(
                f"n_groves={self.n_groves} not divisible by "
                f"{n_shards} ring shards")
        if self.use_kernels and self.n_groves != n_shards:
            raise ValueError(
                "use_kernels needs one grove per shard (the multi-"
                "grove gather path has no Pallas tree-traversal PE)")

    # -- properties ------------------------------------------------------
    @property
    def gcs(self) -> tuple[GroveCollection, ...]:
        """Per-head GroveCollections; for a pack-seeded engine these are
        dequantized fp32 views, realized only on first access."""
        if self._gcs is None:
            self._gcs = self._seed_pack.to_groves()
        return self._gcs

    @property
    def n_groves(self) -> int:
        if self._seed_pack is not None:
            return self._seed_pack.n_groves
        return self._gcs[0].n_groves

    @property
    def n_shards(self) -> int:
        if self.backend == "ring" and self.mesh is not None:
            return self.mesh.shape[self.axis]
        return 1

    @property
    def multi_output(self) -> bool:
        if self._seed_pack is not None:
            return self._seed_pack.n_heads > 1
        return len(self._gcs) > 1

    # -- policy resolution ----------------------------------------------
    def resolve(self, policy: FogPolicy | None = None) -> FogPolicy:
        """Fill a policy's None knobs from the engine defaults.

        ``block_b``/``compact`` may legitimately remain None after this:
        the fused evaluation path then consults the autotuner's best-config
        table for the resolved (pack, n_features) — see
        :mod:`repro.kernels.autotune` — and the per-hop backends fall back
        to ``DEFAULT_BLOCK_B``.
        """
        p = policy if policy is not None else self.policy
        return p.replace(
            max_hops=p.max_hops if p.max_hops is not None else self.n_groves,
            backend=p.backend if p.backend is not None else self.backend,
            block_b=p.block_b if p.block_b is not None else self.block_b,
            chunk_b=p.chunk_b if p.chunk_b is not None else self.chunk_b,
            lazy=p.lazy if p.lazy is not None else self.lazy,
            precision=(p.precision if p.precision is not None
                       else self.precision),
            compact=p.compact if p.compact is not None else self.compact,
            interpret=(p.interpret if p.interpret is not None
                       else self.interpret))

    # -- evaluation ------------------------------------------------------
    def eval(self, x: jax.Array, key: jax.Array, thresh=None,
             max_hops: int | None = None, *,
             policy: FogPolicy | None = None) -> FogResult:
        """GCEval(X, policy) — Algorithm 2, any backend.

        Canonical call: ``eval(x, key, policy=FogPolicy(...))``.  The
        positional ``(thresh, max_hops)`` form is deprecated.
        """
        if isinstance(thresh, FogPolicy):
            # a policy passed positionally (the decode_step_fog calling
            # convention) is the canonical form, not the deprecated one
            if policy is not None or max_hops is not None:
                raise TypeError("pass a single FogPolicy (positionally or "
                                "via policy=), without extra thresh/"
                                "max_hops arguments")
            policy, thresh = thresh, None
        if policy is not None and (thresh is not None or max_hops is not None):
            raise TypeError("pass either policy= or the deprecated "
                            "(thresh, max_hops) arguments, not both")
        if policy is None and (thresh is not None or max_hops is not None):
            warnings.warn(
                "FogEngine.eval(x, key, thresh, max_hops) is deprecated; "
                "pass eval(x, key, policy=FogPolicy(threshold=..., "
                "max_hops=...)) instead",
                DeprecationWarning, stacklevel=2)
            policy = self.policy.replace(
                threshold=thresh if thresh is not None else
                self.policy.threshold,
                max_hops=max_hops)
        p = self.resolve(policy)
        backend, max_hops = p.backend, p.max_hops
        if backend == "ring":
            self._check_ring_config(lazy=bool(p.lazy), chunk_b=p.chunk_b)
        x = jnp.asarray(x)
        B = x.shape[0]
        thresh_v = p.lane_thresholds(B)
        budget_v = p.lane_budgets(B)
        n_shards = self.mesh.shape[self.axis] if backend == "ring" else 1
        start = sample_starts(key, B, self.n_groves, n_shards)
        if backend == "ring":
            res = self._eval_ring(x, start, thresh_v, budget_v, max_hops,
                                  p.precision)
        else:
            res = self._eval_chunked(x, start, thresh_v, budget_v, max_hops,
                                     backend, p.block_b, p.chunk_b, p.lazy,
                                     p.precision, p.compact, p.interpret)
        # every evaluation path carries its own energy telemetry: callers
        # read res.energy_pj instead of re-deriving HopMeter + fog_energy
        self._n_features = int(x.shape[1])
        model = self.energy_model(p.precision, x.shape[1])
        return EvalReport(proba=res.proba, label=res.label, hops=res.hops,
                          energy_pj=model.lane_pj(res.hops), model=model)

    __call__ = eval

    def energy_model(self, precision: str | None = None,
                     n_features: int | None = None) -> EnergyModel:
        """The engine's :class:`EnergyModel` at ``precision`` (default: the
        engine default precision).  ``n_features`` defaults to the pack's
        feature-index domain only implicitly via the last evaluation; pass
        it explicitly when pricing before any eval."""
        precision = precision if precision is not None else self.precision
        if n_features is None:
            n_features = self._n_features
            if n_features is None:
                raise ValueError(
                    "n_features unknown before the first eval; pass "
                    "energy_model(precision, n_features=...) explicitly")
        key = (precision, int(n_features))
        model = self._energy_models.get(key)
        if model is None:
            model = EnergyModel.from_pack(
                self.tables.pack(precision), n_features)
            self._energy_models[key] = model
        return model

    def _resolve_chunk(self, backend, pack: ForestPack, B: int, block_b: int,
                       chunk_b, n_features: int):
        """Concrete chunk size, or None for whole-batch evaluation.

        An explicit int is respected as-is.  ``"auto"`` / None on the fused
        backend chunk ONLY when the packed tables plus the whole batch's
        VMEM footprint exceed the budget, and then size the chunk from the
        pack's per-chunk footprint (largest lane count that fits beside the
        resident tables) — an int8 pack that fits where fp32 would not
        therefore runs un-chunked.  On the per-hop backends (no resident
        tables) ``"auto"`` never chunks.
        """
        if isinstance(chunk_b, int):
            return chunk_b if B > chunk_b else None
        if backend != "fused":
            return None
        from repro.kernels.fused_fog import fit_block_b, vmem_working_set
        from repro.kernels.tree_traverse import VMEM_BUDGET
        tables = pack.layout("fused")
        ws = vmem_working_set(*tables, block_b=min(block_b, B),
                              n_features=n_features)
        if ws < VMEM_BUDGET:
            return None
        fit = fit_block_b(*tables, n_features=n_features)
        if fit < 1:
            return None   # tables alone over budget: let the kernel's
            # ValueError explain (chunking cannot shrink resident tables)
        # ws over budget forces fit < min(block_b, B): each chunk is one
        # (shrunken) kernel block via the min(block_b, cb) at the call site
        return min(fit, B)

    def _eval_chunked(self, x, start, thresh, budget, max_hops, backend,
                      block_b, chunk_b, lazy, precision, compact=None,
                      interpret=None) -> FogResult:
        B = x.shape[0]
        pack = self.tables.pack(precision)
        if block_b is None or (compact is None and backend == "fused"):
            # unset knobs resolve from the autotuner: the cached measured
            # winner for this (precision, field size), else the analytic
            # VMEM-model seed; per-hop backends just take the default tile
            if backend == "fused":
                from repro.kernels import autotune
                cfg = autotune.best_config(pack, int(x.shape[1]))
                if block_b is None:
                    block_b = cfg.block_b or DEFAULT_BLOCK_B
                if compact is None:
                    compact = cfg.compact
            elif block_b is None:
                block_b = DEFAULT_BLOCK_B
        compact = True if compact is None else compact
        cb = self._resolve_chunk(backend, pack, B, block_b, chunk_b,
                                 x.shape[1])
        if cb is None:
            return _eval_core(pack, x, start, thresh, budget, max_hops,
                              backend, min(block_b, B), lazy, compact,
                              interpret)
        pad = (-B) % cb
        if pad:  # dead-pad the tail chunk so every chunk hits one compile;
            # padded lanes are discarded, so they get thresh=-1 / budget=1 —
            # any margin clears a negative gate, so they die on hop 1 and
            # never keep an early-exit while_loop (lazy or in-kernel fused)
            # spinning after the real lanes have exited
            x = jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)])
            start = jnp.concatenate([start, jnp.zeros((pad,), start.dtype)])
            thresh = jnp.concatenate(
                [thresh, jnp.full((pad,), -1.0, thresh.dtype)])
            budget = jnp.concatenate(
                [budget, jnp.ones((pad,), budget.dtype)])
        chunks = [
            _eval_core(pack, x[i:i + cb], start[i:i + cb],
                       thresh[i:i + cb], budget[i:i + cb], max_hops,
                       backend, min(block_b, cb), lazy, compact, interpret)
            for i in range(0, B + pad, cb)
        ]
        out = jax.tree.map(lambda *ls: jnp.concatenate(ls)[:B], *chunks)
        return out

    def _eval_ring(self, x, start, thresh, budget, max_hops,
                   precision) -> FogResult:
        from repro.core.fog_ring import ring_eval
        tables = self.tables.get("ring", precision,
                                 self.mesh.shape[self.axis])
        proba, hops = ring_eval(
            self.gcs[0], x, start, thresh, max_hops, self.mesh, self.axis,
            use_kernels=self.use_kernels, tables=tables,
            hop_budget=budget)
        return FogResult(proba=proba,
                         label=jnp.argmax(proba, axis=-1).astype(jnp.int32),
                         hops=hops)


# --------------------------------------------------------------------------
# hop accounting shared with the serving path
# --------------------------------------------------------------------------

class HopMeter:
    """DEPRECATED streaming hop counter.

    Evaluation results now carry their own telemetry: ``FogEngine.eval``
    returns an :class:`EvalReport` with per-lane ``energy_pj`` and the
    pricing :class:`EnergyModel`, and the serving scheduler accumulates
    :class:`~repro.serve.scheduler.ServeStats` (fed to an
    ``EnergyGovernor`` when one is installed).  This shim keeps the old
    accounting arithmetic working for external callers.
    """

    def __init__(self) -> None:
        warnings.warn(
            "HopMeter is deprecated; read EvalReport.energy_pj from "
            "FogEngine.eval (or ContinuousBatcher.stats on the serving "
            "path) instead",
            DeprecationWarning, stacklevel=2)
        self.total_hops = 0
        self.n_events = 0

    def update(self, hops) -> None:
        h = np.asarray(hops)
        self.total_hops += int(h.sum())
        self.n_events += int(h.size)

    def reset(self) -> None:
        """Clear the accounting (e.g. between scheduler runs)."""
        self.total_hops = 0
        self.n_events = 0

    @property
    def mean_hops(self) -> float:
        return self.total_hops / max(1, self.n_events)

    def summary(self, n_groves: int) -> str:
        return (f"hops/event {self.mean_hops:.2f} "
                f"(grove fraction {self.mean_hops / max(1, n_groves):.2f}, "
                f"{self.n_events} events)")
