"""FogPolicy — the one runtime-knob contract for Algorithm-2 evaluation.

The paper's value proposition is that threshold and hop count are *run-time*
knobs trading accuracy for energy (Fig. 5).  Every such knob lives here, in
one frozen, pytree-registered dataclass, instead of being scattered across
``FogEngine.__init__`` kwargs, positional ``eval`` arguments, and private
conventions in ``budget.py`` / ``serve/scheduler.py`` / ``models/fog_exit.py``:

===============  ============================================================
knob             meaning
===============  ============================================================
``threshold``    MaxDiff confidence gate — a scalar for the whole batch, or a
                 per-lane ``[B]`` vector (mixed-QoS batches: each lane buys
                 its own accuracy/energy point)
``max_hops``     global hop cap (static loop trip count); None = n_groves
``hop_budget``   per-lane energy cap — scalar or ``[B]`` int; a lane stops
                 hopping once it has consumed its budget even if still
                 unconfident (anytime inference under an energy contract)
``backend``      "reference" | "pallas" | "fused" | "ring"; None = engine
                 default
``block_b``      pallas batch tile; None = engine default
``chunk_b``      batch chunking (VMEM bound): an int, ``"auto"`` (chunk only
                 when the packed tables + batch footprint exceed the VMEM
                 budget, sized from the pack's per-chunk footprint), or
                 None = engine default
``lazy``         early-exit while_loop vs fixed-trip scan; None = engine
                 default
``precision``    packed-table dtype: "fp32" | "bf16" | "int8" (int8 reads a
                 quarter of the table bytes per hop and fits ~4x the field
                 in VMEM); None = engine default
``compact``      fused backend: permute live lanes to a contiguous prefix
                 each hop and walk only the covering power-of-two prefix
                 (bit-identical; pays when the threshold profile exits lanes
                 early); None = engine default
``interpret``    Pallas execution mode: None derives from the runtime
                 (compiled Mosaic on a real TPU, interpreted jnp elsewhere);
                 an explicit bool overrides — debugging a Mosaic miscompile
                 with True on TPU, or asserting compiled execution
===============  ============================================================

``threshold`` and ``hop_budget`` are pytree *data* (they may be traced,
per-lane arrays); everything else is static metadata, so a ``FogPolicy``
passes through ``jax.jit`` boundaries without retriggering compilation when
only the traced knobs change.

A policy is engine-agnostic: the same object drives ``FogEngine.eval``,
``FogClassifier.predict``, the ``budget.py`` design sweeps, the
continuous-batching scheduler (which assembles per-lane vectors from
per-request policies — see :func:`assemble`), and the LM early-exit gate in
``models/fog_exit.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.pack import PRECISIONS  # noqa: E402  (re-export: the
# precision knob's domain lives with the packed-table layer)

BACKENDS = ("reference", "pallas", "fused", "ring")

# per-lane "no budget" sentinel: hops < NO_BUDGET is always true for any
# reachable hop count, so unbudgeted lanes are capped by max_hops alone
NO_BUDGET = 2**31 - 1


@partial(jax.tree_util.register_dataclass,
         data_fields=("threshold", "hop_budget"),
         meta_fields=("max_hops", "backend", "block_b", "chunk_b", "lazy",
                      "precision", "compact", "interpret"))
@dataclasses.dataclass(frozen=True)
class FogPolicy:
    """Every runtime knob of one Algorithm-2 evaluation, in one object."""

    threshold: float | jax.Array = 0.3
    max_hops: int | None = None
    hop_budget: int | jax.Array | None = None
    backend: str | None = None
    block_b: int | None = None
    chunk_b: int | str | None = None
    lazy: bool | None = None
    precision: str | None = None
    compact: bool | None = None
    interpret: bool | None = None

    def __post_init__(self):
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"pick from {BACKENDS} (or None)")
        if self.precision is not None and self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"pick from {PRECISIONS} (or None)")
        if self.max_hops is not None and self.max_hops < 1:
            raise ValueError(f"max_hops must be >= 1, got {self.max_hops}")
        if isinstance(self.chunk_b, str):
            if self.chunk_b != "auto":
                raise ValueError(f"chunk_b must be an int, 'auto' or None, "
                                 f"got {self.chunk_b!r}")
        elif self.chunk_b is not None and self.chunk_b < 1:
            raise ValueError(f"chunk_b must be >= 1, got {self.chunk_b}")
        # a lane always spends its first hop before any gate can fire, so a
        # budget below 1 is unsatisfiable; validate when concrete (traced
        # budgets inside jit are the caller's contract)
        if (self.hop_budget is not None
                and not isinstance(self.hop_budget, jax.core.Tracer)):
            if (np.asarray(self.hop_budget) < 1).any():
                raise ValueError(
                    f"hop_budget must be >= 1 everywhere (the first hop is "
                    f"always spent), got {self.hop_budget}")

    # -- convenience -----------------------------------------------------
    def replace(self, **kw) -> "FogPolicy":
        """A copy with some knobs changed (frozen dataclass idiom)."""
        return dataclasses.replace(self, **kw)

    @property
    def per_lane(self) -> bool:
        """True when threshold or hop_budget carries a per-lane vector."""
        return (getattr(self.threshold, "ndim", 0) > 0
                or getattr(self.hop_budget, "ndim", 0) > 0)

    @property
    def static_overrides(self) -> tuple[str, ...]:
        """Names of the static knobs this policy sets (non-None).  Static
        knobs select compiled programs, so contexts that share one program
        across many policies (the serving scheduler) must reject them on
        per-request policies — except ``precision``, which the scheduler
        handles by dispatching one program per precision group."""
        return tuple(k for k in ("max_hops", "backend", "block_b",
                                 "chunk_b", "lazy", "precision", "compact",
                                 "interpret")
                     if getattr(self, k) is not None)

    # -- persistence -----------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-safe scalar-knob dict (artifact persistence: FogClassifier
        saves, frontier dumps).  Per-lane policies are batch-shaped state
        and refuse to serialize."""
        if self.per_lane:
            raise ValueError(
                "cannot serialize a per-lane policy (its threshold/"
                "hop_budget vectors are batch-shaped)")

        def scalar(v):
            return v if v is None else np.asarray(v).item()

        return {"threshold": scalar(self.threshold),
                "max_hops": self.max_hops,
                "hop_budget": scalar(self.hop_budget),
                "backend": self.backend, "block_b": self.block_b,
                "chunk_b": self.chunk_b, "lazy": self.lazy,
                "precision": self.precision, "compact": self.compact,
                "interpret": self.interpret}

    @classmethod
    def from_dict(cls, d: dict) -> "FogPolicy":
        return cls(**d)

    # -- lane-vector materialization (the engines' single entry) ---------
    def lane_thresholds(self, B: int) -> jax.Array:
        """``threshold`` as a per-lane float32 ``[B]`` vector."""
        t = jnp.asarray(self.threshold, jnp.float32)
        if t.ndim == 0:
            return jnp.broadcast_to(t, (B,))
        if t.shape != (B,):
            raise ValueError(
                f"per-lane threshold has shape {t.shape}, batch is {B}")
        return t

    def lane_budgets(self, B: int) -> jax.Array:
        """``hop_budget`` as a per-lane int32 ``[B]`` vector (NO_BUDGET
        sentinel where unset — the max_hops loop bound still applies)."""
        if self.hop_budget is None:
            return jnp.full((B,), NO_BUDGET, jnp.int32)
        b = jnp.asarray(self.hop_budget, jnp.int32)
        if b.ndim == 0:
            return jnp.broadcast_to(b, (B,))
        if b.shape != (B,):
            raise ValueError(
                f"per-lane hop_budget has shape {b.shape}, batch is {B}")
        return b


# -- device-resident per-lane policy state (the packed serving path) -------
#
# The continuous batcher's packed fast path keeps each span's per-lane
# threshold / hop-budget vectors RESIDENT on the serving device and splices
# only the lanes that changed (admit / retire), instead of re-assembling and
# re-uploading full vectors every step.  Lanes without an explicit
# per-request policy carry sentinels — NaN threshold / negative budget —
# that the jitted dispatch resolves against the step's default rung
# (``jnp.where``), so a governor rung change never forces a re-splice.
# Retired lanes are stamped DEAD: threshold -1 confirms on the first hop
# (MaxDiff >= 0 > -1 always) and budget 1 hard-caps it, so empty lanes cost
# one hop and compact away instead of walking the default policy.

THRESH_DEFAULT = float("nan")
BUDGET_DEFAULT = -1
DEAD_THRESH = -1.0
DEAD_BUDGET = 1


def lane_knobs(policy: "FogPolicy | None") -> tuple[float, int]:
    """One lane's resident (threshold, hop_budget) encoding: concrete
    values for an explicit policy (an unset hop_budget is NO_BUDGET — the
    per-request contract fully overrides the default, matching
    :func:`assemble`), default sentinels otherwise."""
    if policy is None:
        return THRESH_DEFAULT, BUDGET_DEFAULT
    # float()/int() accept python numbers, np scalars and 0-d arrays
    # directly; wrapping in np.asarray costs ~2us per lane in the refill
    bud = (int(policy.hop_budget)
           if policy.hop_budget is not None else NO_BUDGET)
    return float(policy.threshold), bud


class LanePolicies:
    """Host mirror of one span's resident per-lane policy vectors, with
    dirty-lane tracking: the serving replica drains :meth:`take_dirty` into
    a donated device splice right before each dispatch.  All lanes start
    DEAD (the span serves nothing until admits arrive)."""

    def __init__(self, n: int):
        self.n = int(n)
        self.thresh = np.full((n,), DEAD_THRESH, np.float32)
        self.budget = np.full((n,), DEAD_BUDGET, np.int32)
        self._dirty = np.zeros((n,), bool)

    def stamp(self, lane: int, thr: float, bud: int) -> None:
        """Raw per-lane write (admit resolved knobs, flush re-stamps)."""
        self.thresh[lane] = thr
        self.budget[lane] = bud
        self._dirty[lane] = True

    def stamp_many(self, lanes, thr, bud) -> None:
        """Vectorized :meth:`stamp` — the hot-loop refill stages one bulk
        write per step instead of a Python call per lane."""
        self.thresh[lanes] = thr
        self.budget[lanes] = bud
        self._dirty[lanes] = True

    def admit(self, lane: int, policy: "FogPolicy | None" = None) -> None:
        self.stamp(lane, *lane_knobs(policy))

    def retire(self, lane: int) -> None:
        self.stamp(lane, DEAD_THRESH, DEAD_BUDGET)

    def retire_many(self, lanes) -> None:
        self.stamp_many(lanes, DEAD_THRESH, DEAD_BUDGET)

    @property
    def dirty(self) -> bool:
        return bool(self._dirty.any())

    def take_dirty(self):
        """``(idx, thresh, budget)`` of every lane staged since the last
        take (idx ascending for deterministic splices), clearing the
        mask."""
        idx = np.flatnonzero(self._dirty).astype(np.int32)
        self._dirty[idx] = False
        return idx, self.thresh[idx], self.budget[idx]

    def resolve(self, default: "FogPolicy") -> tuple[np.ndarray, np.ndarray]:
        """The full effective vectors under ``default`` — the host-side
        reference of what the jitted ``jnp.where`` resolution computes
        (tests + the synchronous conformance path)."""
        thr = np.where(np.isnan(self.thresh),
                       np.float32(np.asarray(default.threshold)),
                       self.thresh).astype(np.float32)
        def_bud = (int(np.asarray(default.hop_budget))
                   if default.hop_budget is not None else NO_BUDGET)
        bud = np.where(self.budget < 0, np.int32(def_bud),
                       self.budget).astype(np.int32)
        return thr, bud


def margin_backend(backend: "str | None") -> str:
    """Map an engine backend to the confidence-margin implementation the LM
    early-exit gate runs: kernel-flavored backends ("pallas", "fused") route
    the pallas top-2 kernel, everything else (incl. "ring", which has no
    meaning for the layer-grove gate) the jnp reference."""
    return "pallas" if backend in ("pallas", "fused") else "reference"


def assemble(policies: Sequence["FogPolicy | None"],
             default: "FogPolicy | None" = None) -> FogPolicy:
    """Stack per-request scalar policies into one per-lane batch policy.

    The continuous-batching scheduler holds one (possibly absent) scalar
    policy per slot; this builds the single ``FogPolicy`` whose ``threshold``
    / ``hop_budget`` are ``[n_slots]`` vectors, so one compiled decode step
    serves mixed-QoS traffic.  Static knobs (backend, block_b, ...) come
    from ``default`` — they select compiled programs and cannot vary by lane.
    """
    default = default if default is not None else FogPolicy()
    thr = [float(p.threshold if p is not None else default.threshold)
           for p in policies]
    budgets = [(p.hop_budget if p is not None else default.hop_budget)
               for p in policies]
    budget_vec = None
    if any(b is not None for b in budgets):
        budget_vec = np.asarray(
            [int(b) if b is not None else NO_BUDGET for b in budgets],
            np.int32)
    # host numpy on purpose: the vectors are assembled (and re-sliced by the
    # data-parallel dispatcher) every decode step — jnp arrays here would
    # cost a device round-trip per step before the jit boundary converts
    # them anyway
    return default.replace(threshold=np.asarray(thr, np.float32),
                           hop_budget=budget_vec)
