"""Pareto frontier over FogPolicy grids — Fig. 5 operating-point selection
as an API.

The paper's Fig. 5 picks a run-time operating point by sweeping the
threshold knob and reading accuracy against energy.  This module
generalizes that sweep to the full runtime-knob plane the engine exposes
(threshold x hop budget x precision x backend), prunes it to the Pareto
frontier (no surviving policy is beaten on BOTH accuracy and energy), and
answers the budget question directly:

    from repro.core import build_frontier, auto_policy

    frontier = build_frontier(engine, x_cal, y_cal)
    policy = auto_policy(engine, x_cal, y_cal, energy_budget_nj=2.0)

Every point is priced by the engine's own :class:`EvalReport` telemetry
(:class:`~repro.core.energy.EnergyModel` at the precision the evaluation
actually ran at), so the frontier's energy axis is the same number the
serving governor later observes — calibration and enforcement share one
model.  The frontier serializes to a JSON-safe dict (:meth:`Frontier.
to_dict`) so model artifacts can carry their calibrated operating points
(``FogClassifier.save``), and its ladder view (:meth:`Frontier.ladder`,
quality-descending) is what the serving ``EnergyGovernor`` walks when the
rolling energy estimate breaches the SLO.

By construction the frontier is *monotone*: sorted by energy ascending,
accuracy strictly increases — CI's ``energy_gate`` re-asserts this on every
benchmark dump (:meth:`Frontier.check_monotone`).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.core.policy import FogPolicy


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One calibrated operating point: a scalar FogPolicy and its measured
    accuracy / modeled energy on the calibration set."""

    policy: FogPolicy
    accuracy: float
    energy_nj: float          # mean modeled nJ / classification
    mean_hops: float

    @property
    def edp(self) -> float:
        """Energy-delay product (delay proxy: mean hops, as in budget.py)."""
        return self.energy_nj * self.mean_hops

    def __str__(self) -> str:
        # nJ everywhere: frontier logs and sweep rows share one unit
        knobs = [f"thr={float(np.asarray(self.policy.threshold).mean()):.2f}"]
        if self.policy.hop_budget is not None:
            knobs.append(f"budget={int(self.policy.hop_budget)}")
        if self.policy.precision is not None:
            knobs.append(self.policy.precision)
        return (f"[{' '.join(knobs)}] acc={self.accuracy:.3f} "
                f"E={self.energy_nj:.3f}nJ hops={self.mean_hops:.2f}")

    def to_dict(self) -> dict:
        return {"policy": self.policy.to_dict(),
                "accuracy": float(self.accuracy),
                "energy_nj": float(self.energy_nj),
                "mean_hops": float(self.mean_hops)}

    @classmethod
    def from_dict(cls, d: dict) -> "FrontierPoint":
        return cls(policy=FogPolicy.from_dict(d["policy"]),
                   accuracy=d["accuracy"], energy_nj=d["energy_nj"],
                   mean_hops=d["mean_hops"])


class Frontier:
    """The Pareto-optimal subset of a calibrated policy sweep.

    Points are stored energy-ascending; along that order accuracy strictly
    increases (dominated and duplicate-accuracy points are pruned), so
    ``under_budget`` is a reverse scan and ``ladder`` is just the reversed
    point list.
    """

    def __init__(self, points: Sequence[FrontierPoint]):
        pts = sorted(points, key=lambda p: (p.energy_nj, -p.accuracy))
        frontier: list[FrontierPoint] = []
        for p in pts:
            if not frontier or p.accuracy > frontier[-1].accuracy:
                frontier.append(p)
        self.points: tuple[FrontierPoint, ...] = tuple(frontier)
        if not self.points:
            raise ValueError("cannot build a frontier from zero points")

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    def __str__(self) -> str:
        return "\n".join(str(p) for p in self.points)

    def under_budget(self, energy_budget_nj: float) -> FrontierPoint:
        """Highest-accuracy point with energy <= budget.  Raises ValueError
        when even the cheapest point exceeds the budget — an unmeetable SLO
        should fail loudly at calibration, not silently overspend."""
        ok = [p for p in self.points if p.energy_nj <= energy_budget_nj]
        if not ok:
            raise ValueError(
                f"energy budget {energy_budget_nj:.3f} nJ is below the "
                f"cheapest frontier point ({self.points[0].energy_nj:.3f} "
                f"nJ, {self.points[0]})")
        return ok[-1]          # energy-ascending == accuracy-ascending

    def ladder(self) -> list[FrontierPoint]:
        """Quality-descending walk for the serving governor: rung 0 is the
        most accurate (most expensive) point, the last rung the cheapest."""
        return list(reversed(self.points))

    def check_monotone(self) -> None:
        """Assert the frontier invariant: no point has both lower accuracy
        and higher energy than a neighbor (CI's ``energy_gate``)."""
        for a, b in zip(self.points, self.points[1:]):
            if not (b.energy_nj >= a.energy_nj and b.accuracy > a.accuracy):
                raise AssertionError(
                    f"frontier not monotone: {b} does not improve on {a}")

    def to_dict(self) -> dict:
        return {"points": [p.to_dict() for p in self.points]}

    @classmethod
    def from_dict(cls, d: dict) -> "Frontier":
        """Restore a stored frontier VERBATIM — no re-sorting or
        re-pruning.  A persisted dump must stay checkable: re-pruning on
        load would silently repair a regressed builder's output and make
        ``check_monotone`` (CI's energy_gate) unable to fail."""
        points = tuple(FrontierPoint.from_dict(p) for p in d["points"])
        if not points:
            raise ValueError("cannot restore a frontier with zero points")
        # under_budget's "last fitting point is the best" scan needs the
        # stored order to be energy-ascending; a corrupted or mis-ordered
        # dump must fail at load, not resolve budgets to the wrong point.
        # (Accuracy monotonicity is deliberately NOT repaired or enforced
        # here — that is check_monotone's job, i.e. CI's energy_gate.)
        energies = [p.energy_nj for p in points]
        if any(b < a for a, b in zip(energies, energies[1:])):
            raise ValueError("frontier dump is not energy-sorted")
        f = cls.__new__(cls)
        f.points = points
        return f


# ------------------------------------------------------ point selection ----
# The generic selection rules shared by budget.py's design sweeps
# (TopologyPoint lists) and frontier sweeps (FrontierPoint lists): any
# object with .accuracy, .edp and a threshold (own attribute or on .policy)
# qualifies.

def _threshold_of(p) -> float:
    t = getattr(p, "threshold", None)
    if t is None:
        t = np.asarray(p.policy.threshold).mean()
    return float(t)


def select_min_edp(points: Sequence, accuracy_slack: float = 0.02):
    """Min-EDP point whose accuracy is within ``slack`` of the best (the
    paper's Fig. 4 design pick)."""
    best_acc = max(p.accuracy for p in points)
    ok = [p for p in points if p.accuracy >= best_acc - accuracy_slack]
    return min(ok, key=lambda p: p.edp)


def find_opt_threshold(points: Sequence, tolerance: float = 0.005):
    """FoG_opt: the smallest threshold above which accuracy stops
    increasing (paper §4.2)."""
    pts = sorted(points, key=_threshold_of)
    best_acc = max(p.accuracy for p in pts)
    for p in pts:
        if p.accuracy >= best_acc - tolerance:
            return p
    return pts[-1]


# ---------------------------------------------------------------- sweeps ----
def default_grid(thresholds: Sequence[float] | None = None,
                 hop_budgets: Sequence[int | None] | None = None,
                 precisions: Sequence[str | None] | None = None,
                 backends: Sequence[str | None] | None = None,
                 base: FogPolicy | None = None) -> list[FogPolicy]:
    """The default calibration grid: threshold x hop budget x precision x
    backend, stamped onto ``base``.  An axis left None inherits the base
    policy's own knob (so a facade-configured hop budget or backend
    survives calibration); precision additionally sweeps "int8" — the
    paper's cheap-table operating points — unless overridden."""
    base = base if base is not None else FogPolicy()
    if thresholds is None:
        thresholds = (0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.1)
    if hop_budgets is None:
        hop_budgets = (base.hop_budget,)
    if precisions is None:
        precisions = tuple(dict.fromkeys((base.precision, "int8")))
    if backends is None:
        backends = (base.backend,)
    return [base.replace(threshold=float(t), hop_budget=hb,
                         precision=pr, backend=be)
            for pr in precisions for be in backends
            for hb in hop_budgets for t in thresholds]


def sweep_policies(engine, x_cal, y_cal,
                   policies: Iterable[FogPolicy],
                   key: jax.Array | None = None) -> list[FrontierPoint]:
    """Price a policy grid on calibration data: one engine evaluation per
    policy, accuracy from labels, energy from the EvalReport's own model."""
    import jax.numpy as jnp
    if key is None:
        key = jax.random.key(0)
    y = np.asarray(y_cal)
    x = jnp.asarray(x_cal)
    points = []
    seen: set = set()
    for pol in policies:
        # stamp the RESOLVED precision on the stored policy: a
        # precision=None point calibrated on today's engine default would
        # silently execute at a different dtype after the frontier travels
        # in an artifact (or the default changes via quantize()) — the
        # stored accuracy/energy must keep describing what runs
        pol = pol.replace(precision=engine.resolve(pol).precision)
        if not pol.per_lane:
            # resolution can collapse grid points (precision=None on an
            # int8-default engine duplicates the explicit int8 axis):
            # don't pay a full calibration eval twice for one policy
            k = tuple(sorted(pol.to_dict().items()))
            if k in seen:
                continue
            seen.add(k)
        res = engine.eval(x, key, policy=pol)
        rep = res.energy_report()
        points.append(FrontierPoint(
            policy=pol,
            accuracy=float((np.asarray(res.label) == y).mean()),
            energy_nj=rep.per_example_nj,
            mean_hops=float(np.asarray(res.hops).mean())))
    return points


def build_frontier(engine, x_cal, y_cal,
                   policies: Iterable[FogPolicy] | None = None,
                   key: jax.Array | None = None) -> Frontier:
    """Sweep (default: :func:`default_grid`) and prune to the frontier."""
    if policies is None:
        policies = default_grid()
    return Frontier(sweep_policies(engine, x_cal, y_cal, policies, key))


def auto_policy(engine, x_cal, y_cal, energy_budget_nj: float,
                policies: Iterable[FogPolicy] | None = None,
                key: jax.Array | None = None) -> FogPolicy:
    """The paper's Fig. 5 operating-point selection as one call: the
    highest-accuracy FogPolicy whose calibrated energy fits the budget."""
    frontier = build_frontier(engine, x_cal, y_cal, policies, key)
    return frontier.under_budget(energy_budget_nj).policy
