"""Sharded, fault-tolerant checkpointing (no orbax in the container).

Layout:
  <dir>/step_<N>/shard_<H>.npz     one npz per host: its addressable shards
  <dir>/step_<N>/meta.json         pytree structure, global shapes, shardings
  <dir>/step_<N>/COMMIT            written LAST -> atomic visibility

Fault-tolerance properties:
  * atomicity: a step directory without COMMIT is garbage-collected on
    restore (a writer died mid-write); restore picks the newest committed
    step, so a crash can never leave training unable to restart.
  * async: save() can run on a background thread (snapshot is taken
    synchronously via device_get — cheap relative to the write)
  * elasticity: shards are stored with their *logical* global shapes and
    PartitionSpecs, so a checkpoint written on one mesh restores onto any
    mesh whose axes divide the same global shapes (re-mesh on shrink/grow).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save(step: int, tree, directory: str | os.PathLike,
         *, async_write: bool = False, keep: int = 3) -> threading.Thread | None:
    """Write a committed checkpoint for ``step``.  Returns the writer thread
    if async."""
    directory = Path(directory)
    step_dir = directory / f"step_{step:08d}"
    tmp_dir = directory / f".tmp_step_{step:08d}"
    items, _ = _flatten(tree)
    # snapshot to host memory NOW (donation/mutation safety), write later
    host = {k: np.asarray(jax.device_get(v)) for k, v in items}

    def write():
        if tmp_dir.exists():
            shutil.rmtree(tmp_dir)
        tmp_dir.mkdir(parents=True)
        pid = jax.process_index()
        np.savez(tmp_dir / f"shard_{pid}.npz", **host)
        meta = {"step": step, "keys": sorted(host),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
                "time": time.time()}
        (tmp_dir / "meta.json").write_text(json.dumps(meta))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.rename(step_dir)
        (step_dir / "COMMIT").touch()          # commit marker LAST
        _gc(directory, keep)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(directory: Path, keep: int) -> None:
    steps = sorted(d for d in directory.glob("step_*")
                   if (d / "COMMIT").exists())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
    for d in directory.glob(".tmp_step_*"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = Path(directory)
    steps = [int(d.name.split("_")[1]) for d in directory.glob("step_*")
             if (d / "COMMIT").exists()]
    return max(steps) if steps else None


def restore(tree_like, directory: str | os.PathLike,
            step: int | None = None, *, shardings=None):
    """Restore into the structure of ``tree_like`` (a pytree of arrays or
    ShapeDtypeStructs).  Uncommitted step dirs are removed (crash cleanup).
    """
    directory = Path(directory)
    # crash cleanup: drop uncommitted writes
    for d in directory.glob("step_*"):
        if not (d / "COMMIT").exists():
            shutil.rmtree(d, ignore_errors=True)
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    step_dir = directory / f"step_{step:08d}"
    data = np.load(step_dir / f"shard_{jax.process_index()}.npz")
    items, treedef = _flatten(tree_like)
    leaves = []
    for key, like in items:
        arr = data[key]
        want = tuple(like.shape)
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
