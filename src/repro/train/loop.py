"""Distributed train-step factory (pjit) + per-shape input specs.

``make_train_step(cfg, mesh)`` builds the jitted step with full sharding
annotations: params/optimizer sharded per launch.sharding rules, batch over
the dp axes, gradients clipped + AdamW, optional int8 error-feedback
compression modeling the cross-pod wire format.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.launch.mesh import dp_axes
from repro.launch.sharding import batch_spec, param_shardings
from repro.models import transformer as T
from repro.optim import adamw, clip_by_global_norm, linear_warmup_cosine
from repro.optim.compression import ef_compress_grads


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def input_specs(cfg: ArchConfig, shape: str, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    sp = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if sp.kind == "train":
        if cfg.frontend:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if sp.kind == "prefill":
        if cfg.frontend:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a seq_len cache
    if cfg.frontend:
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dtype),
                "length": jax.ShapeDtypeStruct((), i32)}
    return {"token": jax.ShapeDtypeStruct((B,), i32),
            "length": jax.ShapeDtypeStruct((), i32)}


def shape_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape == "long_500k" and not cfg.long_context:
        return False, ("full-attention arch: 512k-token decode cell skipped "
                       "by design (see DESIGN.md §5)")
    return True, ""


def make_train_step(cfg: ArchConfig, mesh, *, lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    clip_norm: float = 1.0, compress_pod_grads: bool = False,
                    param_dtype=jnp.bfloat16, donate: bool = True):
    """Returns (train_step, params_shardings, opt_shardings, batch_shardings).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    from repro.optim.optim import OptState

    init_opt, update_opt = adamw(
        lr=linear_warmup_cosine(lr, warmup, total_steps),
        b1=0.9, b2=0.95, weight_decay=0.1)

    params_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, param_dtype), jax.random.key(0))
    p_specs = param_shardings(cfg, mesh, params_shape)
    o_specs = OptState(step=P(), mu=p_specs, nu=p_specs)

    def loss_fn(params, batch):
        return T.lm_loss(params, cfg, tokens=batch.get("tokens"),
                         labels=batch["labels"], embeds=batch.get("embeds"),
                         remat=True)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        if compress_pod_grads:
            # int8 + error feedback models the cross-pod wire format; the
            # EF residual is recomputed per-step (stateless approximation
            # of the EF buffer: residual feeds the *same* step's update)
            grads, _ = ef_compress_grads(grads, None)
        params, opt_state = update_opt(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    jitted = jax.jit(
        train_step,
        # batch spec inferred on call
        in_shardings=compat.jit_shardings(mesh, (p_specs, o_specs, None)),
        out_shardings=compat.jit_shardings(mesh, (p_specs, o_specs, None)),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, p_specs, o_specs, init_opt


def make_train_step_lowerable(cfg: ArchConfig, mesh, shape: str,
                              accum_steps: int = 1, **kw):
    """Fully-specified jitted step + abstract inputs, ready to .lower().

    ``accum_steps > 1`` = gradient accumulation: the global batch is split
    into k microbatches scanned sequentially; activation working set (the
    dominant temp-memory term for the >300B archs) shrinks ~k x at the
    cost of k x more weight re-reads (FSDP gathers per microbatch).
    """
    sp = SHAPES[shape]
    assert sp.kind == "train", shape
    assert sp.global_batch % accum_steps == 0, (shape, accum_steps)
    init_opt, update_opt = adamw(
        lr=linear_warmup_cosine(kw.get("lr", 3e-4), 100, 10000),
        b1=0.9, b2=0.95, weight_decay=0.1)

    params_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, kw.get("param_dtype", jnp.bfloat16)),
        jax.random.key(0))
    from repro.optim.optim import OptState
    p_specs = param_shardings(cfg, mesh, params_shape)
    o_specs = OptState(step=P(), mu=p_specs, nu=p_specs)
    opt_shape = jax.eval_shape(init_opt, params_shape)

    batch_shape = input_specs(cfg, shape)
    b_specs = {k: P(dp_axes(mesh), *([None] * (len(v.shape) - 1)))
               for k, v in batch_shape.items()}

    def loss_fn(params, batch):
        return T.lm_loss(params, cfg, tokens=batch.get("tokens"),
                         labels=batch["labels"], embeds=batch.get("embeds"),
                         remat=True)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grad_acc = carry
            # microbatch keeps its batch-over-dp sharding
            mb = {k: T.constrain_batch(v) for k, v in mb.items()}
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree.map(lambda a, b: a + b, grad_acc, g)), None

        micro_batches = {
            k: v.reshape(accum_steps, v.shape[0] // accum_steps, *v.shape[1:])
            for k, v in batch.items()}
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.zeros(()), zero), micro_batches)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, kw.get("clip_norm", 1.0))
        if kw.get("compress_pod_grads", False):
            grads, _ = ef_compress_grads(grads, None)
        params, opt_state = update_opt(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    jitted = jax.jit(
        train_step,
        in_shardings=compat.jit_shardings(mesh, (p_specs, o_specs, b_specs)),
        out_shardings=compat.jit_shardings(mesh, (p_specs, o_specs, None)),
    )
    return jitted, (params_shape, opt_shape, batch_shape)
