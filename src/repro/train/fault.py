"""Fault tolerance: heartbeats, failure detection, restart, elastic re-mesh.

On a real multi-pod deployment each host runs a ``Heartbeat`` writer and the
coordinator a ``FleetMonitor``; here the same logic is exercised in-process
by the tests (the container is one host).  The contract:

  * every host touches  <dir>/hb_<host>.json  every ``interval`` seconds
  * a host is DEAD if its heartbeat is older than ``timeout``
  * on death the monitor returns a RestartPlan: newest committed checkpoint
    + the surviving host set; launch/train.py re-enters its main loop with
    a mesh rebuilt from the surviving hosts (elastic: data-parallel extent
    shrinks, model extent must stay — enforced here)
  * stragglers (heartbeat fresh but step counter stale vs the fleet median)
    are reported for eviction — the FoG ring tolerates them natively
    (neighbor-only dependency); the training all-reduce does not.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class HostState:
    host: str
    last_beat: float
    step: int


class Heartbeat:
    def __init__(self, directory: str, host: str):
        self.path = Path(directory) / f"hb_{host}.json"
        self.host = host

    def beat(self, step: int) -> None:
        self.path.write_text(json.dumps(
            {"host": self.host, "time": time.time(), "step": step}))


@dataclasses.dataclass
class RestartPlan:
    restore_step: int | None
    alive_hosts: list[str]
    dead_hosts: list[str]
    stragglers: list[str]
    new_data_extent: int


class FleetMonitor:
    """Coordinator-side failure detection + elastic restart planning."""

    def __init__(self, directory: str, *, timeout: float = 60.0,
                 straggler_factor: float = 0.5):
        self.dir = Path(directory)
        self.timeout = timeout
        self.straggler_factor = straggler_factor

    def poll(self) -> list[HostState]:
        out = []
        for p in self.dir.glob("hb_*.json"):
            try:
                d = json.loads(p.read_text())
                out.append(HostState(d["host"], d["time"], d["step"]))
            except (json.JSONDecodeError, KeyError):
                continue   # torn write: treat as missing this round
        return out

    def plan(self, *, now: float | None = None,
             model_extent: int = 16, chips_per_host: int = 4) -> RestartPlan:
        now = time.time() if now is None else now
        hosts = self.poll()
        alive = [h for h in hosts if now - h.last_beat <= self.timeout]
        dead = [h for h in hosts if now - h.last_beat > self.timeout]
        steps = sorted(h.step for h in alive)
        median = steps[len(steps) // 2] if steps else 0
        stragglers = [h.host for h in alive
                      if median > 10 and h.step < median * self.straggler_factor]
        # elastic: the data axis shrinks to what the alive hosts support;
        # the model axis is fixed by the sharded parameter layout
        total_chips = len(alive) * chips_per_host
        new_data = max(1, total_chips // model_extent)
        return RestartPlan(
            restore_step=ckpt.latest_step(self.dir),
            alive_hosts=sorted(h.host for h in alive),
            dead_hosts=sorted(h.host for h in dead),
            stragglers=stragglers,
            new_data_extent=new_data,
        )


def deterministic_data_key(base_seed: int, step: int) -> int:
    """Step-indexed PRNG stream: after restart the data order at step N is
    identical regardless of crash history."""
    return (base_seed * 1_000_003 + step) % (2**31 - 1)
