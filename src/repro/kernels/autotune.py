"""Measured autotuner for the fused FoG kernel and the trainer histogram.

``block_b`` (batch lanes per launch block) and ``compact`` (live-lane
compaction) are the two knobs that set the fused kernel's VMEM traffic and
its per-hop work, and their best values move with the pack: int8 tables
leave ~3x more VMEM for lane state than fp32, a wide field (many groves x
deep trees) squeezes the batch block down, and compaction only pays when
the workload's early-exit profile actually empties lanes.  Hand-picking
one constant (the historical ``block_b=128``/``256``) therefore leaves
latency on the table somewhere in the (precision, field size) plane.

This module keeps a best-config table keyed by the pack signature:

    key = (precision, n_heads, n_groves, grove_size, depth, n_classes,
           n_features)

``best_config(key)`` is what the engine consults when a policy leaves
``block_b`` unset: a measured entry wins; otherwise the ANALYTIC SEED —
derived from the (fixed, 8-aligned) ``fit_block_b`` VMEM model — answers
immediately, so an untuned engine never stalls to benchmark.  ``tune()``
runs the measured sweep (halving ladder of aligned block sizes from the
VMEM fit, x compaction on/off, best-of-k timing on representative inputs)
and caches the winner; set ``FOG_AUTOTUNE_CACHE=/path/file.json`` to
persist winners across processes (loaded lazily, written atomically), the
re-tune story for new hardware.

The device forest trainer shares the table (and the cache file).  Its
level-wise histogram kernel has three tile knobs (``block_n`` batch lanes,
``block_r`` resident rows, ``block_f`` feature columns) plus a path
crossover ``matmul_max_r``: below that many (node, class) rows the Pallas
one-hot matmul kernel wins, above it the XLA scatter path does (deep
levels spread few samples over many nodes, where a dense one-hot wastes
its width).  Histogram entries are keyed by the trainer signature

    key = ("hist", n_trees, depth, n_features, n_bins, n_classes)

``best_hist_config(...)`` mirrors ``best_config``: a measured/cached entry
wins, else an analytic seed (scatter-everywhere on interpreted backends,
matmul for the top levels on a compiled TPU); ``tune_histogram()`` measures
both paths per level size and the block_n ladder on synthetic shapes.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.fused_fog import LANE_ALIGN, fit_block_b

CACHE_ENV = "FOG_AUTOTUNE_CACHE"

# analytic fallback cap: past ~256 lanes the walk's gather width saturates
# the VPU and bigger blocks only grow VMEM pressure
SEED_CAP = 256

# in-process best-config table: key tuple -> TuneResult
_CACHE: dict[tuple, "TuneResult"] = {}
_LOADED_FROM: str | None = None


@dataclass(frozen=True)
class TuneResult:
    """One winning fused-kernel configuration."""
    block_b: int
    compact: bool
    measured_s: float | None = None   # None: analytic seed, never measured
    source: str = "analytic"          # "analytic" | "measured" | "cache-file"

    def to_dict(self) -> dict:
        return {"block_b": self.block_b, "compact": self.compact,
                "measured_s": self.measured_s, "source": self.source}


@dataclass(frozen=True)
class HistConfig:
    """One winning trainer-histogram configuration (tile sizes + path
    crossover; see kernels/histogram.py)."""
    block_n: int
    block_r: int
    block_f: int
    matmul_max_r: int                 # Pallas one-hot path while R <= this
    measured_s: float | None = None   # None: analytic seed, never measured
    source: str = "analytic"          # "analytic" | "measured" | "cache-file"

    def to_dict(self) -> dict:
        return {"block_n": self.block_n, "block_r": self.block_r,
                "block_f": self.block_f, "matmul_max_r": self.matmul_max_r,
                "measured_s": self.measured_s, "source": self.source}


def pack_key(pack, n_features: int) -> tuple:
    """The (precision, field size) signature a tuned config is valid for."""
    return (pack.precision, pack.n_heads, pack.n_groves, pack.grove_size,
            pack.depth, pack.n_classes, int(n_features))


def hist_key(n_trees: int, depth: int, n_features: int, n_bins: int,
             n_classes: int) -> tuple:
    """The trainer signature a tuned histogram config is valid for."""
    return ("hist", int(n_trees), int(depth), int(n_features), int(n_bins),
            int(n_classes))


def _key_str(key: tuple) -> str:
    return "/".join(str(k) for k in key)


def analytic_block_b(pack, n_features: int) -> int:
    """Seed config from the VMEM model alone: the largest aligned block
    that fits beside the packed tables, capped at SEED_CAP (floor of
    LANE_ALIGN so a viable pack always gets a runnable block)."""
    tables = pack.layout("fused")
    fit = fit_block_b(*tables, n_features=n_features)
    return max(LANE_ALIGN, min(fit, SEED_CAP)) if fit > 0 else 0


def candidate_blocks(pack, n_features: int, batch_b: int | None = None) -> list[int]:
    """The measured sweep's block_b ladder: the VMEM fit (aligned), then
    halvings down to LANE_ALIGN — every size that changes the grid."""
    fit = fit_block_b(*pack.layout("fused"), n_features=n_features)
    if fit <= 0:
        return []
    top = min(fit, 1024)
    if batch_b is not None:
        top = min(top, batch_b + (-batch_b) % LANE_ALIGN)
    top -= top % LANE_ALIGN
    out = []
    b = max(top, LANE_ALIGN)
    while b >= LANE_ALIGN:
        out.append(b)
        b //= 2
        b -= b % LANE_ALIGN
    return out or [LANE_ALIGN]


def best_config(pack, n_features: int) -> TuneResult:
    """The config the engine uses when ``block_b`` is unset: the cached
    measured winner for this pack signature, else the analytic seed."""
    _load_cache_file()
    key = pack_key(pack, n_features)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    return TuneResult(block_b=analytic_block_b(pack, n_features),
                      compact=True, source="analytic")


def tune(pack, x, start, thresh, budget, *, max_hops: int,
         repeats: int = 3, persist: bool = True,
         blocks: list[int] | None = None) -> TuneResult:
    """Measured sweep over block_b candidates x compaction on/off.

    ``x/start/thresh/budget`` should be representative of serving traffic —
    the winner is workload-dependent (compaction pays exactly when this
    threshold profile exits lanes early).  Best-of-``repeats`` wall time
    per candidate, winner cached under the pack signature (and persisted
    to ``$FOG_AUTOTUNE_CACHE`` when set and ``persist``).  ``blocks``
    narrows the sweep to an explicit block_b ladder (VMEM-infeasible
    entries are dropped); default is the full halving ladder from the
    VMEM fit."""
    from repro.kernels import ops

    key = pack_key(pack, int(x.shape[1]))
    tables = pack.layout("fused")
    feasible = candidate_blocks(pack, int(x.shape[1]), int(x.shape[0]))
    if blocks is None:
        blocks = feasible
    else:
        cap = max(feasible) if feasible else 0
        blocks = [b for b in blocks if LANE_ALIGN <= b <= cap] or feasible
    if not blocks:
        raise ValueError(
            f"pack {key} has no VMEM-feasible block_b; shrink the field or "
            "use precision=\"int8\"")

    best: TuneResult | None = None
    for block_b in blocks:
        for compact in (False, True):
            def run():
                p, h = ops.fused_fog(*tables[:3], x, start, thresh, budget,
                                     *tables[3:], max_hops=max_hops,
                                     block_b=block_b, compact=compact)
                jax.block_until_ready((p, h))
            run()                                  # compile / warm
            t = min(_timed(run) for _ in range(repeats))
            if best is None or t < best.measured_s:
                best = TuneResult(block_b=block_b, compact=compact,
                                  measured_s=t, source="measured")
    _CACHE[key] = best
    if persist:
        _save_cache_file()
    return best


def analytic_hist_config(n_trees: int, depth: int, n_features: int,
                         n_bins: int, n_classes: int) -> HistConfig:
    """Seed histogram config, answered without benchmarking.

    Tile sizes come straight from the kernel's VMEM model; the path
    crossover depends on the backend: a compiled TPU keeps the one-hot
    matmul (MXU work against a VMEM-resident block) while the row count is
    modest, whereas an interpreted backend pays the matmul's full
    ``N*R*F*bins`` flop bill on the host VPU-less path, where the XLA
    scatter always wins — so the interpreted seed is scatter-everywhere.
    """
    from repro.kernels import histogram
    from repro.kernels.tree_traverse import resolve_interpret
    block_f = histogram.default_block_f(n_features, n_bins)
    matmul_max_r = 0 if resolve_interpret(None) else 2048
    return HistConfig(block_n=histogram.BLOCK_N, block_r=histogram.BLOCK_R,
                      block_f=block_f, matmul_max_r=matmul_max_r,
                      source="analytic")


def best_hist_config(n_trees: int, depth: int, n_features: int, n_bins: int,
                     n_classes: int) -> HistConfig:
    """The config the device trainer uses: the cached measured winner for
    this trainer signature, else the analytic seed."""
    _load_cache_file()
    key = hist_key(n_trees, depth, n_features, n_bins, n_classes)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    return analytic_hist_config(n_trees, depth, n_features, n_bins,
                                n_classes)


# skip timing the one-hot matmul path once its modeled flops pass this
# (interpreted hosts would stall for minutes measuring a foregone loss)
_HIST_TUNE_FLOP_CAP = 2e9


def tune_histogram(n_trees: int, depth: int, n_features: int, n_bins: int,
                   n_classes: int, *, n_samples: int, seed: int = 0,
                   repeats: int = 3, persist: bool = True,
                   blocks: tuple[int, ...] = (512, 1024, 2048)) -> HistConfig:
    """Measured histogram sweep on synthetic level shapes.

    Times the Pallas one-hot kernel over the ``blocks`` batch-tile ladder
    at a shallow probe level, then walks the levels deepest-rows-first
    timing Pallas vs scatter per row count; ``matmul_max_r`` is the
    largest row count where the kernel still wins (it loses monotonically
    as rows grow, so the walk stops at the first loss).  On an interpreted
    backend the Pallas side is never timed (interpret-mode matmuls lose by
    construction and cost minutes to prove it); only the segment-sum
    levels are measured, with ``matmul_max_r = 0``.  Winner cached under
    the trainer signature (persisted to ``$FOG_AUTOTUNE_CACHE`` when set
    and ``persist``).
    """
    from repro.kernels import histogram
    from repro.kernels.tree_traverse import resolve_interpret

    key = hist_key(n_trees, depth, n_features, n_bins, n_classes)
    seed_cfg = analytic_hist_config(n_trees, depth, n_features, n_bins,
                                    n_classes)
    interp = resolve_interpret(None)
    k = jax.random.key(seed)
    ky, kb, kw = jax.random.split(k, 3)
    y = jax.random.randint(ky, (n_samples,), 0, n_classes)
    bins = jax.random.randint(kb, (n_samples, n_features), 0, n_bins)
    w = jnp.ones((n_trees, n_samples), jnp.float32)

    def node_at(level: int):
        return jax.random.randint(kw, (n_trees, n_samples), 0, 1 << level)

    def timed(fn) -> float:
        out = fn()
        jax.block_until_ready(out)      # compile / warm
        return min(_timed(lambda: jax.block_until_ready(fn()))
                   for _ in range(repeats))

    # block_n ladder at a shallow probe level (cheap enough to matmul)
    best_bn, best_t = seed_cfg.block_n, None
    if not interp:
        probe = min(2, depth - 1)
        node = node_at(probe)
        for bn in blocks:
            t = timed(lambda: histogram.histogram_level_pallas(
                node, y, w, bins, n_nodes=1 << probe, n_bins=n_bins,
                n_classes=n_classes, block_n=bn, block_r=seed_cfg.block_r,
                block_f=seed_cfg.block_f))
            if best_t is None or t < best_t:
                best_bn, best_t = bn, t

    # per-level crossover: largest R where the Pallas path still wins.
    # The win region must stay contiguous from R=0 (the dispatcher tests
    # R <= matmul_max_r), so growth stops at the first level Pallas loses.
    matmul_max_r, total = 0, 0.0
    pallas_alive = not interp
    for level in range(depth):
        r = (1 << level) * n_classes
        flops = n_samples * r * n_features * n_bins
        node = node_at(level)
        kw_args = dict(n_nodes=1 << level, n_bins=n_bins,
                       n_classes=n_classes)
        t_sc = timed(lambda: histogram.histogram_level_scatter(
            node, y, w, bins, **kw_args))
        if not pallas_alive or flops > _HIST_TUNE_FLOP_CAP:
            total += t_sc
            continue
        t_pl = timed(lambda: histogram.histogram_level_pallas(
            node, y, w, bins, block_n=best_bn, block_r=seed_cfg.block_r,
            block_f=seed_cfg.block_f, **kw_args))
        total += min(t_pl, t_sc)
        if t_pl < t_sc:
            matmul_max_r = r
        else:
            pallas_alive = False

    best = HistConfig(block_n=best_bn, block_r=seed_cfg.block_r,
                      block_f=seed_cfg.block_f, matmul_max_r=matmul_max_r,
                      measured_s=total, source="measured")
    _CACHE[key] = best
    if persist:
        _save_cache_file()
    return best


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def clear_cache() -> None:
    """Drop every in-process entry (tests; does not touch the cache file)."""
    global _LOADED_FROM
    _CACHE.clear()
    _LOADED_FROM = None


def _load_cache_file() -> None:
    global _LOADED_FROM
    path = os.environ.get(CACHE_ENV)
    if not path or _LOADED_FROM == path:
        return
    _LOADED_FROM = path
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return
    for kstr, cfg in raw.items():
        key = tuple(p if i == 0 else int(p)
                    for i, p in enumerate(kstr.split("/")))
        if key in _CACHE:       # fresher in-process measurements win
            continue
        if key[0] == "hist":
            _CACHE[key] = HistConfig(block_n=int(cfg["block_n"]),
                                     block_r=int(cfg["block_r"]),
                                     block_f=int(cfg["block_f"]),
                                     matmul_max_r=int(cfg["matmul_max_r"]),
                                     measured_s=cfg.get("measured_s"),
                                     source="cache-file")
        else:
            _CACHE[key] = TuneResult(block_b=int(cfg["block_b"]),
                                     compact=bool(cfg["compact"]),
                                     measured_s=cfg.get("measured_s"),
                                     source="cache-file")


def _save_cache_file() -> None:
    path = os.environ.get(CACHE_ENV)
    if not path:
        return
    payload = {_key_str(k): v.to_dict() for k, v in _CACHE.items()}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
