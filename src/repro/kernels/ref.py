"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dequantize_tables(threshold: jax.Array, leaf: jax.Array,
                      thr_scale: jax.Array | None = None,
                      leaf_scale: jax.Array | None = None):
    """Packed table values -> the fp32 values every backend compares against.

    The one dequantization rule shared by the jnp reference paths and the
    Pallas kernels (which apply it to *gathered* elements in-register):
    fp32 passes through, bf16 upcasts exactly, int8 multiplies by its
    per-tree fp32 scale.  Scales broadcast over the trailing node/leaf axes
    (``[..., k, 1]`` against ``[..., k, N]``).
    """
    quantized = threshold.dtype == jnp.int8
    thr = threshold.astype(jnp.float32)
    lf = leaf.astype(jnp.float32)
    if quantized:
        if thr_scale is None or leaf_scale is None:
            raise ValueError("int8 tables need thr_scale/leaf_scale")
        thr = thr * thr_scale
        # ±127 are the padding sentinels (±inf thresholds, "always go
        # left" complete-tree nodes) — restore them exactly
        thr = jnp.where(threshold == 127, jnp.inf, thr)
        thr = jnp.where(threshold == -127, -jnp.inf, thr)
        lf = lf * leaf_scale
    return thr, lf


def tree_traverse_ref(feature: jax.Array, threshold: jax.Array,
                      leaf: jax.Array, x: jax.Array,
                      thr_scale: jax.Array | None = None,
                      leaf_scale: jax.Array | None = None) -> jax.Array:
    """Grove bundle evaluation: mean leaf distribution over trees.

    feature   int32           [t, 2**d - 1]
    threshold fp32|bf16|int8  [t, 2**d - 1]
    leaf      fp32|bf16|int8  [t, 2**d, C]
    x         float32         [B, F]
    returns   float32         [B, C]

    Packed (bf16/int8) tables are dequantized up front — the oracle for the
    Pallas kernel's in-register dequantize of gathered values (elementwise,
    so the fp32 compare/accumulate sees bitwise-identical numbers).
    """
    threshold, leaf = dequantize_tables(threshold, leaf, thr_scale,
                                        leaf_scale)
    depth = int(np.log2(leaf.shape[1]) + 0.5)
    B = x.shape[0]
    t = feature.shape[0]
    idx = jnp.zeros((B, t), jnp.int32)
    for _ in range(depth):
        f = feature[jnp.arange(t)[None, :], idx]          # [B, t]
        thr = threshold[jnp.arange(t)[None, :], idx]      # [B, t]
        xv = jnp.take_along_axis(x, f, axis=1)            # [B, t]
        idx = 2 * idx + 1 + (xv > thr).astype(jnp.int32)
    leaf_idx = idx - (leaf.shape[1] - 1)                  # [B, t]
    dists = leaf[jnp.arange(t)[None, :], leaf_idx]        # [B, t, C]
    return dists.mean(axis=1)


def top2_confidence_ref(prob: jax.Array) -> jax.Array:
    """MaxDiff margin per row: [B, C] -> [B]."""
    m1 = jnp.max(prob, axis=-1)
    is_max = prob == m1[:, None]
    first = jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1
    m2 = jnp.max(jnp.where(is_max & first, -jnp.inf, prob), axis=-1)
    return jnp.abs(m1 - m2)


def grove_aggregate_ref(prob_acc: jax.Array, contrib: jax.Array,
                        live: jax.Array, hops: jax.Array,
                        thresh: jax.Array):
    """Algorithm 2 lines 7-11 fused: accumulate, normalize, gate.

    prob_acc [B, C], contrib [B, C], live [B] bool, hops [B] int32,
    thresh scalar or per-lane [B] -> (prob_acc', hops', live', margin)
    """
    prob_acc = prob_acc + jnp.where(live[:, None], contrib, 0.0)
    hops = hops + live.astype(jnp.int32)
    prob_norm = prob_acc / jnp.maximum(hops, 1)[:, None].astype(prob_acc.dtype)
    margin = top2_confidence_ref(prob_norm)
    live = live & (margin < thresh)
    return prob_acc, hops, live, margin


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """Naive full-matrix attention oracle (GQA broadcast, Dv may differ)."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Sq, K, G, D)
    s = jnp.einsum("bqkgd,bpkd->bkgqp", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqp,bpkd->bkgqd", p, v.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[3])
    return out.astype(q.dtype)


def ssd_chunk_ref(xbar, a, Bm, Cm):
    """Intra-chunk SSD oracle (mirrors models/mamba2.ssd_chunked's
    y_diag + chunk-state terms).

    xbar [B,nc,Q,H,P], a [B,nc,H,Q], Bm/Cm [B,nc,Q,N]
    -> (y_diag [B,nc,Q,H,P], states [B,nc,H,P,N])
    """
    Q = xbar.shape[2]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(diff), 0.0)                   # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, xbar)
    cum = cs
    decay_end = jnp.exp(cum[..., -1:] - cum)                  # [B,nc,H,Q]
    states = jnp.einsum("bchj,bcjn,bcjhp->bchpn", decay_end, Bm, xbar)
    return y_diag, states
