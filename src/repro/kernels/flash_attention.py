"""Pallas TPU kernel: fused causal flash attention (GQA-aware).

The pure-JAX blocked attention in models/layers.py materializes every
[blk_q, blk_k] score/probability tile at an XLA fusion boundary — measured
at ~70 TB HBM traffic per train step for minicpm3-4b (the dominant roofline
term).  This kernel keeps the whole online-softmax pipeline (qk^T, mask,
exp, rescale, pv) in VMEM: HBM traffic collapses to one q/k/v read + one
output write per layer.

Grid: (batch*kv_head*q_group, nq) — one q block per program, kv scanned
inside with ``jax.lax.fori_loop``; the causal upper triangle is skipped at
block granularity (trip count = ceil((iq+1)*blk_q / blk_k)), which also
removes the ~2x masked-FLOP waste the jnp path pays.

Validated against ref.flash_attention_ref with interpret=True (CPU) over
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, blk_q: int,
                  blk_k: int, seq_k: int, causal: bool):
    iq = pl.program_id(1)
    q = q_ref[0]                     # [blk_q, D]
    D = q.shape[-1]
    Dv = v_ref.shape[-1]

    nk = seq_k // blk_k
    if causal:
        n_live = jnp.minimum((iq * blk_q + blk_q + blk_k - 1) // blk_k, nk)
    else:
        n_live = nk

    def body(jk, carry):
        acc, m, l = carry
        k = pl.load(k_ref,
                    (pl.dslice(0, 1), pl.dslice(jk * blk_k, blk_k),
                     slice(None)))[0]
        v = pl.load(v_ref,
                    (pl.dslice(0, 1), pl.dslice(jk * blk_k, blk_k),
                     slice(None)))[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [blk_q, blk_k]
        if causal:
            qpos = iq * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            kpos = jk * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l

    acc0 = jnp.zeros((blk_q, Dv), jnp.float32)
    m0 = jnp.full((blk_q,), -jnp.inf)
    l0 = jnp.zeros((blk_q,))
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, blk_q: int = 512,
                           blk_k: int = 512, scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """q [B,Sq,H,D], k/v [B,Sk,K,Dkv] -> [B,Sq,H,Dv].  H % K == 0."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // K
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0
    nq = Sq // blk_q

    # flatten (B, K, G) into one "head-lane" axis; kv broadcast over G
    qf = q.reshape(B, Sq, K, G, D).transpose(0, 2, 3, 1, 4) \
        .reshape(B * K * G, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * K, Sk, D), G, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * K, Sk, Dv), G, axis=0)

    # VMEM budget: q block + full k/v stripes per lane
    assert blk_q * D * 4 + Sk * (D + Dv) * 2 < 12 * 2**20, \
        "k/v stripe exceeds VMEM; lower blk sizes or shard sequence"

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, blk_q=blk_q,
                          blk_k=blk_k, seq_k=Sk, causal=causal),
        grid=(B * K * G, nq),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, Sk, D), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, Sk, Dv), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, Dv), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K * G, Sq, Dv), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, K, G, Sq, Dv).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, Dv)
