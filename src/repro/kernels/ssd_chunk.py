"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block (fused).

The chunked SSD scan (models/mamba2.py) materializes per-chunk [Q, Q]
decay/score tiles (L = exp(segsum), C·Bᵀ) at XLA fusion boundaries — the
SSM analogue of unfused attention scores, and the residual memory term for
the mamba/jamba cells after the flash-attention fix.  This kernel fuses
the whole intra-chunk computation per (batch, chunk) program:

    per head h:   cum   = cumsum(a_h)                      [Q]
                  L     = exp(cum_i - cum_j) . tril        [Q, Q]  (VMEM)
                  S     = C B^T                            [Q, Q]  (VMEM)
                  y_h   = (S * L) @ xbar_h                 [Q, P]
                  st_h  = (B * exp(cum_Q - cum))^T @ xbar_h [N, P]

emitting y_diag [Q, H, P] and chunk-state summaries [H, P, N]; the cheap
O(nc) inter-chunk recurrence and the C·state_prev off-diagonal term stay
in jnp (they carry no [Q,Q] tiles).  Oracle: ref.ssd_chunk_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *,
                      n_heads: int):
    x = x_ref[0]          # [Q, H, P]
    a = a_ref[0]          # [H, Q]
    Bm = b_ref[0]         # [Q, N]
    Cm = c_ref[0]         # [Q, N]
    Q = x.shape[0]

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [Q,Q]
    tril = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)

    for h in range(n_heads):                      # static unroll over local heads
        ah = a[h].astype(jnp.float32)             # [Q]
        cum = jnp.cumsum(ah)
        L = jnp.where(tril, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
        xh = x[:, h, :].astype(jnp.float32)       # [Q, P]
        y = jnp.dot(scores * L, xh,
                    preferred_element_type=jnp.float32)            # [Q, P]
        decay_end = jnp.exp(cum[-1] - cum)                         # [Q]
        st = jnp.dot((Bm.astype(jnp.float32) * decay_end[:, None]).T,
                     xh * jnp.exp(0.0),
                     preferred_element_type=jnp.float32)           # [N, P]
        y_ref[0, :, h, :] = y.astype(y_ref.dtype)
        st_ref[0, h, :, :] = st.T.astype(st_ref.dtype)             # [P, N]


def ssd_chunk_pallas(xbar: jax.Array, a: jax.Array, Bm: jax.Array,
                     Cm: jax.Array, *, interpret: bool = True):
    """Fused intra-chunk SSD.

    xbar [B, nc, Q, H, P] (dt-scaled inputs), a [B, nc, H, Q] (log decays),
    Bm/Cm [B, nc, Q, N]  ->  (y_diag [B, nc, Q, H, P], states [B, nc, H, P, N])
    """
    B, nc, Q, H, P = xbar.shape
    N = Bm.shape[-1]
    # VMEM: x chunk + per-head [Q,Q] tiles
    assert Q * Q * 4 * 2 + Q * (H * P + 2 * N) * 4 < 12 * 2**20, \
        "chunk working set exceeds VMEM; lower ssm_chunk"

    xf = xbar.reshape(B * nc, Q, H, P)
    af = a.reshape(B * nc, H, Q)
    bf = Bm.reshape(B * nc, Q, N)
    cf = Cm.reshape(B * nc, Q, N)

    y, st = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, n_heads=H),
        grid=(B * nc,),
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, H, Q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc, Q, H, P), xbar.dtype),
            jax.ShapeDtypeStruct((B * nc, H, P, N), xbar.dtype),
        ],
        interpret=interpret,
    )(xf, af, bf, cf)
    return (y.reshape(B, nc, Q, H, P), st.reshape(B, nc, H, P, N))
