"""Pallas TPU kernel: the ENTIRE Algorithm-2 loop in one launch.

The paper's PE keeps the grove walk on-chip: an input hops grove-to-grove
without its probability array ever leaving the accelerator.  The per-hop
backends reproduce the semantics but pay a kernel-launch-and-HBM round trip
per hop — ``max_hops x (grove gather + aggregate)`` dispatches, with the
[B, C] probability state re-read from HBM every hop.  This kernel is the
TPU analogue of the PE itself: ALL grove node tables (feature / threshold /
leaf for every grove, every output head) are pinned whole in VMEM, the
batch is tiled over the grid, and the full early-exit loop — per-lane live
mask, per-lane ``[B]`` threshold and hop budget, rotation start
``start [B]``, MaxDiff gate, hop counting, min-over-heads rule — runs as a
``while_loop`` *inside* the kernel.  One launch emits (proba, hops); the
loop exits as soon as every lane in the block is confident (or budgeted
out), so an easy block touches VMEM tables for one hop and stops.

Tables arrive packed (``forest.pack.ForestPack`` dtypes): fp32, bf16 or
per-tree-scaled int8 with fp32 scales.  The resident tables and every load
from them stay at the packed width — int8 pins ~4x the field of groves in
the same VMEM — and only the *gathered* [BB, t] values are dequantized to
fp32 for the compare/accumulate, mirroring the ASIC's fixed-point SRAM.

Live-lane compaction (``compact=True``): after each hop the block's live
lanes are permuted to a contiguous prefix (a stable cumsum-ranked
partition — per-lane state just relocates, so hops/labels are bit-identical
to the uncompacted walk), and the next hop's gather-compare walk runs over
the smallest power-of-two prefix that covers the survivors instead of the
full block.  Exited lanes therefore stop occupying walk lanes: at a high
threshold most lanes exit on hop 1 and every later hop touches a fraction
of the block's VMEM lane state — the same sparsity win the reference-lazy
path shows at batch granularity (22.3 -> 11.9 ms), recovered inside the
kernel.  The engine's autotuner measures compaction on/off per (precision,
field size) and serves the faster setting.

Block sizing (mirrors tree_traverse.py): BB lanes x t trees x d levels of
int32 index state is small; the resident tables dominate VMEM at their
packed byte size — the whole field of groves, not one grove, must fit.
The wrapper rejects working sets over the ~16 MB v5e VMEM budget with a
ValueError reporting required vs available bytes and the two remedies
(``chunk_b=...`` batch slices, ``precision="int8"`` tables); the engine's
``chunk_b="auto"`` applies the first remedy automatically.

Batches need not align: the batch is dead-lane padded to the block boundary
(padded lanes enter with live=0, so they never walk, never count hops, and
never keep the early-exit loop spinning) and outputs are sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tree_traverse import (VMEM_BUDGET, _dequant_gathered,
                                         resolve_interpret, vmem_error)

# TPU lane tiling: batch blocks are sized in multiples of this so a block's
# [BB, t] walk state maps onto whole sublanes (fit_block_b rounds down to it)
LANE_ALIGN = 8

# smallest compacted walk prefix: shrinking below one aligned sublane group
# buys nothing (the VPU processes whole sublanes either way)
MIN_COMPACT_WIDTH = 8


def vmem_table_bytes(feature, threshold, leaf, thr_scale, leaf_scale) -> int:
    """Bytes of packed grove tables the kernel pins whole in VMEM."""
    return int(feature.nbytes + threshold.nbytes + leaf.nbytes
               + thr_scale.nbytes + leaf_scale.nbytes)


def vmem_lane_bytes(*, n_heads: int, n_classes: int, grove_size: int,
                    depth: int, n_features: int) -> int:
    """Per-lane VMEM state, byte-exact per dtype: the fp32 input row, the
    [O, C] prob accumulators (x2 for the normalized copy), the [t] x
    (depth + 2) int32 walk/gather state, five 4-byte per-lane scalars
    (start, threshold, hop budget, hop count, compaction origin index) —
    and the live mask at its actual int8 width, ONE byte, not four."""
    words = (n_features                    # x row, fp32
             + 2 * n_heads * n_classes    # prob + normalized copy, fp32
             + grove_size * (depth + 2)   # walk idx + gathered f/thr, int32
             + 5)                         # start/thresh/budget/hops/orig
    return 4 * words + 1                  # + int8 live mask


def vmem_working_set(feature, threshold, leaf, thr_scale, leaf_scale, *,
                     block_b: int, n_features: int) -> int:
    """Bytes resident in VMEM: every packed table + one batch block's state."""
    O, _, t, _ = feature.shape
    C = leaf.shape[4]
    depth = int(np.log2(leaf.shape[3]) + 0.5)
    tables = vmem_table_bytes(feature, threshold, leaf, thr_scale, leaf_scale)
    block = block_b * vmem_lane_bytes(n_heads=O, n_classes=C, grove_size=t,
                                      depth=depth, n_features=n_features)
    return tables + block


def fit_block_b(feature, threshold, leaf, thr_scale, leaf_scale, *,
                n_features: int) -> int:
    """Largest LANE_ALIGN-aligned batch block that fits VMEM beside the
    packed tables (0 when the tables alone are over budget).  The raw
    lane-count quotient is rounded DOWN to a multiple of 8 — an unaligned
    block (say 731) defeats TPU sublane tiling and pads up inside Mosaic,
    silently overshooting the modeled footprint.  A sliver of headroom
    below one aligned group (0 < fit < 8) is returned unrounded so the
    evaluation still runs rather than refusing.  ``FogEngine``'s
    auto-chunking and the autotuner's analytic seed size from this."""
    O, _, t, _ = feature.shape
    C = leaf.shape[4]
    depth = int(np.log2(leaf.shape[3]) + 0.5)
    tables = vmem_table_bytes(feature, threshold, leaf, thr_scale, leaf_scale)
    lane = vmem_lane_bytes(n_heads=O, n_classes=C, grove_size=t, depth=depth,
                           n_features=n_features)
    fit = max(0, (VMEM_BUDGET - 1 - tables) // lane)
    return fit - fit % LANE_ALIGN if fit >= LANE_ALIGN else fit


def _compact_perm(live):
    """Gather permutation moving live lanes to a contiguous prefix.

    Stable on both sides (cumsum ranks preserve relative order), so the
    permutation is a pure relocation of per-lane state: every lane keeps
    its own values and the walk/gate math is bit-identical.  Returns
    ``perm`` with ``new[i] = old[perm[i]]``.
    """
    BB = live.shape[0]
    livei = live.astype(jnp.int32)
    n_live = jnp.sum(livei)
    rank_live = jnp.cumsum(livei) - 1
    rank_dead = jnp.cumsum(1 - livei) - 1
    pos = jnp.where(livei > 0, rank_live, n_live + rank_dead)   # old -> new
    iota = jax.lax.iota(jnp.int32, BB)
    return jnp.zeros((BB,), jnp.int32).at[pos].set(iota)


def _fused_fog_kernel(feature_ref, threshold_ref, leaf_ref, thr_scale_ref,
                      leaf_scale_ref, x_ref, start_ref, thresh_ref,
                      budget_ref, live_ref, proba_out, hops_out,
                      *, depth: int, max_hops: int, n_groves: int,
                      compact: bool):
    x0 = x_ref[...]                      # [BB, F]
    start0 = start_ref[...]              # [BB]
    thresh0 = thresh_ref[...]            # [BB] per-lane gate
    budget0 = budget_ref[...]            # [BB] per-lane hop cap
    live0 = live_ref[...]                # [BB] int8 (0 = dead-padded lane)
    feature = feature_ref[...]           # [O, G, t, nodes]
    threshold = threshold_ref[...]       # packed dtype
    leaf = leaf_ref[...]                 # [O, G, t, L, C] packed dtype
    thr_scale = thr_scale_ref[...]       # [O, G, t, 1] fp32
    leaf_scale = leaf_scale_ref[...]     # [O, G, t, 1, 1]
    O = feature.shape[0]
    t = feature.shape[2]
    L, C = leaf.shape[3], leaf.shape[4]
    BB = x0.shape[0]

    def walk(o, g, xs):
        # per-lane grove walk against head o's VMEM-resident tables: the
        # same d gather-compare levels as tree_traverse, but the grove is
        # selected per lane (g) and the lane width follows the compacted
        # prefix instead of being fixed at BB
        w = xs.shape[0]
        trange = jax.lax.broadcasted_iota(jnp.int32, (w, t), 1)
        gcol = g[:, None]
        ts = thr_scale[o][gcol, trange, 0]                 # [w, t]
        idx = jnp.zeros((w, t), jnp.int32)
        for _ in range(depth):           # static unroll
            f = feature[o][gcol, trange, idx]              # [w, t]
            thr = _dequant_gathered(threshold[o][gcol, trange, idx], ts,
                                    sentinel=True)
            xv = jnp.take_along_axis(xs, f, axis=1)        # [w, t]
            idx = 2 * idx + 1 + (xv > thr).astype(jnp.int32)
        dists = _dequant_gathered(
            leaf[o][gcol, trange, idx - (L - 1)],          # [w, t, C]
            leaf_scale[o][gcol, trange, 0, 0][..., None])
        return dists.mean(axis=1)

    # compacted walk prefix widths: BB, BB/2, ... down to MIN_COMPACT_WIDTH.
    # Only one branch executes per hop (lax.switch); survivors always sit in
    # a prefix after compaction, so the smallest width covering them is exact.
    widths = [BB]
    if compact:
        while widths[-1] % 2 == 0 and widths[-1] // 2 >= MIN_COMPACT_WIDTH:
            widths.append(widths[-1] // 2)

    def walk_all(g, xs, n_live):
        if len(widths) == 1:
            return jnp.stack([walk(o, g, xs) for o in range(O)])

        def prefix_branch(w):
            def run(_):
                out = jnp.stack([walk(o, g[:w], xs[:w]) for o in range(O)])
                # lanes beyond the prefix are dead (livef = 0 masks them);
                # pad with zeros to keep the [O, BB, C] accumulate shape
                return jnp.pad(out, ((0, 0), (0, BB - w), (0, 0)))
            return run

        # halving level: how many times the prefix can shrink and still
        # cover every live lane
        lvl = jnp.zeros((), jnp.int32)
        for w in widths[1:]:
            lvl = lvl + (n_live <= w).astype(jnp.int32)
        return jax.lax.switch(lvl, [prefix_branch(w) for w in widths], None)

    def body(state):
        j, prob, live, hops, x, start, thresh, budget, orig = state
        n_live = jnp.sum(live.astype(jnp.int32))
        if compact:
            def do_compact(args):
                prob, live, hops, x, start, thresh, budget, orig = args
                perm = _compact_perm(live)
                take = lambda a: jnp.take(a, perm, axis=0)
                return (jnp.take(prob, perm, axis=1), take(live), take(hops),
                        take(x), take(start), take(thresh), take(budget),
                        take(orig))

            # hop 1 (and any fully-live block) skips the permutation
            prob, live, hops, x, start, thresh, budget, orig = jax.lax.cond(
                n_live < BB, do_compact, lambda args: args,
                (prob, live, hops, x, start, thresh, budget, orig))
        g = (start + j) % n_groves
        livef = live.astype(jnp.float32)
        prob = prob + walk_all(g, x, n_live) * livef[None, :, None]
        hops = hops + live.astype(jnp.int32)
        denom = jnp.maximum(hops, 1).astype(jnp.float32)
        prob_norm = prob / denom[None, :, None]
        # MaxDiff with first-max masking (identical to grove_aggregate)
        m1 = jnp.max(prob_norm, axis=-1)                   # [O, BB]
        is_max = prob_norm == m1[..., None]
        first = jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1
        m2 = jnp.max(jnp.where(is_max & first, -jnp.inf, prob_norm), axis=-1)
        # min-over-outputs rule: live until EVERY head clears the gate
        margin = jnp.abs(m1 - m2).min(axis=0)              # [BB]
        live = (live.astype(bool) & (margin < thresh)
                & (hops < budget)).astype(jnp.int8)
        return j + 1, prob, live, hops, x, start, thresh, budget, orig

    def cond(state):
        j, _, live = state[0], state[1], state[2]
        return (j < max_hops) & (jnp.sum(live.astype(jnp.int32)) > 0)

    state0 = (jnp.zeros((), jnp.int32),
              jnp.zeros((O, BB, C), jnp.float32),
              live0,
              jnp.zeros((BB,), jnp.int32),
              x0, start0, thresh0, budget0,
              jax.lax.iota(jnp.int32, BB))
    _, prob, _, hops, _, _, _, _, orig = jax.lax.while_loop(cond, body, state0)
    denom = jnp.maximum(hops, 1).astype(jnp.float32)
    proba = (prob / denom[None, :, None]).transpose(1, 0, 2)   # [BB, O, C]
    if compact:
        # undo the accumulated compaction permutation: lane orig[i] of the
        # input lives at row i, so scatter row i back to slot orig[i]
        inv = jnp.zeros((BB,), jnp.int32).at[orig].set(
            jax.lax.iota(jnp.int32, BB))
        proba = jnp.take(proba, inv, axis=0)
        hops = jnp.take(hops, inv, axis=0)
    proba_out[...] = proba
    hops_out[...] = hops


def fused_fog_pallas(feature: jax.Array, threshold: jax.Array,
                     leaf: jax.Array, x: jax.Array, start: jax.Array,
                     thresh: jax.Array, budget: jax.Array,
                     thr_scale: jax.Array | None = None,
                     leaf_scale: jax.Array | None = None, *,
                     max_hops: int, block_b: int = 128,
                     compact: bool = True,
                     interpret: bool | None = None):
    """One-launch Algorithm-2 evaluation over head-stacked packed tables.

    feature    int32           [O, G, t, 2**d - 1]   all heads, all groves
    threshold  fp32|bf16|int8  [O, G, t, 2**d - 1]
    leaf       fp32|bf16|int8  [O, G, t, 2**d, C]
    thr_scale  float32         [O, G, t, 1]      per-tree dequant scales
    leaf_scale float32         [O, G, t, 1, 1]   (default ones)
    x          float32 [B, F];  start int32 [B];  thresh float32 [B];
    budget     int32   [B]
    compact    permute live lanes to a prefix each hop and walk only the
               covering power-of-two prefix (bit-identical results)
    interpret  None derives from ``jax.default_backend()`` (compiled on a
               real TPU, interpreted elsewhere); a bool overrides
    returns    (proba float32 [B, O, C] hop-normalized, hops int32 [B])
    """
    B, F = x.shape
    O, G, t, _ = feature.shape
    L, C = leaf.shape[3], leaf.shape[4]
    depth = int(np.log2(L) + 0.5)
    block_b = min(block_b, B)
    interpret = resolve_interpret(interpret)
    if thr_scale is None:
        thr_scale = jnp.ones((O, G, t, 1), jnp.float32)
    if leaf_scale is None:
        leaf_scale = jnp.ones((O, G, t, 1, 1), jnp.float32)

    ws = vmem_working_set(feature, threshold, leaf, thr_scale, leaf_scale,
                          block_b=block_b, n_features=F)
    if ws >= VMEM_BUDGET:
        tables = vmem_table_bytes(feature, threshold, leaf, thr_scale,
                                  leaf_scale)
        raise vmem_error(
            "fused FoG", ws,
            f"{O} heads x {G} groves x {t} trees, depth {depth}, {C} "
            f"classes, {threshold.dtype} tables = {tables} B resident + "
            f"block_b={block_b} batch state = {ws - tables} B; the largest "
            f"batch block fitting beside these tables is "
            f"{fit_block_b(feature, threshold, leaf, thr_scale, leaf_scale, n_features=F)}",
            chunkable=True)

    pad = (-B) % block_b
    live8 = jnp.ones((B,), jnp.int8)
    if pad:  # dead-lane pad: padded lanes enter dead and are sliced off
        x = jnp.pad(x, ((0, pad), (0, 0)))
        start = jnp.pad(start, (0, pad))
        thresh = jnp.pad(thresh, (0, pad))
        budget = jnp.pad(budget, (0, pad), constant_values=1)
        live8 = jnp.pad(live8, (0, pad))
        B = B + pad

    whole4 = lambda i: (0, 0, 0, 0)
    whole5 = lambda i: (0, 0, 0, 0, 0)
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    proba, hops = pl.pallas_call(
        functools.partial(_fused_fog_kernel, depth=depth, max_hops=max_hops,
                          n_groves=G, compact=compact),
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec(feature.shape, whole4),    # tables: whole, VMEM-pinned
            pl.BlockSpec(threshold.shape, whole4),
            pl.BlockSpec(leaf.shape, whole5),
            pl.BlockSpec(thr_scale.shape, whole4),
            pl.BlockSpec(leaf_scale.shape, whole5),
            pl.BlockSpec((block_b, F), row),        # batch: tiled
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
        ],
        out_specs=[
            pl.BlockSpec((block_b, O, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b,), vec),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, O, C), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(feature, threshold, leaf, thr_scale, leaf_scale, x, start, thresh,
      budget, live8)
    if pad:
        proba, hops = proba[:-pad], hops[:-pad]
    return proba, hops
