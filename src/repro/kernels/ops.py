"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels execute with ``interpret=True`` (the kernel
body runs as jnp on CPU — correctness identical, performance irrelevant); on
a real TPU backend they compile to Mosaic.  Callers never pass ``interpret``
themselves.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.tree_traverse import resolve_interpret, tree_traverse_pallas
from repro.kernels.top2_confidence import top2_confidence_pallas
from repro.kernels.grove_aggregate import grove_aggregate_pallas
from repro.kernels.fused_fog import fused_fog_pallas
from repro.kernels import ref


def _interpret() -> bool:
    return resolve_interpret(None)


@partial(jax.jit, static_argnames=("block_b",))
def tree_traverse(feature, threshold, leaf, x, thr_scale=None,
                  leaf_scale=None, *, block_b: int = 128):
    """Grove bundle eval [B,F] -> [B,C] over packed fp32/bf16/int8 tables
    (Pallas; oracle: ref.tree_traverse_ref).  int8 tables stay int8 in
    VMEM; gathered values dequantize in-kernel via the per-tree scales."""
    return tree_traverse_pallas(feature, threshold, leaf, x,
                                thr_scale, leaf_scale,
                                block_b=block_b, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_b",))
def top2_confidence(prob, *, block_b: int = 256):
    """MaxDiff margin [B,C] -> [B] (Pallas; oracle: ref.top2_confidence_ref)."""
    return top2_confidence_pallas(prob, block_b=block_b, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_b",))
def grove_aggregate(prob_acc, contrib, live, hops, thresh, *, block_b: int = 256):
    """Fused Algorithm-2 hop update; thresh is a scalar or per-lane [B]
    vector (Pallas; oracle: ref.grove_aggregate_ref)."""
    return grove_aggregate_pallas(prob_acc, contrib, live, hops, thresh,
                                  block_b=block_b, interpret=_interpret())


@partial(jax.jit, static_argnames=("max_hops", "block_b", "compact",
                                   "interpret"))
def fused_fog(feature, threshold, leaf, x, start, thresh, budget,
              thr_scale=None, leaf_scale=None, *,
              max_hops: int, block_b: int = 128, compact: bool = True,
              interpret: bool | None = None):
    """Whole Algorithm-2 loop in ONE kernel launch: head-stacked packed
    grove tables [O,G,t,...] pinned in VMEM at their packed width (fp32/
    bf16/int8 — int8 fits ~4x the field), per-lane thresh/budget, early-
    exit while_loop inside the kernel, gathered values dequantized in-
    register.  ``compact`` permutes live lanes to a prefix each hop and
    walks only the covering power-of-two prefix (bit-identical results);
    ``interpret=None`` derives from the runtime backend.  Returns
    (proba [B,O,C], hops [B]); oracle: the FogEngine reference backend
    over the same pack."""
    return fused_fog_pallas(feature, threshold, leaf, x, start, thresh,
                            budget, thr_scale, leaf_scale,
                            max_hops=max_hops, block_b=block_b,
                            compact=compact, interpret=interpret)


__all__ = ["tree_traverse", "top2_confidence", "grove_aggregate",
           "fused_fog", "ref"]
