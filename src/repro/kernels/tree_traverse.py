"""Pallas TPU kernel: grove bundle tree traversal.

The paper's PE — a comparator array walking k decision trees — becomes a
VMEM-resident walk: the grove's node tables (feature idx, thresholds, leaf
distributions; a few hundred KB for k<=32, d<=10) are pinned whole in VMEM,
the batch is tiled over the grid, and the depth loop is fully unrolled (d is
static).  Each level is a vectorized gather-compare over the [BB, t] lane
block — VPU work, no MXU — so the kernel is gather-throughput-bound, and
keeping the node tables in VMEM (vs HBM re-reads per level) is the entire
win: d x 2 words/lane/level come from VMEM instead of HBM.

Block sizing: BB=128 lanes x t trees x (d levels) int32 index state fits
easily; leaf tables dominate VMEM at t * 2**d * C * 4 bytes — the wrapper
asserts the working set stays under the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# usable VMEM budget shared by every table-resident kernel (v5e ~16 MB,
# minus headroom for spills/double buffers); fused_fog imports this too
VMEM_BUDGET = 14 * 2**20


def _tree_traverse_kernel(feature_ref, threshold_ref, leaf_ref, x_ref,
                          out_ref, *, depth: int):
    x = x_ref[...]                      # [BB, F]
    feature = feature_ref[...]          # [t, nodes]
    threshold = threshold_ref[...]      # [t, nodes]
    leaf = leaf_ref[...]                # [t, L, C]
    t = feature.shape[0]
    BB = x.shape[0]

    idx = jnp.zeros((BB, t), jnp.int32)
    trange = jax.lax.broadcasted_iota(jnp.int32, (BB, t), 1)
    for _ in range(depth):              # static unroll: d gather-compare levels
        f = feature[trange, idx]                        # [BB, t]
        thr = threshold[trange, idx]                    # [BB, t]
        xv = jnp.take_along_axis(x, f, axis=1)          # [BB, t]
        idx = 2 * idx + 1 + (xv > thr).astype(jnp.int32)
    leaf_idx = idx - (leaf.shape[1] - 1)
    dists = leaf[trange, leaf_idx]                      # [BB, t, C]
    out_ref[...] = dists.mean(axis=1)


def tree_traverse_pallas(feature: jax.Array, threshold: jax.Array,
                         leaf: jax.Array, x: jax.Array,
                         *, block_b: int = 128,
                         interpret: bool = True) -> jax.Array:
    """[t,N] x [t,N] x [t,L,C] x [B,F] -> [B,C] grove probabilities.

    ``B`` need not divide ``block_b``: the batch is dead-padded with zero
    rows up to the next block boundary (the padded walks are discarded) and
    the output is sliced back to ``B``.
    """
    B, F = x.shape
    t, L, C = leaf.shape
    depth = int(np.log2(L) + 0.5)
    block_b = min(block_b, B)

    # VMEM budget check (v5e ~16MB usable): tables + one batch block
    tables = (feature.size + threshold.size + leaf.size) * 4
    block = block_b * (F + C + t * (depth + 2)) * 4
    if tables + block >= VMEM_BUDGET:
        raise ValueError(
            f"grove working set {tables + block} B ({t} trees, depth "
            f"{depth}, {C} classes, block_b={block_b}) exceeds the ~16 MB "
            "VMEM budget; shrink grove_size/depth or block_b")

    pad = (-B) % block_b
    if pad:  # dead-pad unaligned batches; padded rows are sliced off below
        x = jnp.pad(x, ((0, pad), (0, 0)))
        B = B + pad

    grid = (B // block_b,)
    out = pl.pallas_call(
        functools.partial(_tree_traverse_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec(feature.shape, lambda i: (0, 0)),    # tables: whole, VMEM-pinned
            pl.BlockSpec(threshold.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),     # batch: tiled
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(feature, threshold, leaf, x)
    return out[:-pad] if pad else out
