"""Pallas TPU kernel: grove bundle tree traversal.

The paper's PE — a comparator array walking k decision trees — becomes a
VMEM-resident walk: the grove's node tables (feature idx, thresholds, leaf
distributions; a few hundred KB for k<=32, d<=10) are pinned whole in VMEM,
the batch is tiled over the grid, and the depth loop is fully unrolled (d is
static).  Each level is a vectorized gather-compare over the [BB, t] lane
block — VPU work, no MXU — so the kernel is gather-throughput-bound, and
keeping the node tables in VMEM (vs HBM re-reads per level) is the entire
win: d x 2 words/lane/level come from VMEM instead of HBM.

Tables arrive packed (``forest.pack.ForestPack`` dtypes): fp32, bf16 or
per-tree-scaled int8 with fp32 scales.  Quantized values are dequantized
*in-kernel, after the gather* — the VMEM-resident table and every load from
it stay at the packed width (int8 reads a quarter of the fp32 bytes per
node), and only the gathered [BB, t] values are widened to fp32 for the
compare, mirroring the ASIC's fixed-point SRAM + fp compare split.

Block sizing: BB=128 lanes x t trees x (d levels) int32 index state fits
easily; leaf tables dominate VMEM at t * 2**d * C * itemsize bytes — the
wrapper rejects working sets over the ~16 MB v5e VMEM budget with a
ValueError that reports required vs available bytes and the remedies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# usable VMEM budget shared by every table-resident kernel (v5e ~16 MB,
# minus headroom for spills/double buffers); fused_fog imports this too
VMEM_BUDGET = 14 * 2**20


def resolve_interpret(override: bool | None = None) -> bool:
    """The one place the Pallas ``interpret`` flag is decided.

    ``None`` (the default everywhere) derives it from the runtime: anything
    but a real TPU backend runs the kernel body interpreted as jnp (Mosaic
    cannot target CPU/GPU here), while a TPU compiles to Mosaic — so a
    real-TPU deployment never silently serves the interpreted kernel.  An
    explicit bool wins unconditionally (debugging a Mosaic miscompile with
    ``interpret=True`` on TPU, or asserting compiled execution in tests).
    """
    if override is not None:
        return bool(override)
    return jax.default_backend() != "tpu"


def vmem_error(kind: str, required: int, detail: str,
               chunkable: bool = False) -> ValueError:
    """The shared over-budget rejection: required vs available bytes, plus
    the remedies.  ``chunkable`` names auto-chunking only where the engine
    actually applies it (the fused backend); the per-grove kernel's budget
    is dominated by its resident tables, which chunking cannot shrink."""
    chunk = ("evaluate in slices that fit (FogPolicy(chunk_b=\"auto\") "
             "sizes them from the pack footprint), or " if chunkable else "")
    return ValueError(
        f"{kind} VMEM working set is {required} B ({required / 2**20:.1f} "
        f"MiB) but only {VMEM_BUDGET} B ({VMEM_BUDGET / 2**20:.1f} MiB) is "
        f"usable ({detail}); remedies: {chunk}shrink the resident tables "
        "with precision=\"int8\" (~4x smaller than fp32); shrinking "
        "block_b, n_groves, grove_size or depth also helps")


def _dequant_gathered(vals, scale_rows, sentinel: bool = False):
    """Widen gathered packed values to fp32 (int8: multiply by the gathered
    per-tree scale; fp32/bf16: exact upcast).  Static on the table dtype.
    ``sentinel`` restores the threshold padding codes (int8 ±127 -> ±inf,
    the complete-tree "always go left" nodes — see forest.pack)."""
    out = vals.astype(jnp.float32)
    if vals.dtype == jnp.int8:
        out = out * scale_rows
        if sentinel:
            out = jnp.where(vals == 127, jnp.inf, out)
            out = jnp.where(vals == -127, -jnp.inf, out)
    return out


def _tree_traverse_kernel(feature_ref, threshold_ref, leaf_ref,
                          thr_scale_ref, leaf_scale_ref, x_ref,
                          out_ref, *, depth: int):
    x = x_ref[...]                      # [BB, F]
    feature = feature_ref[...]          # [t, nodes]
    threshold = threshold_ref[...]      # [t, nodes] packed dtype
    leaf = leaf_ref[...]                # [t, L, C]  packed dtype
    thr_scale = thr_scale_ref[...]      # [t, 1] fp32 per-tree scales
    leaf_scale = leaf_scale_ref[...]    # [t, 1, 1]
    t = feature.shape[0]
    BB = x.shape[0]

    idx = jnp.zeros((BB, t), jnp.int32)
    trange = jax.lax.broadcasted_iota(jnp.int32, (BB, t), 1)
    ts_rows = thr_scale[:, 0][None, :]                  # [1, t] broadcast
    for _ in range(depth):              # static unroll: d gather-compare levels
        f = feature[trange, idx]                        # [BB, t]
        thr = _dequant_gathered(threshold[trange, idx], ts_rows,
                                sentinel=True)
        xv = jnp.take_along_axis(x, f, axis=1)          # [BB, t]
        idx = 2 * idx + 1 + (xv > thr).astype(jnp.int32)
    leaf_idx = idx - (leaf.shape[1] - 1)
    dists = _dequant_gathered(leaf[trange, leaf_idx],   # [BB, t, C]
                              leaf_scale[:, 0, 0][None, :, None])
    out_ref[...] = dists.mean(axis=1)


def tree_traverse_pallas(feature: jax.Array, threshold: jax.Array,
                         leaf: jax.Array, x: jax.Array,
                         thr_scale: jax.Array | None = None,
                         leaf_scale: jax.Array | None = None,
                         *, block_b: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """[t,N] x [t,N] x [t,L,C] x [B,F] -> [B,C] grove probabilities.

    ``threshold``/``leaf`` may be fp32, bf16 or int8 (then ``thr_scale``
    [t,1] / ``leaf_scale`` [t,1,1] carry the per-tree dequant scales;
    omitted scales default to ones).  ``B`` need not divide ``block_b``:
    the batch is dead-padded with zero rows up to the next block boundary
    (the padded walks are discarded) and the output is sliced back to ``B``.
    """
    B, F = x.shape
    t, L, C = leaf.shape
    depth = int(np.log2(L) + 0.5)
    block_b = min(block_b, B)
    interpret = resolve_interpret(interpret)
    if thr_scale is None:
        thr_scale = jnp.ones((t, 1), jnp.float32)
    if leaf_scale is None:
        leaf_scale = jnp.ones((t, 1, 1), jnp.float32)

    tables = int(feature.nbytes + threshold.nbytes + leaf.nbytes
                 + thr_scale.nbytes + leaf_scale.nbytes)
    block = block_b * (F + C + t * (depth + 2)) * 4
    if tables + block >= VMEM_BUDGET:
        raise vmem_error(
            "grove", tables + block,
            f"{t} trees, depth {depth}, {C} classes, "
            f"{threshold.dtype} tables = {tables} B resident + "
            f"block_b={block_b} batch state = {block} B")

    pad = (-B) % block_b
    if pad:  # dead-pad unaligned batches; padded rows are sliced off below
        x = jnp.pad(x, ((0, pad), (0, 0)))
        B = B + pad

    grid = (B // block_b,)
    out = pl.pallas_call(
        functools.partial(_tree_traverse_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec(feature.shape, lambda i: (0, 0)),    # tables: whole, VMEM-pinned
            pl.BlockSpec(threshold.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(thr_scale.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf_scale.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),     # batch: tiled
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(feature, threshold, leaf, thr_scale, leaf_scale, x)
    return out[:-pad] if pad else out
