"""Pallas TPU kernel: fused MaxDiff confidence (top-2 margin, no sort).

The ASIC's MaxDiff comparator: one pass max, one masked pass for the second
max, absolute difference.  Row block tiled over the grid; class axis stays
whole in VMEM (C is small).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tree_traverse import resolve_interpret


def _top2_kernel(prob_ref, out_ref):
    prob = prob_ref[...]                                  # [BB, C]
    m1 = jnp.max(prob, axis=-1)
    is_max = prob == m1[:, None]
    first = jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1
    m2 = jnp.max(jnp.where(is_max & first, -jnp.inf, prob), axis=-1)
    out_ref[...] = jnp.abs(m1 - m2)


def top2_confidence_pallas(prob: jax.Array, *, block_b: int = 256,
                           interpret: bool | None = None) -> jax.Array:
    """[B, C] -> [B] top-2 margin.

    ``B`` need not divide ``block_b``: the batch is zero-padded to the next
    block boundary and the padded rows' margins sliced off.
    """
    B, C = prob.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        prob = jnp.pad(prob, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _top2_kernel,
        grid=((B + pad) // block_b,),
        in_specs=[pl.BlockSpec((block_b, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B + pad,), prob.dtype),
        interpret=resolve_interpret(interpret),
    )(prob)
    return out[:B] if pad else out
