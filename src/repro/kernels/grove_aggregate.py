"""Pallas TPU kernel: fused Algorithm-2 inner step (lines 7-11).

Fuses the per-hop state update — masked probability accumulate, hop count,
normalization, MaxDiff margin, liveness gate — into one VMEM pass so the
[B, C] probability state is read and written exactly once per hop instead
of materializing four intermediates in HBM.

The confidence gate takes a per-lane threshold vector: a scalar threshold is
broadcast to ``[B]`` before the call, so mixed-QoS batches (every lane with
its own accuracy/energy trade-off, ``FogPolicy.threshold`` as a vector) run
the same kernel at identical cost.

Precision contract: grove tables are packed (fp32/bf16/int8 — see
``forest.pack.ForestPack``) and the per-hop grove walk dequantizes its
*contribution* rows to fp32 before this kernel sees them, so the
accumulate/normalize/MaxDiff state here is always fp32 regardless of the
table dtype ("int8 loads, fp32 compare/accumulate").  The wrapper enforces
that contract rather than silently accumulating in a narrow dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tree_traverse import resolve_interpret


def _aggregate_kernel(prob_ref, contrib_ref, live_ref, hops_ref, thresh_ref,
                      prob_out, hops_out, live_out, margin_out):
    prob = prob_ref[...]           # [BB, C]
    contrib = contrib_ref[...]     # [BB, C]
    live = live_ref[...]           # [BB] (int8 mask: pallas bools are awkward)
    hops = hops_ref[...]           # [BB]
    thresh = thresh_ref[...]       # [BB] per-lane gate

    livef = live.astype(prob.dtype)
    prob = prob + contrib * livef[:, None]
    hops = hops + live.astype(jnp.int32)
    denom = jnp.maximum(hops, 1).astype(prob.dtype)
    prob_norm = prob / denom[:, None]

    m1 = jnp.max(prob_norm, axis=-1)
    is_max = prob_norm == m1[:, None]
    first = jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1
    m2 = jnp.max(jnp.where(is_max & first, -jnp.inf, prob_norm), axis=-1)
    margin = jnp.abs(m1 - m2)

    prob_out[...] = prob
    hops_out[...] = hops
    live_out[...] = (live.astype(bool) & (margin < thresh)).astype(jnp.int8)
    margin_out[...] = margin


def grove_aggregate_pallas(prob_acc: jax.Array, contrib: jax.Array,
                           live: jax.Array, hops: jax.Array,
                           thresh: jax.Array, *, block_b: int = 256,
                           interpret: bool | None = None):
    """Fused hop update.  live is bool [B]; thresh is a scalar or per-lane
    [B] vector; returns (prob, hops, live, margin).

    ``B`` need not divide ``block_b``: the batch is dead-lane padded up to
    the next block boundary (padded lanes carry live=0, so their garbage
    margins never gate anything; the thresh vector pads along with them)
    and the outputs are sliced back to ``B``.
    """
    if jnp.issubdtype(contrib.dtype, jnp.integer):
        raise ValueError(
            f"grove_aggregate accumulates in floating point; dequantize "
            f"packed contributions before the hop update (got "
            f"{contrib.dtype})")
    B, C = prob_acc.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    thresh = jnp.broadcast_to(jnp.asarray(thresh, prob_acc.dtype), (B,))
    live8 = live.astype(jnp.int8)
    if pad:
        prob_acc = jnp.pad(prob_acc, ((0, pad), (0, 0)))
        contrib = jnp.pad(contrib, ((0, pad), (0, 0)))
        live8 = jnp.pad(live8, (0, pad))
        hops = jnp.pad(hops, (0, pad))
        thresh = jnp.pad(thresh, (0, pad))
        B = B + pad
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    prob, hops, live8, margin = pl.pallas_call(
        _aggregate_kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, C), row),
            pl.BlockSpec((block_b, C), row),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
        ],
        out_specs=[
            pl.BlockSpec((block_b, C), row),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
            pl.BlockSpec((block_b,), vec),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), prob_acc.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int8),
            jax.ShapeDtypeStruct((B,), prob_acc.dtype),
        ],
        interpret=resolve_interpret(interpret),
    )(prob_acc, contrib, live8, hops, thresh)
    if pad:
        prob, hops, live8, margin = (prob[:-pad], hops[:-pad], live8[:-pad],
                                     margin[:-pad])
    return prob, hops, live8.astype(bool), margin
