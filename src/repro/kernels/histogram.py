"""Pallas TPU kernel: level-wise split-search histograms for the trainer.

The device forest trainer (``forest/grow.py``) reduces each level of tree
induction to one tensor: per-(tree, node, feature, bin, class) weighted
sample counts, from which every candidate split's gain falls out of a
cumsum.  This module builds that tensor two ways behind one dispatcher:

``histogram_level_pallas``
    The TPU-native scatter-add: a batch-tiled Pallas kernel whose
    histogram block stays VMEM-resident across the batch grid dimension
    (zero-initialized on the first tile, accumulated in fp32 on every
    revisit).  Data-dependent vector scatter does not vectorize on the
    VPU, so the scatter is expressed as the classic one-hot contraction —
    rows one-hot in (node, class) weighted by the bootstrap multiplicity,
    columns one-hot in (feature, bin), accumulated with one MXU matmul per
    tile.  The tree axis rides the leading grid dimension (equivalently a
    vmap: it would add the same leading grid dim).

``histogram_level_scatter``
    The XLA path: the SAME one-hot contraction, but as a row-wise
    segment-sum — each sample contributes its precomputed weighted
    (feature, bin) one-hot row (``F*bins`` contiguous floats, built once
    per fit since bins never change across levels) to the ``node*C + y``
    segment.  One window update per sample instead of ``F`` scalar
    scatters amortizes the scatter overhead ~F-fold, and the flops stay
    O(N*F*bins) at every level — deep levels have many nodes, each holding
    few samples, which is exactly where a dense one-hot matmul wastes its
    width.  Exact same fp32 counts as the kernel (integer-valued sums, no
    rounding).

``histogram_level`` picks between them by the level's row count
``nodes * n_classes`` against a crossover threshold; the autotuner
(``kernels/autotune.py``) measures the crossover and the tile sizes per
(n_trees, depth, F, bins, C) signature and persists them in the same
best-config cache the serving engine consults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tree_traverse import (VMEM_BUDGET, resolve_interpret,
                                         vmem_error)

# default tile sizes (the analytic seed; autotune may override)
BLOCK_N = 1024      # batch lanes per tile
BLOCK_R = 512       # (node, class) histogram rows resident per block
BLOCK_COLS = 512    # target (feature, bin) columns per block


def default_block_f(n_features: int, n_bins: int) -> int:
    """Features per column block: as many as keep the one-hot/bin block
    near BLOCK_COLS columns (floor 1 so any signature is runnable)."""
    return max(1, min(n_features, BLOCK_COLS // max(n_bins, 1)))


def _hist_kernel(node_ref, y_ref, w_ref, bins_ref, out_ref, *,
                 n_classes: int, n_bins: int, block_r: int):
    ir = pl.program_id(1)
    ib = pl.program_id(3)
    node = node_ref[0]              # [BN] level-local node ids
    y = y_ref[...]                  # [BN]
    w = w_ref[0]                    # [BN] bootstrap multiplicity (0 = OOB/pad)
    bins = bins_ref[...]            # [BN, BF] bin indices

    # rows: one-hot in (node, class), weighted — local to this row block
    row = node * n_classes + y - ir * block_r
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (node.shape[0], block_r), 1)
    a = jnp.where(row[:, None] == r_iota, w[:, None], 0.0)

    # cols: one-hot in (feature, bin) for this feature block
    b_iota = jax.lax.broadcasted_iota(
        jnp.int32, (bins.shape[0], bins.shape[1], n_bins), 2)
    b = (bins[:, :, None] == b_iota).astype(jnp.float32)
    b = b.reshape(bins.shape[0], bins.shape[1] * n_bins)

    part = jax.lax.dot_general(a, b, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(ib == 0)       # first batch tile zero-inits the resident block
    def _():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += part


def histogram_level_pallas(node: jax.Array, y: jax.Array, w: jax.Array,
                           bins: jax.Array, *, n_nodes: int, n_bins: int,
                           n_classes: int, block_n: int = BLOCK_N,
                           block_r: int = BLOCK_R, block_f: int | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """[T,N] node ids x [N] labels x [T,N] weights x [N,F] bins ->
    [T, n_nodes, F, n_bins, C] fp32 count histograms.

    The batch (grid dim 3, innermost) is dead-padded with w=0 lanes; rows
    (node*C + y) and feature columns are padded to their block sizes and
    sliced off the output.  Weighted counts are integer-valued, so fp32
    accumulation is exact below 2**24 samples per cell.
    """
    T, N = node.shape
    F = bins.shape[1]
    if block_f is None:
        block_f = default_block_f(F, n_bins)
    R = n_nodes * n_classes
    block_r = min(block_r, R)
    block_n = min(block_n, N)
    block_f = min(block_f, F)
    cols = block_f * n_bins

    need = 4 * (block_n * block_r            # one-hot rows
                + 2 * block_n * cols         # bins block + one-hot cols
                + 2 * block_r * cols         # partial + resident hist block
                + block_n * (3 + block_f))   # node/y/w lanes
    if need >= VMEM_BUDGET:
        raise vmem_error(
            "histogram", need,
            f"block_n={block_n} x block_r={block_r} rows x "
            f"block_f={block_f}*{n_bins} cols")

    pad_n = (-N) % block_n
    pad_r = (-R) % block_r
    pad_f = (-F) % block_f
    node = jnp.pad(node.astype(jnp.int32), ((0, 0), (0, pad_n)))
    y = jnp.pad(y.astype(jnp.int32), (0, pad_n))
    w = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, pad_n)))
    bins = jnp.pad(bins.astype(jnp.int32), ((0, pad_n), (0, pad_f)))
    Np, Fp, Rp = N + pad_n, F + pad_f, R + pad_r

    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_classes=n_classes, n_bins=n_bins,
                          block_r=block_r),
        grid=(T, Rp // block_r, Fp // block_f, Np // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda t, ir, jf, ib: (t, ib)),
            pl.BlockSpec((block_n,), lambda t, ir, jf, ib: (ib,)),
            pl.BlockSpec((1, block_n), lambda t, ir, jf, ib: (t, ib)),
            pl.BlockSpec((block_n, block_f), lambda t, ir, jf, ib: (ib, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_r, cols),
                               lambda t, ir, jf, ib: (t, ir, jf)),
        out_shape=jax.ShapeDtypeStruct((T, Rp, Fp * n_bins), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(node, y, w, bins)

    out = out[:, :R, : F * n_bins]
    out = out.reshape(T, n_nodes, n_classes, F, n_bins)
    return jnp.transpose(out, (0, 1, 3, 4, 2))      # [T, nodes, F, B, C]


def onehot_rows(bins: jax.Array, w: jax.Array, n_bins: int) -> jax.Array:
    """The level-invariant half of the scatter path, built once per fit:
    ``wu[t, n] = w[t, n] * onehot(bins[n])`` — each sample's weighted
    (feature, bin) one-hot row ``[F * n_bins]``, shared by every level's
    segment-sum."""
    N, F = bins.shape
    u = (bins.astype(jnp.int32)[:, :, None]
         == jnp.arange(n_bins)).astype(jnp.float32)
    u = u.reshape(N, F * n_bins)
    return w.astype(jnp.float32)[:, :, None] * u[None]


def histogram_level_scatter(node: jax.Array, y: jax.Array, w: jax.Array,
                            bins: jax.Array, *, n_nodes: int, n_bins: int,
                            n_classes: int,
                            wu: jax.Array | None = None) -> jax.Array:
    """XLA segment-sum path: identical [T, n_nodes, F, n_bins, C] counts
    via one ``F * n_bins``-row window update per sample per tree.

    ``wu`` is the precomputed :func:`onehot_rows` output; pass it when
    calling once per level (the trainer does) so the one-hot rows are
    built once per fit instead of once per level."""
    T, N = node.shape
    F = bins.shape[1]
    if wu is None:
        wu = onehot_rows(bins, w, n_bins)
    seg = node.astype(jnp.int32) * n_classes + y.astype(jnp.int32)[None, :]

    def one(seg_t, wu_t):
        return jax.ops.segment_sum(wu_t, seg_t,
                                   num_segments=n_nodes * n_classes)

    out = jax.vmap(one)(seg, wu)                 # [T, nodes*C, F*n_bins]
    out = out.reshape(T, n_nodes, n_classes, F, n_bins)
    return jnp.transpose(out, (0, 1, 3, 4, 2))   # [T, nodes, F, B, C]


def histogram_level(node: jax.Array, y: jax.Array, w: jax.Array,
                    bins: jax.Array, *, n_nodes: int, n_bins: int,
                    n_classes: int, matmul_max_r: int = 0,
                    block_n: int = BLOCK_N, block_r: int = BLOCK_R,
                    block_f: int | None = None,
                    interpret: bool | None = None,
                    wu: jax.Array | None = None) -> jax.Array:
    """The trainer's per-level histogram: the Pallas one-hot kernel while
    the level's ``n_nodes * n_classes`` row count is at most
    ``matmul_max_r`` (few wide nodes — matmul territory), else the XLA
    segment-sum path (many thin nodes).  Both produce identical counts;
    the crossover and tile sizes come from ``kernels.autotune``."""
    if n_nodes * n_classes <= matmul_max_r:
        return histogram_level_pallas(
            node, y, w, bins, n_nodes=n_nodes, n_bins=n_bins,
            n_classes=n_classes, block_n=block_n, block_r=block_r,
            block_f=block_f, interpret=interpret)
    return histogram_level_scatter(node, y, w, bins, n_nodes=n_nodes,
                                   n_bins=n_bins, n_classes=n_classes,
                                   wu=wu)
