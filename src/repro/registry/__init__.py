"""Multi-tenant model registry: versioned artifacts, hot-swap, canary
routing (:mod:`.registry`) and the VMEM-budgeted resident pack set
(:mod:`.cache`)."""
from repro.registry.cache import CacheStats, PackCache
from repro.registry.registry import ModelRegistry, TenantState

__all__ = ["CacheStats", "ModelRegistry", "PackCache", "TenantState"]
