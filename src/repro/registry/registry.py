"""ModelRegistry — multi-tenant versioned forest artifacts behind one process.

The paper's energy wins only matter at fleet scale if one serving process
can host *many* fields of groves at once: real edge fleets serve several
models per device, and drift retraining (the Adaptive-RF direction) needs
zero-downtime swaps.  This module is that substrate.

A registry roots a directory tree of **tenants** (named models), each with
monotonically versioned ``.npz`` artifacts (the exact
:meth:`~repro.forest.pack.ForestPack.save` format ``FogClassifier`` writes)
and one ``MANIFEST.json`` naming the live version:

    root/
      alpha/
        MANIFEST.json        {"live": 2, "canary": null, "versions": [1, 2]}
        v00001.npz
        v00002.npz
      beta/
        ...

Every mutation is atomic: artifacts and manifests are written to a temp
file and ``os.replace``'d into place, so a crashed publish can never leave
a tenant pointing at a half-written model.  :meth:`publish` is a
**hot-swap**: the manifest flips to the new version in one in-memory +
on-disk step, in-flight requests keep the version they were assigned at
slot time (the batcher pins ``Request.version`` on slot assignment), and
new requests route to the new live — no draining, no request loss.

Traffic-split rollout: ``publish(tenant, model, canary=0.05)`` keeps the
old live and routes a deterministic hash-split of requests
(:meth:`route`) to the new version.  Per-version
:class:`~repro.serve.scheduler.ServeStats` telemetry (fed by the batcher)
makes the canary judgeable — :meth:`judge_canary` compares live vs canary
mean hops/nJ — and :meth:`promote` / :meth:`abort_canary` settle it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import zlib
from pathlib import Path

MANIFEST = "MANIFEST.json"
_TENANT_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


@dataclasses.dataclass
class TenantState:
    """One tenant's manifest: the live version, an optional canary split,
    and every version ever published (artifacts are kept for rollback)."""

    live: int | None = None
    canary_version: int | None = None
    canary_fraction: float = 0.0
    versions: list[int] = dataclasses.field(default_factory=list)

    def to_json(self) -> dict:
        canary = (None if self.canary_version is None else
                  {"version": self.canary_version,
                   "fraction": self.canary_fraction})
        return {"live": self.live, "canary": canary,
                "versions": list(self.versions)}

    @classmethod
    def from_json(cls, d: dict) -> "TenantState":
        c = d.get("canary") or {}
        return cls(live=d.get("live"),
                   canary_version=c.get("version"),
                   canary_fraction=float(c.get("fraction", 0.0)),
                   versions=[int(v) for v in d.get("versions", [])])


class ModelRegistry:
    """Versioned multi-tenant artifact store + deterministic traffic router.

    root:  the registry directory (created on first publish).  Existing
           tenants' manifests are loaded eagerly, so a fresh process serves
           exactly what the last one published.
    """

    def __init__(self, root):
        self.root = Path(root)
        self._tenants: dict[str, TenantState] = {}
        # per-(tenant, version) serving telemetry, fed by the batcher —
        # the evidence judge_canary weighs.  In-memory only: telemetry is
        # a property of this serving process, not of the artifact store.
        self._stats: dict[tuple[str, int], object] = {}
        if self.root.is_dir():
            for mf in sorted(self.root.glob(f"*/{MANIFEST}")):
                self._tenants[mf.parent.name] = TenantState.from_json(
                    json.loads(mf.read_text()))

    # -- introspection -----------------------------------------------------
    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    def versions(self, tenant: str) -> list[int]:
        return list(self._state(tenant).versions)

    def live_version(self, tenant: str) -> int:
        live = self._state(tenant).live
        if live is None:
            raise ValueError(f"tenant {tenant!r} has no live version")
        return live

    def canary(self, tenant: str) -> tuple[int, float] | None:
        st = self._state(tenant)
        if st.canary_version is None:
            return None
        return st.canary_version, st.canary_fraction

    def artifact_path(self, tenant: str, version: int) -> Path:
        return self.root / tenant / f"v{int(version):05d}.npz"

    def _state(self, tenant: str) -> TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            raise ValueError(f"unknown tenant {tenant!r}; published tenants: "
                             f"{self.tenants() or 'none'}")
        return st

    # -- publish / rollback / canary lifecycle ----------------------------
    def publish(self, tenant: str, model, *, canary: float | None = None,
                extra: dict | None = None) -> int:
        """Write ``model`` as the tenant's next version, atomically.

        ``model`` is anything with the ForestPack ``save(path)`` contract
        (a :class:`~repro.forest.pack.ForestPack` or a fitted
        ``FogClassifier``).  Without ``canary`` the new version becomes
        live immediately (hot-swap).  With ``canary=f`` (0 < f < 1) the
        old live keeps serving and a deterministic ``f`` fraction of
        request traffic routes to the new version until :meth:`promote`
        or :meth:`abort_canary`.
        """
        if not _TENANT_RE.match(tenant):
            raise ValueError(
                f"invalid tenant name {tenant!r} (letters, digits, '.', "
                "'_', '-'; must not start with a separator)")
        if canary is not None and not 0.0 < canary < 1.0:
            raise ValueError(f"canary fraction must be in (0, 1), "
                             f"got {canary}")
        st = self._tenants.setdefault(tenant, TenantState())
        if canary is not None and st.live is None:
            raise ValueError(
                f"tenant {tenant!r} has no live version to canary against; "
                "first publish must be a full publish")
        version = (max(st.versions) + 1) if st.versions else 1
        tdir = self.root / tenant
        tdir.mkdir(parents=True, exist_ok=True)
        final = self.artifact_path(tenant, version)
        tmp = tdir / f".{final.name}.tmp"
        from repro.forest.pack import ForestPack
        try:
            if isinstance(model, ForestPack):
                model.save(tmp, extra=extra)
            else:
                model.save(tmp)                    # FogClassifier facade
            os.replace(tmp, final)                 # atomic: all or nothing
        finally:
            tmp.unlink(missing_ok=True)
        st.versions.append(version)
        if canary is None:
            st.live = version
            st.canary_version, st.canary_fraction = None, 0.0
        else:
            st.canary_version, st.canary_fraction = version, float(canary)
        self._write_manifest(tenant, st)
        return version

    def rollback(self, tenant: str, to_version: int | None = None) -> int:
        """Flip live back to ``to_version`` (default: the version published
        before the current live).  Any active canary is aborted — a
        rollback is a judgment that the newest code path misbehaves."""
        st = self._state(tenant)
        if st.live is None:
            raise ValueError(f"tenant {tenant!r} has no live version")
        if to_version is None:
            older = [v for v in st.versions if v < st.live]
            if not older:
                raise ValueError(
                    f"tenant {tenant!r} has nothing older than live "
                    f"v{st.live} to roll back to")
            to_version = max(older)
        if to_version not in st.versions:
            raise ValueError(
                f"tenant {tenant!r} has no version {to_version}; "
                f"published: {st.versions}")
        st.live = int(to_version)
        st.canary_version, st.canary_fraction = None, 0.0
        self._write_manifest(tenant, st)
        return st.live

    def promote(self, tenant: str) -> int:
        """Make the canary version live (ends the split)."""
        st = self._state(tenant)
        if st.canary_version is None:
            raise ValueError(f"tenant {tenant!r} has no active canary")
        st.live = st.canary_version
        st.canary_version, st.canary_fraction = None, 0.0
        self._write_manifest(tenant, st)
        return st.live

    def abort_canary(self, tenant: str) -> None:
        """End the split without promoting (the artifact stays on disk)."""
        st = self._state(tenant)
        st.canary_version, st.canary_fraction = None, 0.0
        self._write_manifest(tenant, st)

    def _write_manifest(self, tenant: str, st: TenantState) -> None:
        mf = self.root / tenant / MANIFEST
        tmp = mf.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(st.to_json(), indent=1))
        os.replace(tmp, mf)

    # -- request routing ---------------------------------------------------
    def route(self, tenant: str, rid) -> int:
        """The version serving request ``rid``: the live version, or — with
        an active canary — the canary version for a deterministic hash
        split of the id space.  Pure function of (tenant, rid, manifest):
        the same request always lands on the same side of the split, and
        retries don't flap across versions."""
        st = self._state(tenant)
        if st.live is None:
            raise ValueError(f"tenant {tenant!r} has no live version")
        if st.canary_version is not None:
            h = zlib.crc32(f"{tenant}/{rid}".encode()) % 10_000
            if h < st.canary_fraction * 10_000:
                return st.canary_version
        return st.live

    # -- artifact loading --------------------------------------------------
    def load(self, tenant: str, version: int | None = None):
        """(ForestPack, extra dict) for one tenant version (default live)."""
        from repro.forest.pack import ForestPack
        if version is None:
            version = self.live_version(tenant)
        path = self.artifact_path(tenant, version)
        if not path.is_file():
            raise ValueError(
                f"tenant {tenant!r} v{version}: artifact {path} is missing "
                "(registry directory moved or pruned?)")
        return ForestPack.load_with_meta(path)

    # -- per-version telemetry --------------------------------------------
    def stats_for(self, tenant: str, version: int):
        """The (tenant, version) ServeStats bucket (created on first use);
        the batcher feeds it per decoded event when registry-routed."""
        from repro.serve.scheduler import ServeStats
        key = (tenant, int(version))
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = ServeStats()
        return st

    def judge_canary(self, tenant: str) -> dict:
        """Live-vs-canary evidence: per-version event counts, mean hops and
        mean nJ.  ``delta_nj`` < 0 means the canary is cheaper."""
        st = self._state(tenant)
        if st.canary_version is None:
            raise ValueError(f"tenant {tenant!r} has no active canary")
        live, cny = (self.stats_for(tenant, st.live),
                     self.stats_for(tenant, st.canary_version))
        return {
            "live_version": st.live, "canary_version": st.canary_version,
            "canary_fraction": st.canary_fraction,
            "live": {"n_events": live.n_events, "mean_hops": live.mean_hops,
                     "mean_nj": live.mean_energy_nj},
            "canary": {"n_events": cny.n_events, "mean_hops": cny.mean_hops,
                       "mean_nj": cny.mean_energy_nj},
            "delta_nj": cny.mean_energy_nj - live.mean_energy_nj,
        }
