"""PackCache — VMEM-aware resident set of packed tables, by traffic weight.

The fused kernel pins a whole :class:`~repro.forest.pack.ForestPack` in
VMEM, so a multi-tenant process cannot keep every (tenant, version,
precision) combination resident — the cache holds the byte budget the
accelerator actually has (``ForestPack.table_bytes`` accounting; int8
tables pack ~4x the fields of fp32, which is the densification lever) and
evicts the *least-trafficked* pack when a load would overflow it.

Eviction is safe by construction: dropping a cache entry only releases the
cache's reference — any replica holding the pack for an in-flight dispatch
keeps its own reference until harvest, and an evicted pack reloads lazily
from its registry artifact on the next request that needs it (a miss, not
an error).

Traffic weighting is an exponentially-decayed hit counter: every hit adds
1 to the entry's weight, every *miss* (a load event — the only moment
eviction can happen) decays all weights by ``decay``, so a tenant that
went quiet an hour ago cannot pin tables a currently-hot tenant needs.
Two refinements keep the pure-LFU failure modes out:

* a fresh entry is seeded at the *mean* resident weight, not 1.0 — else a
  newly-published version's bucket is always the eviction minimum and a
  stale heavyweight can thrash it in and out of residency forever;
* eviction prefers **stale versions** — buckets whose version is neither
  live nor canary for their tenant (a hot-swap or promote demoted them) —
  over live buckets, whatever their historical weight.  The old version's
  tables are exactly what a swap should release first.

Per-device placement rides the same entries: :meth:`device_pack` lazily
``jax.device_put``\\ s one committed copy per replica device and drops the
copies with the entry at eviction.  Replicas are symmetric (every device
holds the same resident set), so the budget models ONE device's VMEM.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)

    def reset(self) -> None:
        self.hits = self.misses = self.evictions = 0


@dataclasses.dataclass
class _Entry:
    pack: object
    nbytes: int
    weight: float = 1.0
    # device-index -> committed replica copy (dropped with the entry)
    device_copies: dict = dataclasses.field(default_factory=dict)


class PackCache:
    """Budgeted (tenant, version, precision) -> ForestPack resident set.

    registry:      the :class:`~repro.registry.registry.ModelRegistry`
                   artifacts reload from on a miss
    budget_bytes:  the VMEM byte budget packed tables may occupy (per
                   device — replicas hold symmetric resident sets)
    decay:         per-miss multiplicative decay of every entry's traffic
                   weight (1.0 = pure hit counts, no recency)
    """

    def __init__(self, registry, budget_bytes: int, *, decay: float = 0.97):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be positive")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.registry = registry
        self.budget_bytes = int(budget_bytes)
        self.decay = float(decay)
        self._entries: dict[tuple, _Entry] = {}
        self.stats = CacheStats()
        self.peak_bytes = 0

    # -- accounting --------------------------------------------------------
    @property
    def bytes_used(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def keys(self) -> list[tuple]:
        return list(self._entries)

    def weight_of(self, tenant: str, version: int, precision: str) -> float:
        return self._entries[(tenant, int(version), precision)].weight

    # -- the lookup path ---------------------------------------------------
    def get(self, tenant: str, version: int, precision: str = "fp32"):
        """The resident pack for one bucket, loading (and evicting) on a
        miss.  The returned pack is host/default-device; replicas use
        :meth:`device_pack` for committed per-device copies."""
        key = (tenant, int(version), precision)
        entry = self._entries.get(key)
        if entry is not None:
            entry.weight += 1.0
            self.stats.hits += 1
            return entry.pack
        self.stats.misses += 1
        pack, _ = self.registry.load(tenant, version)
        if pack.precision != precision:
            # the artifact's dtype is the publisher's choice; the serving
            # bucket's dtype is the request's — repack on the way in
            pack = pack.astype(precision)
        nbytes = pack.table_bytes
        if nbytes > self.budget_bytes:
            raise ValueError(
                f"pack ({tenant!r}, v{version}, {precision}) needs "
                f"{nbytes} bytes but the whole cache budget is "
                f"{self.budget_bytes} — raise the budget or publish at a "
                "denser precision (int8 tables are ~4x smaller than fp32)")
        for e in self._entries.values():
            e.weight *= self.decay
        self._evict_down_to(self.budget_bytes - nbytes)
        # seed at the mean resident weight: the newcomer competes fairly
        # instead of being the guaranteed eviction minimum (weight 1.0 vs
        # incumbents' accumulated hit counts would thrash every
        # newly-published version straight back out)
        seed = 1.0
        if self._entries:
            seed = (sum(e.weight for e in self._entries.values())
                    / len(self._entries))
        self._entries[key] = _Entry(pack, nbytes, weight=seed)
        self.peak_bytes = max(self.peak_bytes, self.bytes_used)
        return pack

    def device_pack(self, tenant: str, version: int, precision: str,
                    index: int, device):
        """One replica's committed copy of the bucket's pack (placed on
        first use, cached on the entry, dropped at eviction)."""
        import jax
        pack = self.get(tenant, version, precision)
        entry = self._entries[(tenant, int(version), precision)]
        copy = entry.device_copies.get(index)
        if copy is None:
            copy = entry.device_copies[index] = jax.device_put(pack, device)
        return copy

    def _stale(self, key: tuple) -> bool:
        """Is this bucket's version demoted — neither live nor canary for
        its tenant?  Stale versions are the first eviction candidates: a
        hot-swap's whole point is releasing the old version's tables, and
        their historical traffic weight must not pin them."""
        tenant, version, _ = key
        try:
            st = self.registry._state(tenant)
        except ValueError:
            return True                      # tenant gone entirely
        return version != st.live and version != st.canary_version

    def _evict_down_to(self, limit: int) -> None:
        """Drop entries until ``bytes_used <= limit``: stale versions
        first, then lowest traffic weight (ties broken by insertion
        order: oldest goes first)."""
        while self._entries and self.bytes_used > limit:
            key = min(self._entries,
                      key=lambda k: (not self._stale(k),
                                     self._entries[k].weight))
            del self._entries[key]
            self.stats.evictions += 1

    def evict(self, tenant: str, version: int, precision: str) -> bool:
        """Explicitly drop one bucket (e.g. a rolled-back version)."""
        return self._entries.pop((tenant, int(version), precision),
                                 None) is not None

    def summary(self) -> str:
        return (f"{len(self._entries)} packs, {self.bytes_used}/"
                f"{self.budget_bytes} B (peak {self.peak_bytes}), "
                f"hit rate {self.stats.hit_rate:.3f} "
                f"({self.stats.hits}h/{self.stats.misses}m/"
                f"{self.stats.evictions}e)")
