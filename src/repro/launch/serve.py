"""Serving driver: continuous batching + optional FoG early-exit decode.

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8 [--fog --thresh 0.3]

``--smoke`` serves the reduced config on host devices; the full config +
production mesh path goes through serve/decode.make_serve_step (the same
functions the dry-run lowers).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.policy import FogPolicy
from repro.data.lm_data import DataConfig, batch_at_step
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog, grove_boundaries, lm_hop_energy
from repro.serve.governor import EnergyGovernor
from repro.serve.scheduler import ContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fog", action="store_true")
    ap.add_argument("--fog-backend", default="reference",
                    choices=["reference", "pallas", "fused"],
                    help="engine backend for the exit gate (kernel-flavored "
                         "choices route the pallas top-2 margin kernel)")
    ap.add_argument("--thresh", type=float, default=0.3)
    ap.add_argument("--fog-precision", default=None,
                    choices=["fp32", "bf16", "int8"],
                    help="default FogPolicy precision stamped on the "
                         "batcher (forest-backed decode_fns read it to pick "
                         "their packed tables; this LM layer-grove gate has "
                         "no forest tables and ignores it); requests may "
                         "override per-policy — the batcher dispatches one "
                         "program per precision group")
    ap.add_argument("--hop-budget", type=int, default=None,
                    help="per-request grove budget (anytime decoding cap)")
    ap.add_argument("--energy-budget-nj", type=float, default=None,
                    help="serving SLO: rolling nJ/classification target — "
                         "installs an EnergyGovernor that walks a "
                         "threshold-tightening / hop-capping ladder when "
                         "the rolling estimate breaches the budget "
                         "(energy priced by the LM layer-grove FLOP proxy, "
                         "models/fog_exit.lm_hop_energy)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.energy_budget_nj is not None and not args.fog:
        # without --fog the decode step reports no hop telemetry: the
        # governor would be a silent no-op, which is worse than an error
        ap.error("--energy-budget-nj requires --fog (the governor needs "
                 "the FoG decode path's hop telemetry)")

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    if cfg.frontend:
        raise SystemExit(f"{cfg.name}: stub-frontend archs serve via "
                         "precomputed embeddings; use serve/decode.py directly")
    params = T.init_params(cfg, jax.random.key(args.seed), jnp.float32)
    caches = T.cache_init(cfg, args.slots, args.max_seq, jnp.float32)
    state = {"caches": caches}

    def prefill_fn(slot: int, prompt: np.ndarray) -> int:
        _, c = T.prefill(params, cfg, tokens=jnp.asarray(prompt)[None, :],
                         max_seq=args.max_seq)
        def splice(batch_leaf, row_leaf):
            for ax in range(batch_leaf.ndim):
                if batch_leaf.shape[ax] == args.slots and row_leaf.shape[ax] == 1:
                    sl = [slice(None)] * batch_leaf.ndim
                    sl[ax] = slice(slot, slot + 1)
                    for sax in range(batch_leaf.ndim):
                        if sax != ax and row_leaf.shape[sax] != batch_leaf.shape[sax]:
                            sl[sax] = slice(0, row_leaf.shape[sax])
                    return batch_leaf.at[tuple(sl)].set(row_leaf)
            return batch_leaf
        state["caches"] = jax.tree.map(splice, state["caches"], c)
        return len(prompt)

    default_policy = FogPolicy(threshold=args.thresh,
                               hop_budget=args.hop_budget,
                               backend=args.fog_backend,
                               precision=args.fog_precision)

    def decode_fn(tokens, lengths, policy):
        # policy: the batcher's per-lane assembly of each slot's QoS contract
        length = jnp.int32(int(np.asarray(lengths).max()))
        if args.fog:
            logits, state["caches"], hops = decode_step_fog(
                params, cfg, tokens, state["caches"], length, policy)
            return logits, hops
        logits, state["caches"] = T.decode_step(params, cfg, tokens,
                                                state["caches"], length)
        return logits, None

    governor = None
    if args.energy_budget_nj is not None:
        model = lm_hop_energy(cfg)
        t = args.thresh
        # quality-descending LM ladder: tighten the exit threshold, then
        # cap hops at whatever the budget affords (int8 rungs are moot —
        # the layer-grove gate has no packed forest tables).  An explicit
        # --hop-budget stays a ceiling on every rung: the bottom rung may
        # only TIGHTEN it, or the ladder would stop descending
        cap = model.hops_within(args.energy_budget_nj * 1e3)
        if args.hop_budget is not None:
            cap = min(cap, args.hop_budget)
        ladder = [default_policy,
                  default_policy.replace(threshold=t * 0.5),
                  default_policy.replace(threshold=t * 0.25),
                  default_policy.replace(threshold=t * 0.25,
                                         hop_budget=cap)]
        governor = EnergyGovernor(ladder, args.energy_budget_nj,
                                  model=model, window=max(args.slots * 4, 16))
    batcher = ContinuousBatcher(args.slots, decode_fn, prefill_fn, eos_id=-1,
                                default_policy=default_policy,
                                governor=governor)
    dcfg = DataConfig(cfg.vocab_size, 32, 8, seed=args.seed + 7)
    for rid in range(args.requests):
        prompt = batch_at_step(dcfg, rid)["tokens"][0, :24] % cfg.vocab_size
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=args.max_new))
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s)")
    if args.fog:
        g = len(grove_boundaries(cfg))
        for r in sorted(done, key=lambda r: r.rid):
            h = np.asarray(r.hops, np.float64)
            print(f"  req {r.rid}: groves/token {h.mean():.2f} "
                  f"(flops frac {h.mean() / g:.2f})")
        print(f"[serve] fleet {batcher.stats.summary(g)}")
        if governor is not None:
            print(f"[serve] governor {governor.summary()}")


if __name__ == "__main__":
    main()
