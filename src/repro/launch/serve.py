"""Serving driver: continuous batching + optional FoG early-exit decode.

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8 [--fog --thresh 0.3]

``--smoke`` serves the reduced config on host devices; the full config +
production mesh path goes through serve/decode.make_serve_step (the same
functions the dry-run lowers).

``--devices N`` serves data-parallel: the params are replicated per device,
each device owns the KV caches for a fixed span of slots, and the batcher
fans each step out through a :class:`~repro.serve.dispatch.DeviceDispatcher`
(on CPU, export ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
first).  ``--max-queue`` / ``--shed-policy`` expose the admission-control
knobs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.policy import BACKENDS, PRECISIONS, FogPolicy
from repro.data.lm_data import DataConfig, batch_at_step
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog, grove_boundaries, lm_hop_energy
from repro.serve.governor import EnergyGovernor
from repro.serve.scheduler import ContinuousBatcher, Request


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI (a function so tests can assert the choices stay in
    sync with the engine's registries — see the --fog-backend regression)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM architecture to serve (required unless "
                         "--registry selects forest serving)")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="serve forest models from a ModelRegistry "
                         "directory instead of an LM: multi-tenant "
                         "(model, version, precision)-bucketed dispatch "
                         "through a VMEM-budgeted PackCache")
    ap.add_argument("--tenant", action="append", default=None,
                    help="registry tenant(s) to drive demo traffic at "
                         "(repeatable; default: every published tenant)")
    ap.add_argument("--cache-budget-mb", type=float, default=64.0,
                    help="PackCache VMEM byte budget for resident packed "
                         "tables (registry mode)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fog", action="store_true")
    ap.add_argument("--fog-backend", default="reference",
                    choices=list(BACKENDS),
                    help="engine backend for the exit gate (kernel-flavored "
                         "choices route the pallas top-2 margin kernel; "
                         "'ring' additionally needs a grove mesh)")
    ap.add_argument("--thresh", type=float, default=0.3)
    ap.add_argument("--fog-precision", default=None,
                    choices=list(PRECISIONS),
                    help="default FogPolicy precision stamped on the "
                         "batcher (forest-backed decode_fns read it to pick "
                         "their packed tables; this LM layer-grove gate has "
                         "no forest tables and ignores it); requests may "
                         "override per-policy — the batcher dispatches one "
                         "program per precision group")
    ap.add_argument("--hop-budget", type=int, default=None,
                    help="per-request grove budget (anytime decoding cap)")
    ap.add_argument("--energy-budget-nj", type=float, default=None,
                    help="serving SLO: rolling nJ/classification target — "
                         "installs an EnergyGovernor that walks a "
                         "threshold-tightening / hop-capping ladder when "
                         "the rolling estimate breaches the budget "
                         "(energy priced by the LM layer-grove FLOP proxy, "
                         "models/fog_exit.lm_hop_energy)")
    ap.add_argument("--devices", type=int, default=1,
                    help="data-parallel serving: replicate params over the "
                         "first N local devices and shard the slot batch "
                         "across them (CPU: export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: bound the request queue "
                         "(default unbounded)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "oldest"],
                    help="who is shed when the queue is full")
    ap.add_argument("--sync", action="store_true",
                    help="registry mode: synchronous packed step instead "
                         "of the default double-buffered pipeline (host "
                         "bookkeeping overlapped with device compute)")
    ap.add_argument("--telemetry-every", type=int, default=None,
                    help="registry mode: replay buffered telemetry every "
                         "k harvests instead of per step (default 8; the "
                         "LM decode path accounts inline and only "
                         "accepts 1)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _splice_row(batch_leaf, row_leaf, slot: int, n_slots: int):
    """Write a 1-row prefill cache leaf into lane ``slot`` of a batched
    cache leaf (axis found by its ``n_slots`` extent)."""
    for ax in range(batch_leaf.ndim):
        if batch_leaf.shape[ax] == n_slots and row_leaf.shape[ax] == 1:
            sl = [slice(None)] * batch_leaf.ndim
            sl[ax] = slice(slot, slot + 1)
            for sax in range(batch_leaf.ndim):
                if sax != ax and row_leaf.shape[sax] != batch_leaf.shape[sax]:
                    sl[sax] = slice(0, row_leaf.shape[sax])
            return batch_leaf.at[tuple(sl)].set(row_leaf)
    return batch_leaf


def _serve_registry(args) -> None:
    """Registry demo: N tenants' live forests behind one batcher, mixed
    per-request precisions, per-tenant energy governors when an SLO is
    given.  Feature rows are synthetic (the demo exercises the serving
    plane, not the datasets)."""
    from repro.registry import ModelRegistry, PackCache
    from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer
    from repro.serve.governor import TenantLedger, default_ladder

    registry = ModelRegistry(args.registry)
    tenants = args.tenant or registry.tenants()
    if not tenants:
        raise SystemExit(f"registry {args.registry} has no tenants; "
                         "publish one with ModelRegistry.publish first")
    cache = PackCache(registry,
                      budget_bytes=int(args.cache_budget_mb * 2**20))
    pack0, extra0 = registry.load(tenants[0])
    n_features = int(extra0.get("n_features_in",
                                int(np.asarray(pack0.feature).max()) + 1))
    server = ForestReplicaServer(None, n_features,
                                 backend=args.fog_backend
                                 if args.fog_backend != "reference"
                                 else "fused",
                                 registry=registry, cache=cache)
    if args.devices > 1:
        from repro.launch.mesh import serve_devices
        devices = serve_devices(args.devices)
    else:
        devices = jax.devices()[:1]
    # the packed replica protocol: device-resident slot state + fused
    # dispatch, which is what makes the pipelined step safe
    dispatcher = DeviceDispatcher(server.packed_factory, devices)

    default_policy = FogPolicy(threshold=args.thresh,
                               hop_budget=args.hop_budget,
                               precision=args.fog_precision)
    ledger = None
    if args.energy_budget_nj is not None:
        ledger = TenantLedger()
        for t in tenants:
            model = server.energy_model(tenant=t)
            ledger.add(t, EnergyGovernor(
                default_ladder(default_policy, model,
                               args.energy_budget_nj),
                args.energy_budget_nj, model=model,
                window=max(args.slots * 4, 16)))
    batcher = ContinuousBatcher(args.slots, None, server.prefill, eos_id=-1,
                                default_policy=default_policy,
                                governor=ledger, dispatcher=dispatcher,
                                registry=registry,
                                max_queue=args.max_queue,
                                shed_policy=args.shed_policy,
                                pipeline=not args.sync,
                                telemetry_every=(args.telemetry_every
                                                 if args.telemetry_every
                                                 is not None else 8))
    rng = np.random.default_rng(args.seed)
    admitted = 0
    for rid in range(args.requests):
        t = tenants[rid % len(tenants)]
        admitted += batcher.submit(Request(
            rid=rid, prompt=rng.standard_normal(n_features), model=t,
            max_new_tokens=1))
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    print(f"[serve] registry {args.registry}: {len(done)}/{admitted} "
          f"requests over {len(tenants)} tenants in {dt:.2f}s")
    for t in tenants:
        v = registry.live_version(t)
        st = registry.stats_for(t, v)
        print(f"  {t} v{v}: {st.n_events} events, "
              f"mean hops {st.mean_hops:.2f}"
              + (f", {st.mean_energy_nj:.3f} nJ/event"
                 if st.has_energy else ""))
    print(f"[serve] cache {cache.summary()}")
    if ledger is not None:
        print(f"[serve] ledger\n{ledger.summary()}")


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.registry is not None:
        _serve_registry(args)
        return
    if args.arch is None:
        ap.error("--arch is required (or pass --registry DIR for "
                 "forest-registry serving)")
    if args.energy_budget_nj is not None and not args.fog:
        # without --fog the decode step reports no hop telemetry: the
        # governor would be a silent no-op, which is worse than an error
        ap.error("--energy-budget-nj requires --fog (the governor needs "
                 "the FoG decode path's hop telemetry)")
    if args.devices > 1 and args.slots % args.devices:
        ap.error(f"--slots {args.slots} must divide evenly over "
                 f"--devices {args.devices} (fixed per-device spans)")
    if args.telemetry_every is not None and args.telemetry_every != 1:
        ap.error("--telemetry-every > 1 needs the packed registry plane "
                 "(--registry DIR); the LM decode path accounts inline")

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    if cfg.frontend:
        raise SystemExit(f"{cfg.name}: stub-frontend archs serve via "
                         "precomputed embeddings; use serve/decode.py directly")
    params = T.init_params(cfg, jax.random.key(args.seed), jnp.float32)

    default_policy = FogPolicy(threshold=args.thresh,
                               hop_budget=args.hop_budget,
                               backend=args.fog_backend,
                               precision=args.fog_precision)

    dispatcher = None
    if args.devices > 1:
        from repro.launch.mesh import serve_devices
        from repro.serve.dispatch import DeviceDispatcher

        devices = serve_devices(args.devices)
        # one replica per device: its own committed params copy and the KV
        # caches for its span of slots — lanes never migrate, so a prefill
        # touches exactly one device's cache
        states: dict[int, dict] = {}

        def factory(index, device, span):
            params_d = jax.device_put(params, device)
            caches_d = jax.device_put(
                T.cache_init(cfg, span, args.max_seq, jnp.float32), device)
            states[index] = {"caches": caches_d, "device": device}

            def decode(tokens, lengths, policy):
                length = jnp.int32(int(np.asarray(lengths).max()))
                tk = jax.device_put(jnp.asarray(tokens), device)
                if args.fog:
                    logits, states[index]["caches"], hops = decode_step_fog(
                        params_d, cfg, tk, states[index]["caches"], length,
                        policy)
                    return logits, hops
                logits, states[index]["caches"] = T.decode_step(
                    params_d, cfg, tk, states[index]["caches"], length)
                return logits, None

            return decode

        dispatcher = DeviceDispatcher(factory, devices)
        span = args.slots // args.devices

        def prefill_fn(slot: int, prompt: np.ndarray) -> int:
            _, c = T.prefill(params, cfg,
                             tokens=jnp.asarray(prompt)[None, :],
                             max_seq=args.max_seq)
            st = states[slot // span]
            st["caches"] = jax.tree.map(
                lambda b, r: _splice_row(b, r, slot % span, span),
                st["caches"], jax.device_put(c, st["device"]))
            return len(prompt)

        decode_fn = None
    else:
        caches = T.cache_init(cfg, args.slots, args.max_seq, jnp.float32)
        state = {"caches": caches}

        def prefill_fn(slot: int, prompt: np.ndarray) -> int:
            _, c = T.prefill(params, cfg,
                             tokens=jnp.asarray(prompt)[None, :],
                             max_seq=args.max_seq)
            state["caches"] = jax.tree.map(
                lambda b, r: _splice_row(b, r, slot, args.slots),
                state["caches"], c)
            return len(prompt)

        def decode_fn(tokens, lengths, policy):
            # policy: the batcher's per-lane assembly of the slots' QoS
            # contracts
            length = jnp.int32(int(np.asarray(lengths).max()))
            if args.fog:
                logits, state["caches"], hops = decode_step_fog(
                    params, cfg, tokens, state["caches"], length, policy)
                return logits, hops
            logits, state["caches"] = T.decode_step(params, cfg, tokens,
                                                    state["caches"], length)
            return logits, None

    governor = None
    if args.energy_budget_nj is not None:
        model = lm_hop_energy(cfg)
        t = args.thresh
        # quality-descending LM ladder: tighten the exit threshold, then
        # cap hops at whatever the budget affords (int8 rungs are moot —
        # the layer-grove gate has no packed forest tables).  An explicit
        # --hop-budget stays a ceiling on every rung: the bottom rung may
        # only TIGHTEN it, or the ladder would stop descending
        cap = model.hops_within(args.energy_budget_nj * 1e3)
        if args.hop_budget is not None:
            cap = min(cap, args.hop_budget)
        ladder = [default_policy,
                  default_policy.replace(threshold=t * 0.5),
                  default_policy.replace(threshold=t * 0.25),
                  default_policy.replace(threshold=t * 0.25,
                                         hop_budget=cap)]
        governor = EnergyGovernor(ladder, args.energy_budget_nj,
                                  model=model, window=max(args.slots * 4, 16))
    batcher = ContinuousBatcher(args.slots, decode_fn, prefill_fn, eos_id=-1,
                                default_policy=default_policy,
                                governor=governor, dispatcher=dispatcher,
                                max_queue=args.max_queue,
                                shed_policy=args.shed_policy)
    dcfg = DataConfig(cfg.vocab_size, 32, 8, seed=args.seed + 7)
    admitted = 0
    for rid in range(args.requests):
        prompt = batch_at_step(dcfg, rid)["tokens"][0, :24] % cfg.vocab_size
        admitted += batcher.submit(Request(rid=rid, prompt=prompt,
                                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)}/{admitted} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s)"
          + (f" on {args.devices} devices" if args.devices > 1 else ""))
    if batcher.stats.n_shed:
        print(f"[serve] admission shed {batcher.stats.n_shed}/"
              f"{batcher.stats.n_offered} "
              f"({100 * batcher.stats.shed_rate:.1f}%)")
    if args.fog:
        g = len(grove_boundaries(cfg))
        for r in sorted(done, key=lambda r: r.rid):
            h = np.asarray(r.hops, np.float64)
            print(f"  req {r.rid}: groves/token {h.mean():.2f} "
                  f"(flops frac {h.mean() / g:.2f})")
        print(f"[serve] fleet {batcher.stats.summary(g)}")
        if governor is not None:
            print(f"[serve] governor {governor.summary()}")


if __name__ == "__main__":
    main()
