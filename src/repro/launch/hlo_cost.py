"""HLO-level roofline-term extraction from a compiled dry-run artifact.

This is the *compiled-program* cost model behind ``launch/dryrun.py`` (the
LM decode/train dry-run path): it parses post-optimization HLO text and
multiplies while-loop bodies by their trip counts.  The FoG evaluation
backends have a purpose-built ANALYTIC model instead — dtype-aware bytes
moved per hop for each backend — in :mod:`repro.launch.roofline`; use that
for anything touching BENCH_engine.json.

  compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak, v5e]
  memory     = HLO_bytes / (chips * 819e9)           [HBM bw]
  collective = collective_bytes / (chips * 4 * 50e9) [4 ICI links/chip]

``cost_analysis`` under-counts bodies of ``while`` loops (counted once), so
we also parse the HLO text: every while loop whose trip count is recoverable
from its induction-variable compare gets its body FLOPs multiplied out.
Analytic 6ND is reported alongside as the useful-FLOPs yardstick.
"""
from __future__ import annotations

import dataclasses
import re

# ---- TPU v5e hardware constants (per chip) ----
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # links per chip on a 2D torus


_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array literals in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum output-operand bytes of every collective op, by kind.

    Uses the op's *result* shape (the payload that crosses the wire at least
    once; exact wire bytes depend on algorithm — ring AR moves 2x payload —
    so this is the standard lower bound).
    While-loop bodies appear once in the text; trip-count scaling is applied
    by the caller via ``scale_loops``.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # result type is between '=' and the op name
        lhs, rhs = line.split("=", 1)
        rtype = rhs.strip().split(" ")[0]
        out[kind] = out.get(kind, 0) + _shape_bytes(rtype)
    return out


class HloCostModel:
    """Static call-graph cost model over post-optimization HLO text.

    ``compiled.cost_analysis()`` counts every computation once, so a
    22-layer ``lax.scan`` under-reports FLOPs ~22x.  This model walks the
    call graph — while bodies scaled by the ``known_trip_count`` in their
    backend_config, fusions/calls inlined — and counts:

      * flops: 2 * numel(out) * contracted-size for every dot/convolution
      * bytes: operand + result buffer bytes at top-level-op granularity
        (fusion boundaries = the HBM traffic model: intra-fusion traffic
        stays in registers/VMEM)
      * collective_bytes: result bytes per collective kind

    all multiplied along the call chain.
    """

    _DEF_RE = re.compile(
        r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
        r"(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
        r"([\w\-]+)\(")
    _COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
    _TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
    _CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
    _COND_RE = re.compile(r"condition=%?([\w.\-]+)")
    _BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
    _CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
    _OPERANDS_RE = re.compile(r"\(([^)]*)\)")

    _FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "iota"}

    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[str]] = {}
        self.entry: str | None = None
        cur: list[str] | None = None
        for line in hlo_text.splitlines():
            m = self._COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = []
                self.computations[m.group(1)] = cur
                if line.startswith("ENTRY"):
                    self.entry = m.group(1)
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None and "=" in line:
                cur.append(line)
        self._memo: dict[str, tuple[float, float, dict]] = {}
        self._slice_memo: dict[str, dict[int, float]] = {}

    def _shape_of(self, type_str: str) -> int:
        return _shape_bytes(type_str)

    def _line_types(self, line: str) -> str:
        return line

    def _comp_cost(self, name: str) -> tuple[float, float, dict]:
        """(flops, bytes, collective_by_kind) for one execution of `name`."""
        if name in self._memo:
            return self._memo[name]
        flops = 0.0
        byts = 0.0
        coll: dict[str, float] = {}
        symtab: dict[str, str] = {}
        lines = self.computations.get(name, [])
        for line in lines:
            dm = self._DEF_RE.match(line)
            if not dm:
                continue
            out_name, out_type, op = dm.groups()
            symtab[out_name] = out_type
            if op in self._FREE_OPS:
                continue
            out_bytes = self._shape_of(out_type)

            if op == "dynamic-slice":
                # traffic = the slice read + written, NOT the sliced buffer
                byts += 2 * out_bytes
                continue
            if op == "dynamic-update-slice":
                # traffic = update region read + written (in-place update);
                # out type is the FULL buffer, so use the update operand
                ops_m = self._OPERANDS_RE.search(line[dm.end() - 1:])
                upd_bytes = out_bytes
                if ops_m:
                    names = [n.strip().lstrip("%") for n in ops_m.group(1).split(",")]
                    if len(names) >= 2 and names[1] in symtab:
                        upd_bytes = self._shape_of(symtab[names[1]])
                byts += 2 * upd_bytes
                continue
            if op in ("gather", "scatter"):
                byts += 2 * out_bytes
                continue
            if op == "dot":
                # contracted size from lhs operand type x contracting dims
                ops_m = self._OPERANDS_RE.search(line[dm.end() - 1:])
                contracted = 1
                if ops_m:
                    first = ops_m.group(1).split(",")[0].strip().lstrip("%")
                    lhs_type = symtab.get(first, "")
                    cm = self._CONTRACT_RE.search(line)
                    if cm and lhs_type:
                        dims_m = _SHAPE_RE.search(lhs_type)
                        if dims_m and dims_m.group(2):
                            dims = [int(d) for d in dims_m.group(2).split(",")]
                            for i in (cm.group(1).split(",") if cm.group(1) else []):
                                contracted *= dims[int(i)]
                out_elems = out_bytes / max(
                    _DTYPE_BYTES.get(_SHAPE_RE.search(out_type).group(1), 4), 1) \
                    if _SHAPE_RE.search(out_type) else 0
                flops += 2.0 * out_elems * contracted
                byts += out_bytes + self._operand_bytes(line, dm, symtab)
            elif op == "convolution":
                # rare here; approximate as out_elems * 2 * kernel_elems
                byts += out_bytes + self._operand_bytes(line, dm, symtab)
            elif op == "while":
                body = self._CALL_RE.search(line)
                trip = 1
                tm = self._TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                if body:
                    bf, bb, bc = self._comp_cost(body.group(1))
                    flops += trip * bf
                    byts += trip * bb
                    for k, v in bc.items():
                        coll[k] = coll.get(k, 0.0) + trip * v
                cond = self._COND_RE.search(line)
                if cond:
                    cf, cb, cc = self._comp_cost(cond.group(1))
                    flops += trip * cf
                    byts += trip * cb
            elif op in ("fusion", "call", "custom-call", "async-start"):
                cm = self._CALL_RE.search(line)
                if cm:
                    bf, bb, bc = self._comp_cost(cm.group(1))
                    flops += bf
                    # fusion boundary: traffic is the fusion's operands+result,
                    # NOT the inner ops' buffers.  Operands that the fused
                    # computation only dynamic-slices (scan reading one layer
                    # of a stacked param/residual buffer) count as the slice,
                    # not the whole stack.
                    byts += out_bytes + self._fusion_operand_bytes(
                        line, dm, symtab, cm.group(1))
                    for k, v in bc.items():
                        coll[k] = coll.get(k, 0.0) + v
                else:
                    byts += out_bytes + self._operand_bytes(line, dm, symtab)
            elif op == "conditional":
                bm = self._BRANCH_RE.search(line)
                if bm:
                    branch_costs = [self._comp_cost(b.strip().lstrip("%"))
                                    for b in bm.group(1).split(",")]
                    if branch_costs:
                        # static bound: the most expensive branch
                        best = max(branch_costs, key=lambda t: t[0])
                        flops += best[0]
                        byts += best[1]
                        for k, v in best[2].items():
                            coll[k] = coll.get(k, 0.0) + v
            else:
                cmm = _COLLECTIVE_RE.search(op)
                if cmm:
                    kind = cmm.group(1)
                    coll[kind] = coll.get(kind, 0.0) + out_bytes
                byts += out_bytes + self._operand_bytes(line, dm, symtab)
        self._memo[name] = (flops, byts, coll)
        return self._memo[name]

    def _param_slice_bytes(self, comp_name: str) -> dict[int, float]:
        """For a fused computation: param index -> effective read bytes, for
        params whose ONLY consumers are dynamic-slice ops."""
        if comp_name in self._slice_memo:
            return self._slice_memo[comp_name]
        lines = self.computations.get(comp_name, [])
        params: dict[str, int] = {}
        ptype: dict[str, str] = {}
        for line in lines:
            pm = re.match(r"^\s+%?([\w.\-]+)\s*=\s*(\S+\[[^\]]*\](?:\{[^}]*\})?)"
                          r"\s+parameter\((\d+)\)", line)
            if pm:
                params[pm.group(1)] = int(pm.group(3))
                ptype[pm.group(1)] = pm.group(2)
        out: dict[int, float] = {}
        for pname, pidx in params.items():
            slice_bytes = 0.0
            ok = True
            for line in lines:
                if pname not in line:
                    continue
                if f"%{pname} = " in line or line.strip().startswith(f"{pname} ="):
                    continue
                dm2 = self._DEF_RE.match(line)
                if not dm2:
                    continue
                # is pname actually an operand here?
                if not re.search(rf"[(,]\s*%?{re.escape(pname)}\s*[,)]", line):
                    continue
                if dm2.group(3) == "dynamic-slice":
                    slice_bytes += self._shape_of(dm2.group(2))
                else:
                    ok = False
                    break
            if ok and slice_bytes:
                out[pidx] = slice_bytes
        self._slice_memo[comp_name] = out
        return out

    def _fusion_operand_bytes(self, line: str, dm, symtab: dict,
                              called: str) -> float:
        ops_m = self._OPERANDS_RE.search(line[dm.end() - 1:])
        if not ops_m:
            return 0.0
        slice_map = self._param_slice_bytes(called)
        total = 0.0
        for i, nm in enumerate(ops_m.group(1).split(",")):
            nm = nm.strip().lstrip("%")
            if i in slice_map:
                total += slice_map[i]
            elif nm in symtab:
                total += self._shape_of(symtab[nm])
        return total

    def _operand_bytes(self, line: str, dm, symtab: dict) -> float:
        ops_m = self._OPERANDS_RE.search(line[dm.end() - 1:])
        if not ops_m:
            return 0.0
        total = 0.0
        for nm in ops_m.group(1).split(","):
            nm = nm.strip().lstrip("%")
            if nm in symtab:
                total += self._shape_of(symtab[nm])
        return total

    def totals(self) -> dict:
        # fusion computations are reached via their callers; entry is root
        if not self.entry:
            return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                    "collective_by_kind": {}}
        f, b, c = self._comp_cost(self.entry)
        return {"flops": f, "bytes": b,
                "collective_bytes": float(sum(c.values())),
                "collective_by_kind": c}

    # ---- fused-attention projection -------------------------------------
    def _multiplicities(self) -> dict[str, float]:
        """Execution count per computation along the call graph."""
        mult: dict[str, float] = {}

        def walk(name: str, k: float) -> None:
            mult[name] = mult.get(name, 0.0) + k
            for line in self.computations.get(name, []):
                dm = self._DEF_RE.match(line)
                if not dm:
                    continue
                op = dm.group(3)
                if op == "while":
                    tm = self._TRIP_RE.search(line)
                    trip = int(tm.group(1)) if tm else 1
                    bm = self._CALL_RE.search(line)
                    cm = self._COND_RE.search(line)
                    if bm:
                        walk(bm.group(1), k * trip)
                    if cm:
                        walk(cm.group(1), k * trip)
                elif op in ("fusion", "call", "custom-call"):
                    cm2 = self._CALL_RE.search(line)
                    if cm2:
                        # boundary op: called computation contributes flops
                        # but its buffers are internal — no byte walk needed
                        pass

        walk(self.entry, 1.0) if self.entry else None
        return mult

    def tile_bytes(self, tile_dims: tuple[int, int]) -> float:
        """HBM traffic of ops whose result is a [.., blk_q, blk_k]
        attention tile — the traffic a fused Pallas flash-attention kernel
        keeps in VMEM (see kernels/flash_attention.py)."""
        want = {tile_dims, (tile_dims[1], tile_dims[0])}
        mult = self._multiplicities()
        total = 0.0
        for name, lines in self.computations.items():
            k = mult.get(name)
            if not k:
                continue
            symtab: dict[str, str] = {}
            for line in lines:
                dm = self._DEF_RE.match(line)
                if not dm:
                    continue
                out_name, out_type, op = dm.groups()
                symtab[out_name] = out_type
                if op in self._FREE_OPS or op == "while":
                    continue

                def trailing(ts: str) -> tuple | None:
                    m2 = _SHAPE_RE.search(ts)
                    if not m2 or not m2.group(2):
                        return None
                    dims = [int(d) for d in m2.group(2).split(",")]
                    return tuple(dims[-2:]) if len(dims) >= 2 else None

                contrib = 0.0
                if trailing(out_type) in want:
                    contrib += self._shape_of(out_type)
                ops_m = self._OPERANDS_RE.search(line[dm.end() - 1:])
                if ops_m:
                    for nm in ops_m.group(1).split(","):
                        nm = nm.strip().lstrip("%")
                        t = symtab.get(nm)
                        if t and trailing(t) in want:
                            contrib += self._shape_of(t)
                total += k * contrib
        return total


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_kind: dict
    model_flops: float            # analytic 6ND (or serve equivalent)
    bytes_per_device: float       # peak from memory_analysis
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    tile_bytes: float = 0.0   # attention-tile traffic (fused-kernel removable)

    @property
    def memory_s_fused(self) -> float:
        """Memory term with flash-attention tiles resident in VMEM."""
        return max(self.hlo_bytes - self.tile_bytes, 0.0) / HBM_BW

    def finalize(self) -> "RooflineTerms":
        # HLO quantities are PER-DEVICE (the compiled module is the
        # post-SPMD per-chip program): divide by per-chip peaks only.
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / (ICI_LINKS * ICI_BW)
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound (sum) — reported alongside max()."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """model FLOPs per chip / compiled FLOPs per chip (remat, causal
        masking waste, and routing overhead push this below 1)."""
        per_chip = self.model_flops / self.chips
        return per_chip / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS/(chips*peak) / step_time — 'MFU at the bound'."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.1f} | {self.memory_s*1e3:.1f} | "
                f"{self.collective_s*1e3:.1f} | {self.dominant} | "
                f"{self.model_flops:.3g} | {self.useful_flops_ratio:.2f} | "
                f"{self.roofline_fraction:.2f} |")


def analytic_model_flops(cfg, shape_name: str) -> float:
    """6ND for train; 2ND per generated token for decode; 2ND_prompt for
    prefill.  N = active params (MoE-aware)."""
    from repro.configs.base import param_count
    from repro.train.loop import SHAPES
    sp = SHAPES[shape_name]
    _, active = param_count(cfg)
    tokens = sp.global_batch * sp.seq_len
    if sp.kind == "train":
        return 6.0 * active * tokens
    if sp.kind == "prefill":
        return 2.0 * active * tokens
    # decode: one token per sequence + attention reads over the cache
    flops = 2.0 * active * sp.global_batch
    if not cfg.ssm:
        hd = cfg.resolved_head_dim
        kv_flops = 4.0 * cfg.n_layers * cfg.n_heads * hd * sp.seq_len
        flops += kv_flops * sp.global_batch
    return flops


def extract(compiled, lowered_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, cfg) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    model = HloCostModel(lowered_text)
    tot = model.totals()
    # trip-count-scaled numbers; raw cost_analysis kept as the lower bound
    flops = max(tot["flops"], raw_flops)
    byts = max(tot["bytes"], raw_bytes)
    coll = tot["collective_by_kind"] or collective_bytes_from_hlo(lowered_text)
    # fused-attention projection: [blk_q, blk_k] tiles resident in VMEM
    # when attention runs as the Pallas kernel (validated separately)
    tile_b = model.tile_bytes((512, 1024))
    mem = compiled.memory_analysis()
    bytes_per_dev = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0) + \
        getattr(mem, "output_size_in_bytes", 0) - \
        getattr(mem, "alias_size_in_bytes", 0)
    terms = RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(sum(coll.values())),
        collective_by_kind=coll,
        model_flops=analytic_model_flops(cfg, shape),
        bytes_per_device=float(bytes_per_dev),
    ).finalize()
    terms.tile_bytes = tile_b
    return terms
