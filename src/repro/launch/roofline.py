"""FoG roofline model: dtype-aware bytes-moved per backend vs machine peaks.

The paper's energy claim is a traffic claim — FoG wins because the grove
walk stays on-chip — so every latency we publish should come with "how far
from the bandwidth bound is that?".  This module answers it analytically,
per backend, from quantities the engine already knows (pack shape, packed
table bytes, hop statistics), instead of parsing compiled HLO: the FoG
kernels' traffic is *designed*, not emergent, so the model is a short
closed form per backend.

Traffic model (per evaluation of a ``[B, F]`` batch):

* **per-hop backends** ("reference", "pallas"): every loop iteration
  re-materializes each lane's grove slice from the packed tables
  (``table_bytes / n_groves`` per lane — dtype-aware: an int8 pack moves a
  quarter of fp32) plus the lane's fp32 row, probability state update and
  loop bookkeeping.  Iterations = ``max_hops`` for the fixed-trip scan,
  the observed max hop count for the lazy while_loop.

* **fused**: the tables are pinned ONCE per launch (× chunks when the
  engine auto-chunks) and per-lane state crosses HBM once — in: row +
  start/thresh/budget/live; out: proba + hops.  Hop count doesn't multiply
  HBM traffic at all; that is the whole point of the kernel.

* **ring**: fused-style per-shard pinning plus the rotation's collective
  bytes (probability state crossing ICI ``iters`` times).

FLOPs: a lane-hop walks one grove per head — ``O·t`` trees × (2 ops per
level × depth + C leaf accumulates) — plus the MaxDiff gate (~``8·O·C``).
Compute lane-hops are ``Σ hops`` when the backend skips exited lanes
(fused-compacted) and ``B × iters`` when it computes dead lanes anyway.

``bound`` is whichever of ``bytes/peak_bw`` and ``flops/peak_flops`` is
slower; ``achieved`` = ideal over measured.  Machine peaks come from a
:class:`MachineSpec` — pass your own to re-rate for new hardware; the
bundled specs cover the TPU v5e target and an order-of-magnitude host-CPU
stand-in for the interpret-mode container (whose achieved % is honestly
tiny: the interpreted kernel is a correctness vehicle, not a fast path).

The LM dry-run HLO cost model that used to live here is first-class in
:mod:`repro.launch.hlo_cost`; importing its names from here still works
behind a ``DeprecationWarning`` (see ``__getattr__`` at the bottom).
"""
from __future__ import annotations

import dataclasses
import warnings


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Peak rates the roofline is drawn against."""
    name: str
    peak_flops: float    # FLOP/s
    peak_bw: float       # HBM (main-memory) bytes/s
    ici_bw: float = 0.0  # interconnect bytes/s (ring backend); 0 = ignore


# TPU v5e per chip: bf16 MXU peak and HBM bandwidth (the deploy target the
# kernels are written for)
TPU_V5E = MachineSpec("tpu-v5e", peak_flops=197e12, peak_bw=819e9,
                      ici_bw=4 * 50e9)

# order-of-magnitude stand-in for the CPU container the interpret-mode
# kernels run in; override with a measured spec for real host numbers
HOST_CPU = MachineSpec("host-cpu", peak_flops=1e11, peak_bw=5e10)

SPECS = {s.name: s for s in (TPU_V5E, HOST_CPU)}

# fixed per-lane bookkeeping bytes a per-hop iteration touches: live mask,
# hop counter read+write, threshold and budget reads
_LANE_LOOP_BYTES = 20
# per-lane one-time fused traffic besides the fp32 row and outputs:
# start + thresh + budget (4 B each) + int8 live mask
_LANE_FUSED_IN_BYTES = 13


@dataclasses.dataclass(frozen=True)
class RooflineEstimate:
    """One backend's modeled traffic/compute and the resulting bound."""
    backend: str
    spec: MachineSpec
    bytes_moved: float
    flops: float

    @property
    def memory_s(self) -> float:
        return self.bytes_moved / self.spec.peak_bw

    @property
    def compute_s(self) -> float:
        return self.flops / self.spec.peak_flops

    @property
    def bound(self) -> str:
        return "memory" if self.memory_s >= self.compute_s else "compute"

    @property
    def ideal_s(self) -> float:
        """No-overlap roofline time: the slower of the two terms."""
        return max(self.memory_s, self.compute_s)

    def achieved(self, measured_s: float) -> float:
        """Fraction of the roofline the measurement reaches (0 when the
        measurement is missing/zero — never a division error)."""
        if not measured_s or measured_s <= 0 or self.ideal_s <= 0:
            return 0.0
        return self.ideal_s / measured_s

    def to_dict(self, measured_s: float | None = None) -> dict:
        d = {"backend": self.backend, "spec": self.spec.name,
             "bytes_moved": self.bytes_moved, "flops": self.flops,
             "memory_s": self.memory_s, "compute_s": self.compute_s,
             "bound": self.bound, "ideal_s": self.ideal_s}
        if measured_s is not None:
            d["achieved_pct"] = round(100.0 * self.achieved(measured_s), 4)
        return d


class RooflineModel:
    """Analytic FoG roofline for one packed field of groves.

    pack:       a :class:`~repro.forest.pack.ForestPack` — supplies the
                head/grove/tree/class shape and the dtype-aware table bytes
    n_features: width of the input rows
    spec:       :class:`MachineSpec` (default: the TPU v5e target)
    """

    def __init__(self, pack, n_features: int,
                 spec: MachineSpec | str = TPU_V5E):
        self.pack = pack
        self.n_features = int(n_features)
        self.spec = SPECS[spec] if isinstance(spec, str) else spec

    # -- per-unit terms ---------------------------------------------------
    @property
    def lane_hop_flops(self) -> float:
        """Walk one grove per head for one lane: O·t trees × (compare +
        index update per level + C leaf accumulates), plus the MaxDiff
        gate over the [O, C] state."""
        p = self.pack
        walk = p.n_heads * p.grove_size * (2 * p.depth + p.n_classes)
        gate = 8 * p.n_heads * p.n_classes
        return float(walk + gate)

    @property
    def lane_hop_bytes(self) -> float:
        """Per-hop-backend traffic for one lane in one iteration: its
        grove's slice of the packed tables (dtype-aware), the fp32 row,
        the [O, C] fp32 probability state read+written, bookkeeping."""
        p = self.pack
        return (p.table_bytes / p.n_groves
                + 4 * self.n_features
                + 8 * p.n_heads * p.n_classes
                + _LANE_LOOP_BYTES)

    @property
    def lane_io_bytes(self) -> float:
        """Fused per-lane one-time HBM traffic: fp32 row + scalar knobs in,
        fp32 [O, C] proba + int32 hops out."""
        p = self.pack
        return (4 * self.n_features + _LANE_FUSED_IN_BYTES
                + 4 * p.n_heads * p.n_classes + 4)

    # -- per-backend estimates -------------------------------------------
    def estimate(self, backend: str, batch: int, *, iters: float,
                 hops_total: float | None = None, chunks: int = 1,
                 compact: bool = False) -> RooflineEstimate:
        """Model one evaluation.

        iters:      loop trip count the backend executed — ``max_hops``
                    for the fixed-trip scan backends, the observed max hop
                    count for early-exit loops (lazy reference, fused)
        hops_total: Σ per-lane hops (``batch × mean_hops``); defaults to
                    ``batch × iters`` (no early exit)
        chunks:     fused launches per evaluation (engine auto-chunking
                    re-pins the tables per chunk)
        compact:    fused live-lane compaction — compute scales with
                    Σ hops instead of batch × iters

        ``backend`` may be any engine row name (``"fused-compact"``,
        ``"pallas-chunked"``, ...); the traffic class is derived from its
        root (``fused*`` pins tables once per launch, ``ring*`` adds the
        ICI hop state, everything else — reference / reference-lazy /
        pallas — streams tables per hop) while the estimate reports the
        full name, so benchmark rows keep their own labels.
        """
        p = self.pack
        B = float(batch)
        if hops_total is None:
            hops_total = B * iters
        root = backend.split("-")[0]
        if root == "fused":
            byts = chunks * p.table_bytes + B * self.lane_io_bytes
            lane_hops = hops_total if compact else B * iters
            flops = lane_hops * self.lane_hop_flops
        elif root == "ring":
            # per-shard pin + the probability state crossing ICI every hop
            byts = chunks * p.table_bytes + B * self.lane_io_bytes
            flops = B * iters * self.lane_hop_flops
        else:  # per-hop backends: reference / reference-lazy / pallas
            byts = B * iters * self.lane_hop_bytes
            flops = B * iters * self.lane_hop_flops
        return RooflineEstimate(backend=backend, spec=self.spec,
                                bytes_moved=float(byts), flops=float(flops))


# --------------------------------------------------------------------------
# deprecation shim: the LM dry-run HLO cost model moved to launch/hlo_cost
# --------------------------------------------------------------------------

_MOVED = ("PEAK_FLOPS", "HBM_BW", "ICI_BW", "ICI_LINKS", "HloCostModel",
          "RooflineTerms", "analytic_model_flops", "extract",
          "collective_bytes_from_hlo", "_shape_bytes", "_SHAPE_RE",
          "_DTYPE_BYTES", "_COLLECTIVE_RE")


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.launch.roofline.{name} moved to repro.launch.hlo_cost; "
            "repro.launch.roofline is now the FoG-specific RooflineModel",
            DeprecationWarning, stacklevel=2)
        from repro.launch import hlo_cost
        return getattr(hlo_cost, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
