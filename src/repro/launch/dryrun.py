import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks device count at first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the sharded step (train_step for train shapes,
prefill/decode for serving shapes), lowers it with abstract inputs
(ShapeDtypeStruct — zero allocation), compiles it for the production mesh,
and records memory_analysis / cost_analysis / collective schedule for the
roofline report.  A failure here (sharding mismatch, OOM at compile,
unsupported collective) is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out results.jsonl]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_cost as R
from repro.train.loop import SHAPES, input_specs, make_train_step_lowerable, shape_supported
from repro import compat


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                fog: bool = False, accum_steps: int = 1,
                verbose: bool = True) -> dict:
    """Lower+compile one cell; returns a result record (raises on failure)."""
    cfg = get_arch(arch)
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True, "why": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    sp = SHAPES[shape]
    t0 = time.time()

    with compat.set_mesh(mesh):
        if sp.kind == "train":
            jitted, (params_shape, opt_shape, batch_shape) = \
                make_train_step_lowerable(cfg, mesh, shape,
                                          accum_steps=accum_steps)
            lowered = jitted.lower(params_shape, opt_shape, batch_shape)
        elif sp.kind == "prefill":
            from repro.serve.decode import make_prefill_step
            jitted, (params_shape, inp) = make_prefill_step(cfg, mesh, shape)
            key = "embeds" if cfg.frontend else "tokens"
            lowered = jitted.lower(params_shape, inp[key])
        else:  # decode
            from functools import partial
            import jax.numpy as jnp
            from repro.models import transformer as T
            from repro.serve.decode import make_serve_step
            jitted, (params_shape, cache_shape, inp) = make_serve_step(
                cfg, mesh, shape, fog=fog)
            x_shape = inp["embeds"] if cfg.frontend else inp["token"]
            # fog decode takes the per-lane runtime knobs as traced inputs
            knobs = (inp["fog_thresh"], inp["fog_budget"]) if fog else ()
            lowered = jitted.lower(params_shape, cache_shape,
                                   x_shape, inp["length"], *knobs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    text = compiled.as_text()
    terms = R.extract(compiled, text, arch=arch, shape=shape,
                      mesh_name=mesh_name, chips=chips, cfg=cfg)
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "fog": fog, "accum_steps": accum_steps, "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": terms.hlo_flops, "hlo_bytes": terms.hlo_bytes,
        "collective_bytes": terms.collective_bytes,
        "collective_by_kind": terms.collective_by_kind,
        "model_flops": terms.model_flops,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "tile_bytes": terms.tile_bytes,
        "memory_s_fused": terms.memory_s_fused,
        "useful_flops_ratio": terms.useful_flops_ratio,
        "roofline_fraction": terms.roofline_fraction,
        "bytes_per_device": terms.bytes_per_device,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape} x {mesh_name}"
              f"{' (fog)' if fog else ''}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"compute {terms.compute_s*1e3:.1f}ms "
              f"memory {terms.memory_s*1e3:.1f}ms "
              f"collective {terms.collective_s*1e3:.1f}ms "
              f"-> {terms.dominant}-bound | "
              f"temp/dev {rec['temp_bytes'] and rec['temp_bytes']/2**30:.2f}GiB",
              flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--fog", action="store_true",
                    help="lower the FoG early-exit decode step")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    cells: list[tuple[str, str]]
    if args.all:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch + --shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multipod,
                              fog=args.fog)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, str(e)))
            rec = {"arch": arch, "shape": shape, "error": str(e)}
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        return 1
    print("all cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
