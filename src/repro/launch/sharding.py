"""Sharding rules: parameter/optimizer/activation PartitionSpecs per arch.

Policy (DESIGN.md §6):
  * TP on the `model` axis: attention heads, d_ff, vocab, MoE experts (EP),
    Mamba d_inner/state/heads.  Any dim not divisible by the axis size
    falls back to replication for that dim (e.g. MQA's single KV head).
  * FSDP (ZeRO-3) on the `data` axis for archs >= `fsdp_threshold` params:
    each param's largest remaining dim is additionally sharded over `data`;
    XLA inserts the all-gather-on-use / reduce-scatter-on-grad pair.
  * The `pod` axis is pure DP: parameters replicated across pods, gradients
    all-reduced over it once per step (optionally int8-compressed).
  * MoE with n_experts < model-axis size uses TP-within-expert instead
    (shard d_ff of each expert): grok's 8 experts on a 16-wide axis.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, param_count
from repro.launch.mesh import dp_axes, model_axis_size

FSDP_THRESHOLD = 8e9   # params; above this, weights are FSDP-sharded


def _maybe(axis: str | None, dim: int, axis_size: int):
    """Use `axis` for a dim only if it divides evenly."""
    if axis is None or axis_size <= 1 or dim % axis_size != 0:
        return None
    return axis


def use_fsdp(cfg: ArchConfig) -> bool:
    return param_count(cfg)[0] >= FSDP_THRESHOLD


def _leaf_spec(path: tuple, shape: tuple[int, ...], cfg: ArchConfig,
               mesh, fsdp: bool) -> P:
    msize = model_axis_size(mesh)
    dsize = mesh.shape.get("data", 1)
    names = [getattr(k, "key", getattr(k, "name", None)) or str(getattr(k, "idx", ""))
             for k in path]
    name = names[-1]
    in_stack = "stack" in names
    # stack leaves carry a leading [n_repeat] axis that is never sharded
    core_shape = shape[1:] if in_stack else shape
    d_axis = "data" if fsdp else None

    def spec(*parts) -> P:
        parts = tuple(parts)
        assert len(parts) == len(core_shape), (name, parts, core_shape)
        return P(None, *parts) if in_stack else P(*parts)

    m = lambda i, ax="model": _maybe(ax, core_shape[i], msize)
    dd = lambda i: _maybe(d_axis, core_shape[i], dsize)

    # ---- embeddings ----
    if name == "embed":
        # odd vocabularies (minicpm3 73448, mamba2 50280) don't divide 16:
        # fall back to sharding d_model
        if _maybe("model", core_shape[0], msize):
            return spec("model", dd(1))
        return spec(dd(0), m(1))
    if name == "unembed":
        if _maybe("model", core_shape[1], msize):
            return spec(dd(0), "model")
        return spec(m(0), dd(1))
    # ---- vectors / norms ----
    if len(core_shape) == 1:
        if name in ("A_log", "D", "dt_bias"):
            return spec(m(0))
        if name in ("conv_b_x", "norm"):
            return spec(m(0))
        return spec(None)
    # ---- attention ----
    if name == "wq":
        # few-head models (gemma: 8 heads < 16-way TP): shard d_model
        # instead (partial-sum AR on the projection — small vs replication)
        if _maybe("model", core_shape[1], msize):
            return spec(dd(0), "model", None)
        return spec(m(0), None, None)
    if name in ("wk", "wv"):
        return spec(dd(0), m(1), None)
    if name == "wo":
        if _maybe("model", core_shape[0], msize):
            return spec("model", None, dd(2))
        return spec(None, None, m(2))
    # ---- MLA ----
    # head counts that don't divide the TP width (minicpm3: 40 heads on a
    # 16-wide axis) fall back to sharding the lora rank / d_model
    if name == "w_dq":
        return spec(dd(0), m(1))
    if name == "w_dkv":
        # packed [d, rkv + dr]: keep dim 1 whole (the c_kv/k_rope split at
        # rkv wouldn't align with shard boundaries); it's small anyway
        return spec(dd(0), None)
    if name in ("w_uq", "w_uk", "w_uv"):
        if _maybe("model", core_shape[1], msize):
            return spec(dd(0), "model", None)
        return spec(m(0), None, None)
    if name == "w_o":
        if _maybe("model", core_shape[0], msize):
            return spec("model", None, dd(2))
        return spec(None, None, m(2))
    # ---- MoE ----
    if name == "router":
        # [d, E]: deepseek's 58-layer stacked router is 106M params —
        # shard the expert dim (top_k then all-gathers [B,S,E] logits)
        return spec(None, m(1))
    if len(core_shape) == 3 and name in ("w_gate", "w_up", "w_down"):
        E = core_shape[0]
        if E % msize == 0 and not cfg.moe_tp_within_expert:  # expert parallelism
            if name == "w_down":
                return spec("model", None, dd(2))
            return spec("model", dd(1), None)
        # TP-within-expert (grok: 8 experts on 16-wide axis)
        if name == "w_down":
            return spec(None, m(1), dd(2))
        return spec(None, dd(1), m(2))
    # ---- dense FFN / shared expert ----
    if name in ("w_gate", "w_up"):
        return spec(dd(0), m(1))
    if name == "w_down":
        return spec(m(0), dd(1))
    # ---- mamba ----
    if name in ("in_z", "in_x"):
        return spec(dd(0), m(1))
    if name in ("in_B", "in_C", "in_dt"):
        return spec(dd(0), m(1))
    if name in ("conv_x",):
        return spec(None, m(1))
    if name in ("conv_B", "conv_C"):
        return spec(None, m(1))
    if name == "out_proj":
        return spec(m(0), dd(1))
    # ---- MTP ----
    if name == "proj":
        return spec(dd(0), m(1))
    return spec(*([None] * len(core_shape)))


def param_shardings(cfg: ArchConfig, mesh, params_shape: Any,
                    fsdp: bool | None = None):
    """PartitionSpec pytree matching ``jax.eval_shape(init_params, ...)``."""
    if fsdp is None:
        fsdp = use_fsdp(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf.shape, cfg, mesh, fsdp),
        params_shape)


def batch_spec(mesh) -> P:
    return P(dp_axes(mesh))


def act_spec(mesh) -> P:
    """[B, S, ...] activations: batch over dp axes."""
    return P(dp_axes(mesh), None)


def cache_shardings(cfg: ArchConfig, mesh, cache_shape: Any):
    """KV-cache specs: batch over dp axes; kv-heads on model when they
    divide, otherwise sequence on model (SP — MQA/MLA long-context)."""
    msize = model_axis_size(mesh)
    dp = dp_axes(mesh)

    def leaf(path, x) -> P:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = names[-1]
        in_stack = "stack" in names
        shape = x.shape[1:] if in_stack else x.shape
        batch_first = (dp if shape[0] % np.prod([mesh.shape[a] for a in dp]) == 0
                       else None)
        if name in ("k", "v"):             # [B, S, K, hd]
            if shape[2] % msize == 0:
                parts = (batch_first, None, "model", None)
            else:
                parts = (batch_first, _maybe("model", shape[1], msize), None, None)
        elif name in ("c_kv", "k_rope"):   # [B, S, r] — SP over seq
            parts = (batch_first, _maybe("model", shape[1], msize), None)
        elif name == "state":              # [B, H, P, N]
            parts = (batch_first, _maybe("model", shape[1], msize), None, None)
        else:                              # conv tails [B, K-1, C]
            parts = (batch_first, None, _maybe("model", shape[2], msize))
        return P(None, *parts) if in_stack else P(*parts)

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)
