"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch: ('pod','data') multi-pod, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
