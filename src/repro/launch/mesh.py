"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def serve_devices(n: int | None = None) -> list:
    """The first ``n`` local devices for the data-parallel serving plane
    (None = all of them).

    The serving tier replicates the model per device and shards the BATCH,
    so it wants a flat device list, not a mesh.  On CPU-only hosts the
    platform exposes one device unless ``XLA_FLAGS=
    --xla_force_host_platform_device_count=N`` is set BEFORE jax first
    initializes — the error message repeats that because by the time this
    raises, it is too late to set it in-process.
    """
    devs = jax.devices()
    if n is None:
        return list(devs)
    if n > len(devs):
        raise ValueError(
            f"serve_devices({n}): only {len(devs)} local devices exist; "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} in the environment before jax initializes")
    return list(devs[:n])


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch: ('pod','data') multi-pod, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
