"""End-to-end training driver.

    python -m repro.launch.train --arch tinyllama-1.1b --steps 300 \
        --smoke --ckpt-dir /tmp/ckpt [--resume]

``--smoke`` runs the reduced config of the same family on the host devices
(what the container can execute); the full config + production mesh path is
the same code with ``--smoke`` omitted (requires the real pod).  Features
exercised either way: sharded params/optimizer, deterministic data pipeline,
heartbeats, periodic async checkpoints, crash-resume.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.data.lm_data import DataConfig, batch_at_step
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.fault import Heartbeat
from repro.train.loop import make_train_step
from repro import compat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    mesh = make_host_mesh()
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} "
          f"B={args.batch} S={args.seq}")

    with compat.set_mesh(mesh):
        step_fn, p_specs, o_specs, init_opt = make_train_step(
            cfg, mesh, lr=args.lr, total_steps=args.steps, donate=False)
        params = T.init_params(cfg, jax.random.key(args.seed), jnp.float32)
        opt_state = init_opt(params)

        start_step = 0
        if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start_step = ckpt.restore(
                (params, opt_state), args.ckpt_dir)
            print(f"[train] resumed from step {start_step}")

        dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
        hb = Heartbeat(args.ckpt_dir, f"host{jax.process_index()}") \
            if args.ckpt_dir else None
        writer = None
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = batch_at_step(dcfg, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.frontend:
                emb = jax.random.normal(
                    jax.random.key(step + 1), (args.batch, args.seq, cfg.d_model),
                    jnp.float32) * 0.02
                batch = {"embeds": emb,
                         "labels": batch["labels"] % cfg.vocab_size}
            else:
                batch = {"tokens": batch["tokens"] % cfg.vocab_size,
                         "labels": batch["labels"] % cfg.vocab_size}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if hb:
                hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                writer = ckpt.save(step + 1, (params, opt_state),
                                   args.ckpt_dir, async_write=True)
        if writer is not None:
            writer.join()
        print(f"[train] done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
