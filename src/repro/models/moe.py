"""Mixture-of-Experts layer (grok-1 8e top-2, deepseek-v3 1+256e top-8,
jamba 16e top-2).

Token-choice top-k routing with per-expert capacity (GShard discipline,
TPU-native): instead of ragged gather/scatter (GPU megablocks style), each
expert selects its top-`capacity` tokens by router score with a vmapped
``lax.top_k`` and computes a dense [E, cap, d] x [E, d, f] grouped einsum —
MXU-shaped, statically bounded, and partitionable with experts on the
`model` mesh axis (EP).  Overflow tokens beyond capacity are dropped (their
residual passes through), underflow slots are masked to zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    init = lambda k, *sh: (jax.random.normal(k, sh) / np.sqrt(sh[-2])).astype(dtype)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * 0.02).astype(jnp.float32),
        "w_gate": init(ks[1], E, d, f),
        "w_up": init(ks[2], E, d, f),
        "w_down": init(ks[3], E, f, d),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init(k1, d, fs), "w_up": init(k2, d, fs),
            "w_down": init(k3, fs, d),
        }
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    cap = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
              / max(cfg.n_experts, 1))
    cap = max(8, (cap + 7) // 8 * 8)    # pad to 8 for TPU lane alignment
    return min(cap, n_tokens)


def _constrain(x: jax.Array, *parts) -> jax.Array:
    """with_sharding_constraint iff an ambient mesh is set (no-op in tests)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    parts = tuple(pp if (pp is None or
                         x.shape[i] % mesh.shape[pp] == 0) else None
                  for i, pp in enumerate(parts))
    return jax.lax.with_sharding_constraint(x, P(*parts))


def moe_ffn(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Routing is GROUP-LOCAL (GShard groups == batch rows): each sequence
    routes its own S tokens with per-row expert capacity.  This keeps every
    gather/scatter *within a data shard* — global-top-k routing would make
    XLA all-gather the full [T, d] token array onto every device (measured:
    457 GiB/device temp for deepseek-v3 train_4k).  Expert compute is a
    grouped einsum with experts sharded on the `model` axis (EP): the
    dispatch crossing data->expert shards is the all-to-all the roofline
    attributes to MoE.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # [B, S, E]
    topv, topi = jax.lax.top_k(probs, k)                          # [B, S, k]
    # renormalize the selected gates (deepseek/mixtral convention)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # token-choice gate matrix: probs masked to each token's top-k
    sel = jnp.zeros((B, S, E), jnp.float32)
    bidx = jnp.arange(B)[:, None, None]
    sidx = jnp.arange(S)[None, :, None]
    sel = sel.at[bidx, sidx, topi].set(topv)                      # [B, S, E]

    # per-(row, expert) capacity selection: top-cap tokens of this row
    escore, eidx = jax.lax.top_k(sel.transpose(0, 2, 1), cap)     # [B, E, cap]
    egate = escore * (escore > 0.0)

    xe = jnp.take_along_axis(x[:, None, :, :],
                             eidx[..., None], axis=2)             # [B, E, cap, d]
    xe = _constrain(xe, "data", "model", None, None)
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])             # [B, E, cap, d]
    ye = ye * egate[..., None].astype(ye.dtype)
    ye = _constrain(ye, "data", "model", None, None)

    out = jnp.zeros((B, S, d), ye.dtype)
    out = out.at[jnp.arange(B)[:, None], eidx.reshape(B, -1)].add(
        ye.reshape(B, E * cap, d), mode="drop")

    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sp["w_down"])

    # load-balance auxiliary loss (Switch):  E * sum_e f_e * P_e
    me = jnp.zeros((B, S, E), jnp.float32).at[
        bidx, sidx, topi].set(1.0).mean((0, 1))                   # fraction routed
    pe = probs.mean((0, 1))                                       # mean router prob
    aux = E * jnp.sum(me * pe)
    return out.astype(x.dtype), aux
