"""Mamba-2 (SSD — state-space duality) layer, chunked scan + O(1) decode.

Training/prefill uses the SSD block decomposition (Dao & Gu 2024): the
sequence is split into chunks of Q tokens; within a chunk the quadratic
"attention-like" form runs on the MXU, across chunks a [H, P, N] state is
passed with an O(S/Q) ``lax.scan`` — sub-quadratic in S, which is what makes
the 512k-token long_500k cell feasible for mamba2/jamba while pure-attention
archs skip it.  Decode advances the recurrent state in O(1) per token: no KV
cache, just [B, H, P, N] state + a d_conv-1 conv tail.

Projections are stored UNPACKED (in_z, in_x, in_B, in_C, in_dt and separate
depthwise convs for x/B/C) rather than as one fused in_proj: the packed
layout's segment boundaries (di | di | N | N | H) don't align with a 16-way
`model` shard of the fused output dim, which would force cross-shard
reslicing after every in_proj.  Unpacked, each matrix shards cleanly on its
own output dim (TP on d_inner / state / heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    ks = jax.random.split(key, 9)
    init = lambda k, *sh: (jax.random.normal(k, sh) / np.sqrt(sh[0])).astype(dtype)
    conv = lambda k, c: (jax.random.normal(k, (K, c)) * 0.2).astype(dtype)
    return {
        "in_z": init(ks[0], d, di),
        "in_x": init(ks[1], d, di),
        "in_B": init(ks[2], d, N),
        "in_C": init(ks[3], d, N),
        "in_dt": init(ks[4], d, H),
        "conv_x": conv(ks[5], di), "conv_b_x": jnp.zeros((di,), dtype),
        "conv_B": conv(ks[6], N), "conv_b_B": jnp.zeros((N,), dtype),
        "conv_C": conv(ks[7], N), "conv_b_C": jnp.zeros((N,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": init(ks[8], di, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d, width K: [B,S,C] -> [B,S,C] (+SiLU)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _conv_step(window: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """One-token conv: window [B,K,C] -> [B,C] (+SiLU)."""
    return jax.nn.silu((window * w[None]).sum(axis=1) + b)


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] -> lower-tri cumulative sums L[i,j] = sum_{j<m<=i} a_m."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # [.., i, j]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                initial_state: jax.Array | None = None,
                use_kernels: bool = False):
    """SSD scan.  x [B,S,H,P], dt [B,S,H], A [H], Bm/Cm [B,S,N] (G=1).

    ``use_kernels=True`` computes the intra-chunk block (y_diag + chunk
    state summaries — all the [Q,Q] tile work) with the fused Pallas
    kernel (kernels/ssd_chunk.py); the inter-chunk recurrence and the
    off-diagonal term stay in jnp either way.

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    a = dt * A[None, None, :]                           # [B,S,H] log-decay (<0)
    xbar = x * dt[..., None]                            # [B,S,H,P]

    # chunk views
    ac = a.reshape(Bsz, nc, Q, H).transpose(0, 1, 3, 2)          # [B,nc,H,Q]
    xc = xbar.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    cum = jnp.cumsum(ac, axis=-1)                                # [B,nc,H,Q]
    total = cum[..., -1:]                                        # [B,nc,H,1]
    if use_kernels:
        from repro.kernels.ssd_chunk import ssd_chunk_pallas
        y_diag_k, states = ssd_chunk_pallas(xc, ac, Bc, Cc)
        y_diag = y_diag_k                                        # [B,nc,Q,H,P]
    else:
        # ---- intra-chunk (quadratic, MXU) ----
        L = jnp.exp(_segsum(ac))                                 # [B,nc,H,Q,Q]
        scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # [B,nc,Q,Q]
        y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                            scores, L, xc)                        # [B,nc,Q,H,P]
        # ---- chunk states ----
        decay_to_end = jnp.exp(total - cum)                      # [B,nc,H,Q]
        states = jnp.einsum("bchj,bcjn,bcjhp->bchpn",
                            decay_to_end, Bc, xc)                 # [B,nc,H,P,N]

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(total[..., 0])                         # [B,nc,H]

    def step(s_prev, inp):
        st, dec = inp                                            # [B,H,P,N], [B,H]
        s_new = s_prev * dec[:, :, None, None].astype(s_prev.dtype) + st
        return s_new, s_prev                                     # emit state BEFORE chunk

    s0 = (jnp.zeros((Bsz, H, P, N), x.dtype) if initial_state is None
          else initial_state)
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # [B,nc,H,P,N]

    # ---- inter-chunk output ----
    in_decay = jnp.exp(cum)                                      # [B,nc,H,Q]
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp",
                       Cc, in_decay, prev_states)                # [B,nc,Q,H,P]

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, Bm: jax.Array, Cm: jax.Array):
    """One-token recurrence.  state [B,H,P,N], x [B,H,P], dt [B,H],
    Bm/Cm [B,N] -> (y [B,H,P], new_state)."""
    decay = jnp.exp(dt * A[None, :])                             # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x)
    state = state * decay[:, :, None, None].astype(state.dtype) + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)
    return y, state


def _rmsnorm_gated(y: jax.Array, z: jax.Array, w: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(y.dtype) * (1.0 + w)


def _project(p, cfg: ArchConfig, x: jax.Array):
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"])
    return z, xs, Bm, Cm, dt


def mamba_forward(p: dict, cfg: ArchConfig, x: jax.Array,
                  initial_state=None, conv_tail=None):
    """Full-sequence forward.  x [B,S,d] -> (out [B,S,d], (state, conv_tails))."""
    Bsz, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt = _project(p, cfg, x)
    if conv_tail is not None:
        tx, tB, tC = conv_tail
        xs_c = _causal_conv(jnp.concatenate([tx, xs], 1), p["conv_x"],
                            p["conv_b_x"])[:, tx.shape[1]:]
        Bm_c = _causal_conv(jnp.concatenate([tB, Bm], 1), p["conv_B"],
                            p["conv_b_B"])[:, tB.shape[1]:]
        Cm_c = _causal_conv(jnp.concatenate([tC, Cm], 1), p["conv_C"],
                            p["conv_b_C"])[:, tC.shape[1]:]
    else:
        xs_c = _causal_conv(xs, p["conv_x"], p["conv_b_x"])
        Bm_c = _causal_conv(Bm, p["conv_B"], p["conv_b_B"])
        Cm_c = _causal_conv(Cm, p["conv_C"], p["conv_b_C"])
    tail = cfg.ssm_conv - 1
    new_tail = (xs[:, S - tail:], Bm[:, S - tail:], Cm[:, S - tail:]) \
        if tail else None
    xh = xs_c.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    y, state = ssd_chunked(xh, dt, A, Bm_c, Cm_c, cfg.ssm_chunk, initial_state)
    y = y + xh * p["D"][None, None, :, None]
    y = _rmsnorm_gated(y.reshape(Bsz, S, di), z, p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (state, new_tail)


def mamba_decode(p: dict, cfg: ArchConfig, x: jax.Array, state, conv_tail):
    """One-token decode.  x [B,1,d]; state [B,H,P,N];
    conv_tail (tx [B,K-1,di], tB [B,K-1,N], tC [B,K-1,N])."""
    Bsz = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt = _project(p, cfg, x)
    tx, tB, tC = conv_tail
    wx = jnp.concatenate([tx, xs], axis=1)
    wB = jnp.concatenate([tB, Bm], axis=1)
    wC = jnp.concatenate([tC, Cm], axis=1)
    xs_c = _conv_step(wx, p["conv_x"], p["conv_b_x"])
    Bm_c = _conv_step(wB, p["conv_B"], p["conv_b_B"])
    Cm_c = _conv_step(wC, p["conv_C"], p["conv_b_C"])
    new_tail = (wx[:, 1:], wB[:, 1:], wC[:, 1:])
    xh = xs_c.reshape(Bsz, H, P)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    y, state = ssd_decode_step(state, xh, dt1, A, Bm_c, Cm_c)
    y = y + xh * p["D"][None, :, None]
    y = _rmsnorm_gated(y.reshape(Bsz, 1, di), z[:, :1], p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (state, new_tail)


def mamba_state_init(cfg: ArchConfig, batch: int, dtype):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv - 1
    tails = (jnp.zeros((batch, K, cfg.d_inner), dtype),
             jnp.zeros((batch, K, N), dtype),
             jnp.zeros((batch, K, N), dtype))
    return jnp.zeros((batch, H, P, N), dtype), tails
