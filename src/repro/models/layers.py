"""Shared transformer layers: RMSNorm, RoPE, GQA/MQA attention, gated FFN.

Attention for training/prefill is block-chunked with an online softmax
(flash-attention schedule in pure JAX): the [S, S] score matrix never
materializes — only [blk_q, blk_k] tiles — which is what keeps the 4k-train
and 32k-prefill cells inside HBM at batch 256/32.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + w)


# ------------------------------------------------------------------ RoPE ---
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                              # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- chunked causal attention ---
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, blk_q: int = 512,
                    blk_k: int = 1024, scale: float | None = None) -> jax.Array:
    """Online-softmax blocked attention.

    q/k [B, S, *, D], v [B, Sk, K, Dv] with H % K == 0 (GQA broadcast).
    Dv may differ from D (MLA).  Returns [B, Sq, H, Dv].  No [Sq, Sk]
    materialization.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // K
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    nq, nk = Sq // blk_q, Sk // blk_k

    qb = q.reshape(B, nq, blk_q, K, G, D)
    kb = k.reshape(B, nk, blk_k, K, D)
    vb = v.reshape(B, nk, blk_k, K, Dv)

    def q_block(iq, qi):
        # qi: [B, blk_q, K, G, D]
        # NOTE: kv_step is rematerialized (nothing_saveable): otherwise the
        # inner scan stacks per-step residuals for backward — notably the
        # [blk_q, blk_k] pred masks and p matrices — which dominated temp
        # memory (21.5 GiB/device for tinyllama train_4k).  Recomputing s/p
        # in the backward pass is the standard flash-attention trade:
        # extra QK^T FLOPs for O(blk) instead of O(S) residency.
        def kv_step(carry, jk):
            # Perf iteration 1 (EXPERIMENTS.md §Perf): score/probability
            # tiles stay in the COMPUTE dtype (bf16 on TPU) — only the
            # running stats (m, l) and the output accumulator are f32.
            # Forcing f32 tiles doubled the dominant HBM traffic AND made
            # XLA hoist f32 converts before the TP all-reduces / FSDP
            # all-gathers (f32 wire payloads).  MXU accumulates qk^T in
            # f32 internally regardless of the tile dtype.
            acc, m, l = carry
            kj = jax.lax.dynamic_index_in_dim(kb, jk, axis=1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, jk, axis=1, keepdims=False)
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qi, kj) * scale
            s = s.astype(jnp.float32)  # tile-local; fused with the ops below
            if causal:
                qpos = iq * blk_q + jnp.arange(blk_q)
                kpos = jk * blk_k + jnp.arange(blk_k)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(q.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, blk_q, Dv), jnp.float32)
        m0 = jnp.full((B, K, G, blk_q), -jnp.inf)
        l0 = jnp.zeros((B, K, G, blk_q))
        if causal:
            # only key blocks up to the diagonal participate: bound the scan
            # at this q block's last live key block (the remainder would be
            # fully masked).  trip count is traced -> use fori via masking:
            # scan a static nk but weight dead blocks to zero would waste
            # FLOPs; instead scan exactly ceil((iq+1)*blk_q / blk_k) blocks.
            n_live = jnp.minimum((iq * blk_q + blk_q + blk_k - 1) // blk_k, nk)

            def bounded_step(carry, jk):
                new_carry, _ = kv_step(carry, jk)
                keep = jk < n_live
                merged = jax.tree.map(
                    lambda n, o: jnp.where(
                        keep.reshape((1,) * n.ndim), n, o), new_carry, carry)
                return merged, None

            (acc, m, l), _ = jax.lax.scan(
                jax.checkpoint(bounded_step,
                               policy=jax.checkpoint_policies.nothing_saveable),
                (acc0, m0, l0), jnp.arange(nk))
        else:
            (acc, m, l), _ = jax.lax.scan(
                jax.checkpoint(kv_step,
                               policy=jax.checkpoint_policies.nothing_saveable),
                (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # [B, K, G, blk_q, D]

    out = jax.lax.map(lambda args: q_block(args[0], args[1]),
                      (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4, 5)))
    # out: [nq, B, K, G, blk_q, D]
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, G, Sq, Dv)
    out = out.transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array | int, *, scale: float | None = None
                     ) -> jax.Array:
    """Single-step decode. q [B, 1, H, D]; caches [B, S, K, D]; returns [B,1,H,D]."""
    B, _, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, K, G, D)
    # keep the CACHE operand in its storage dtype: an explicit .astype(f32)
    # here made XLA carry the whole [L,B,S,K,D] cache in f32 through the
    # layer scan (2x cache memory+traffic); preferred_element_type gives
    # the f32 accumulation without promoting the operand (§Perf iter 7)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    s = jnp.where(pos[None, None, None, :] < length, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ------------------------------------------------------------- gated FFN ---
def gated_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array | None,
              w_down: jax.Array, act: str) -> jax.Array:
    """SwiGLU/GeGLU when w_up is present; plain 2-matrix MLP when None."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    if w_up is not None:
        a = a * jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", a, w_down)
