"""FoG for LMs — confidence-gated layer-grove early exit (beyond-paper).

The paper's mechanism transplanted to autoregressive decoding: the layer
stack is split into ``cfg.fog_groups`` *groves* of consecutive blocks.
After each grove the shared unembedding produces logits; the MaxDiff
confidence (top-1 minus top-2 softmax probability — identical to
Algorithm 2 line 9) is compared against a threshold; lanes that clear it
stop computing.  ``hops`` counts groves used per token, exactly like the
classifier's hop counter, and drives the same energy/FLOP accounting.

KV-staleness policy (the known early-exit problem: later tokens attend to
positions whose deep-layer KV was never computed): we use CALM-style state
propagation — an exited lane's last hidden state h is propagated through
the remaining groves' KV projections only (cheap linear ops, no
attention/FFN), so deep caches are filled with the approximation
KV_l(h_exit).  The compute skipped is the attention+FFN body, which is
>95% of per-layer FLOPs for the assigned archs.

On SIMD hardware the savings are realized per *grove*: a grove's body is
wrapped in ``lax.cond`` on ``live.any()``, so whole-batch-confident steps
skip the remaining groves entirely (wall-clock win); per-lane savings
inside a mixed batch are statistical and reported via the hops histogram
(energy win), mirroring DESIGN.md §2's queue->mask argument.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.engine import confidence_margin
from repro.core.policy import FogPolicy, margin_backend
from repro.models import transformer as T


def grove_boundaries(cfg: ArchConfig) -> list[int]:
    """Split the scanned stack's n_repeat blocks into fog_groups segments."""
    _, _, n_rep = T.layer_plan(cfg)
    g = max(1, min(cfg.fog_groups, n_rep))
    base, extra = divmod(n_rep, g)
    sizes = [base + (1 if i < extra else 0) for i in range(g)]
    return sizes


def lm_hop_energy(cfg: ArchConfig):
    """Price one layer-grove "hop" of the LM exit gate: the grove's share
    of the active per-token MACs at the shared per-op energies
    (:mod:`repro.core.energy` constants — a FLOP-proportional proxy, not
    the classifier's tree-SRAM model).  Returns an
    :class:`~repro.core.energy.AffineEnergy`, so the serving
    ``EnergyGovernor`` prices LM hop telemetry with the same contract it
    uses for forest EvalReports."""
    from repro.configs.base import param_count
    from repro.core.energy import E_FP32_MAC, E_SRAM_R32, AffineEnergy
    _, active = param_count(cfg)
    per_grove_macs = active / max(1, len(grove_boundaries(cfg)))
    return AffineEnergy(per_hop_pj=per_grove_macs * (E_FP32_MAC + E_SRAM_R32))


def _stack_slice(stack, start: int, size: int):
    return jax.tree.map(lambda x: jax.lax.slice_in_dim(x, start, start + size,
                                                       axis=0), stack)


def decode_step_fog(params, cfg: ArchConfig, token, cache, length,
                    thresh, embeds=None, *, backend: str = "reference"):
    """FoG decode step.  Returns (logits [B,V], new_cache, hops [B]).

    ``thresh`` is the runtime-knob contract: a :class:`FogPolicy` (the
    canonical form — per-lane ``[B]`` threshold vectors and per-lane hop
    budgets serve mixed-QoS batches), or a bare scalar / ``[B]`` threshold
    for backward compatibility.  A lane whose hop budget is exhausted exits
    even while unconfident (anytime decoding under an energy contract).

    Grove g is executed under ``lax.cond(live.any())``; exited lanes keep
    their grove-g logits via masking (SIMD equivalent of leaving the queue).
    ``backend`` selects the confidence-margin implementation from the shared
    FogEngine surface ("reference" jnp or the "pallas" top-2 kernel) — the
    gate semantics and hop accounting are identical either way; a
    policy's ``backend`` knob overrides the kwarg.
    """
    prefix, period, n_rep = T.layer_plan(cfg)
    sizes = grove_boundaries(cfg)
    B = token.shape[0] if token is not None else embeds.shape[0]
    if isinstance(thresh, FogPolicy):
        policy = thresh
    else:
        policy = FogPolicy(threshold=thresh)
    if policy.backend is not None:
        backend = margin_backend(policy.backend)
    thresh = policy.lane_thresholds(B)
    budget = (policy.lane_budgets(B) if policy.hop_budget is not None
              else None)
    h = (T.embed_tokens(params, cfg, token[:, None]) if embeds is None
         else embeds)

    new_prefix = []
    for p, s, c in zip(params["prefix"], prefix, cache["prefix"]):
        h, c = T._apply_layer_decode(p, cfg, s, h, c, length)
        new_prefix.append(c)

    def run_groves(h):
        live = jnp.ones((B,), bool)
        hops = jnp.zeros((B,), jnp.int32)
        logits = jnp.zeros((B, cfg.vocab_size), jnp.float32)
        new_stack_parts = []
        start = 0
        for g, size in enumerate(sizes):
            blk_params = _stack_slice(params["stack"], start, size)
            blk_cache = _stack_slice(cache["stack"], start, size)

            def scan_fn(h):
                def block(hh, xs):
                    bp, bc = xs
                    nc = {}
                    for j, s in enumerate(period):
                        hh, nc[f"pos{j}"] = T._apply_layer_decode(
                            bp[f"pos{j}"], cfg, s, hh, bc[f"pos{j}"], length)
                    return hh, nc
                return jax.lax.scan(block, h, (blk_params, blk_cache))

            def skip_fn(h):
                # CALM-style: propagate h through KV projections only, so
                # later tokens can attend to this position at deep layers
                def block(hh, xs):
                    bp, bc = xs
                    nc = {}
                    for j, s in enumerate(period):
                        nc[f"pos{j}"] = _kv_only_update(
                            bp[f"pos{j}"], cfg, s, hh, bc[f"pos{j}"], length)
                    return hh, nc
                return jax.lax.scan(block, h, (blk_params, blk_cache))

            any_live = live.any()
            h_new, blk_cache_new = jax.lax.cond(any_live, scan_fn, skip_fn, h)
            # masked select per lane: exited lanes keep their old hidden state
            h = jnp.where(live[:, None, None], h_new, h)
            blk_cache_new = jax.tree.map(
                lambda n, o: _mask_cache(n, o, live), blk_cache_new, blk_cache)
            new_stack_parts.append(blk_cache_new)
            hops = hops + live.astype(jnp.int32)

            g_logits = T.unembed(params, cfg, h[:, 0])
            logits = jnp.where(live[:, None], g_logits, logits)
            if g < len(sizes) - 1:
                probs = jax.nn.softmax(g_logits, axis=-1)
                live = live & (confidence_margin(probs, backend=backend)
                               < thresh)
                if budget is not None:   # per-lane energy cap
                    live = live & (hops < budget)
            start += size
        new_stack = jax.tree.map(
            lambda *parts: jnp.concatenate(parts, axis=0), *new_stack_parts)
        return logits, new_stack, hops

    logits, new_stack, hops = run_groves(h)
    return logits, {"prefix": new_prefix, "stack": new_stack}, hops


def _mask_cache(new, old, live):
    """Per-lane cache select.  Cache leaves are [n_blocks, B, ...]."""
    mask = live.reshape((1, -1) + (1,) * (new.ndim - 2))
    return jnp.where(mask, new, old)


def _kv_only_update(p, cfg: ArchConfig, s, h, cache, length):
    """Fill grove caches from a propagated hidden state (projections only)."""
    x = T.rmsnorm(h, p["ln1"])
    if s.mixer == "mamba":
        # recurrent state advance is the cheap part of a mamba layer; reuse
        # the full decode-state update but discard the output
        _, (st, tail) = __import__("repro.models.mamba2", fromlist=["m"]).mamba_decode(
            p["mamba"], cfg, x, cache["state"], cache["conv"])
        return {"state": st, "conv": tail}
    if s.mixer == "mla":
        from repro.models import mla as mla_mod
        B = x.shape[0]
        pos = jnp.full((B, 1), length, jnp.int32)
        c_kv_new, k_rope_new = mla_mod._compress_kv(p["attn"], cfg, x, pos)
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), length, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), length, axis=1)
        return {"c_kv": c_kv, "k_rope": k_rope}
    from repro.models.layers import apply_rope
    B = x.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)
    k = apply_rope(jnp.einsum("bsd,dke->bske", x, p["attn"]["wk"]), pos,
                   cfg.rope_theta)
    v = jnp.einsum("bsd,dke->bske", x, p["attn"]["wv"])
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), length, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), length, axis=1)
    return {"k": kc, "v": vc}


def fog_flops_per_token(cfg: ArchConfig, mean_hops: float) -> float:
    """Modeled decode FLOPs/token under FoG vs full stack (energy proxy:
    the paper's hops x grove-cost accounting, in FLOP units)."""
    from repro.configs.base import param_count
    _, active = param_count(cfg)
    frac = mean_hops / max(1, min(cfg.fog_groups,
                                  T.layer_plan(cfg)[2]))
    return 2.0 * active * frac
