"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V3).

Train/prefill path expands K/V from the compressed latent and runs the
blocked flash attention.  Decode path uses the ABSORBED form: W_UK is folded
into the query and W_UV into the output, so attention runs directly against
the cached latent c_kv (rank r_kv) + shared k_rope — the cache is
[B, S, r_kv + d_rope] instead of [B, S, H, (d_nope + d_rope + d_v)]:
a 128x/~14x cache-bytes reduction for DeepSeek-V3/MiniCPM3 and the reason
MLA decode is memory-roofline-friendly at 32k context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, flash_attention, rmsnorm


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    s = lambda *sh: 1.0 / np.sqrt(sh[0])
    init = lambda k, *sh: (jax.random.normal(k, sh) * s(*sh)).astype(dtype)
    return {
        "w_dq": init(ks[0], d, rq),
        "q_norm": jnp.zeros((rq,), dtype),
        "w_uq": init(ks[1], rq, H, dn + dr),
        "w_dkv": init(ks[2], d, rkv + dr),
        "kv_norm": jnp.zeros((rkv,), dtype),
        "w_uk": init(ks[3], rkv, H, dn),
        "w_uv": init(ks[4], rkv, H, dv),
        "w_o": init(ks[5], H, dv, d),
    }


def _project_q(p, cfg: ArchConfig, x, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, cfg: ArchConfig, x, positions):
    rkv = cfg.kv_lora_rank
    ckv_rope = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rmsnorm(ckv_rope[..., :rkv], p["kv_norm"])
    k_rope = apply_rope(ckv_rope[..., None, rkv:], positions, cfg.rope_theta)
    return c_kv, k_rope[..., 0, :]                       # [B,S,rkv], [B,S,dr]


def mla_attention_train(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence path: expand K/V, blocked flash attention."""
    B, S, _ = x.shape
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _compress_kv(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, cfg.n_heads, dr))], axis=-1)
    out = flash_attention(q, k, v, causal=True,
                          scale=1.0 / np.sqrt(dn + dr))    # [B,S,H,dv]
    return jnp.einsum("bshe,hed->bsd", out, p["w_o"])


def mla_attention_decode(p, cfg: ArchConfig, x: jax.Array,
                         cache: dict, length) -> tuple[jax.Array, dict]:
    """Absorbed single-step decode against the compressed cache.

    x: [B, 1, d]; cache: {"c_kv": [B, S, rkv], "k_rope": [B, S, dr]}.
    """
    B = x.shape[0]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    positions = jnp.full((B, 1), length, jnp.int32)
    q_nope, q_rope = _project_q(p, cfg, x, positions)      # [B,1,H,dn],[B,1,H,dr]
    c_kv_new, k_rope_new = _compress_kv(p, cfg, x, positions)

    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), length, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), length, axis=1)

    # absorb W_UK into q: q_lat [B,H,rkv].  Cache operands (c_kv, k_rope)
    # stay in storage dtype — preferred_element_type gives f32 accumulation
    # without promoting the carried cache buffers (§Perf iter 7)
    f32 = jnp.float32
    q_lat = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], p["w_uk"],
                       preferred_element_type=f32)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(c_kv.dtype), c_kv,
                       preferred_element_type=f32)
    s_rope = jnp.einsum("bhe,bse->bhs", q_rope[:, 0], k_rope,
                        preferred_element_type=f32)
    s = (s_lat + s_rope) / np.sqrt(dn + dr)
    pos = jnp.arange(s.shape[-1])
    s = jnp.where(pos[None, None, :] <= length, s, -jnp.inf)
    attn = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", attn.astype(c_kv.dtype), c_kv,
                         preferred_element_type=f32)
    # absorb W_UV on the way out
    ctx = jnp.einsum("bhr,rhe->bhe", ctx_lat.astype(p["w_uv"].dtype),
                     p["w_uv"], preferred_element_type=f32)
    out = jnp.einsum("bhe,hed->bd", ctx.astype(x.dtype), p["w_o"])
    return out[:, None, :], {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_init(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }
