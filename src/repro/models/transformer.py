"""Config-driven decoder assembly for all assigned architectures.

The layer sequence of every assigned arch is periodic (jamba: 8-layer
attn:mamba blocks with alternating MoE; deepseek: 3 dense layers then
uniform MoE; the rest: period 1), so parameters are stored as

  prefix : list of per-layer dicts (unscanned — deepseek's 3 dense layers)
  stack  : pytree stacked on a leading [n_repeat] axis, scanned with
           ``lax.scan`` so HLO stays O(period) regardless of depth

Scan keeps compile time and HLO size flat for the 61-88-layer archs; remat
(``jax.checkpoint``) wraps the scan body for training memory.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import mamba2, mla, moe
from repro.models.layers import (
    apply_rope, decode_attention, flash_attention, gated_ffn, rmsnorm,
)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str   # attn | mla | mamba
    ffn: str     # dense | moe


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin activations to batch-over-dp sharding at block boundaries.

    Without this, XLA's sharding propagation can resolve the FSDP-weight /
    batch-activation contraction conflict by UNSHARDING the batch and
    sharding activations' feature dim over `model` instead (observed on
    deepseek-v3: full-batch [256,4096,*] f32 buffers -> 460 GiB/device).
    No-op when no mesh is set (unit tests) or batch doesn't divide.
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as _np
    if x.shape[0] % int(_np.prod([mesh.shape[a] for a in dp])) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 1))))


def layer_plan(cfg: ArchConfig) -> tuple[list[LayerSpec], list[LayerSpec], int]:
    """(prefix_specs, period_specs, n_repeat)."""
    def spec(i: int) -> LayerSpec:
        if cfg.ssm and not cfg.is_attn_layer(i):
            mixer = "mamba"
        elif cfg.mla:
            mixer = "mla"
        else:
            mixer = "attn"
        return LayerSpec(mixer, "moe" if cfg.is_moe_layer(i) else "dense")

    n_prefix = cfg.moe_first_k_dense
    prefix = [spec(i) for i in range(n_prefix)]
    rem = cfg.n_layers - n_prefix
    if cfg.ssm and cfg.attn_layer_period:
        R = cfg.attn_layer_period
    elif cfg.moe and cfg.moe_period > 1:
        R = cfg.moe_period
    else:
        R = 1
    assert rem % R == 0, (cfg.name, rem, R)
    period = [spec(n_prefix + j) for j in range(R)]
    return prefix, period, rem // R


# ---------------------------------------------------------------- params ---
def _init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    init = lambda k, *sh: (jax.random.normal(k, sh) / np.sqrt(d)).astype(dtype)
    return {
        "wq": init(ks[0], d, H, hd),
        "wk": init(ks[1], d, K, hd),
        "wv": init(ks[2], d, K, hd),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) / np.sqrt(H * hd)).astype(dtype),
    }


def _init_ffn(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    init = lambda k, a, b: (jax.random.normal(k, (a, b)) / np.sqrt(a)).astype(dtype)
    p = {"w_gate": init(k1, d, f), "w_down": init(k3, f, d)}
    if cfg.gated_ffn:
        p["w_up"] = init(k2, d, f)
    return p


def _init_layer(key, cfg: ArchConfig, s: LayerSpec, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if s.mixer == "attn":
        p["attn"] = _init_attn(k1, cfg, dtype)
    elif s.mixer == "mla":
        p["attn"] = mla.init_mla(k1, cfg, dtype)
    else:
        p["mamba"] = mamba2.init_mamba(k1, cfg, dtype)
    if s.mixer != "mamba":
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = (moe.init_moe(k2, cfg, dtype) if s.ffn == "moe"
                    else _init_ffn(k2, cfg, dtype))
    else:
        # mamba blocks are mixer-only in mamba2; hybrid (jamba) keeps the FFN
        if cfg.attn_layer_period:
            p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
            p["ffn"] = (moe.init_moe(k2, cfg, dtype) if s.ffn == "moe"
                        else _init_ffn(k2, cfg, dtype))
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    prefix, period, n_rep = layer_plan(cfg)
    keys = jax.random.split(key, 4 + len(prefix))
    d, V = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (V, d)) * 0.02).astype(dtype),
        "out_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(keys[1], (d, V)) / np.sqrt(d)).astype(dtype)
    params["prefix"] = [
        _init_layer(keys[4 + i], cfg, s, dtype) for i, s in enumerate(prefix)]

    def init_block(k):
        sub = jax.random.split(k, len(period))
        return {f"pos{j}": _init_layer(sub[j], cfg, s, dtype)
                for j, s in enumerate(period)}

    block_keys = jax.random.split(keys[2], n_rep)
    params["stack"] = jax.vmap(init_block)(block_keys)
    if cfg.mtp:
        k1, k2 = jax.random.split(keys[3])
        params["mtp"] = {
            "proj": (jax.random.normal(k1, (2 * d, d)) / np.sqrt(2 * d)).astype(dtype),
            "ln": jnp.zeros((d,), dtype),
        }
    return params


# --------------------------------------------------------------- forward ---
def _attn_train(p, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    B, S, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q = jnp.einsum("bsd,dhe->bshe", h, p["wq"])
    k = jnp.einsum("bsd,dke->bske", h, p["wk"])
    v = jnp.einsum("bsd,dke->bske", h, p["wv"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = flash_attention(q, k, v, causal=True)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def _apply_layer_train(p, cfg: ArchConfig, s: LayerSpec, h, aux):
    if s.mixer == "mamba":
        mixed, _ = mamba2.mamba_forward(p["mamba"], cfg, rmsnorm(h, p["ln1"]))
        h = h + mixed
    elif s.mixer == "mla":
        h = h + mla.mla_attention_train(p["attn"], cfg, rmsnorm(h, p["ln1"]))
    else:
        h = h + _attn_train(p["attn"], cfg, rmsnorm(h, p["ln1"]))
    if "ffn" in p:
        x = rmsnorm(h, p["ln2"])
        if s.ffn == "moe":
            y, a = moe.moe_ffn(p["ffn"], cfg, x)
            aux = aux + a
        else:
            y = gated_ffn(x, p["ffn"]["w_gate"], p["ffn"].get("w_up"),
                          p["ffn"]["w_down"], cfg.act)
        h = h + y
    return h, aux


def embed_tokens(params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens]
    return h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)   # gemma-style scale


def unembed(params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    h = rmsnorm(h, params["out_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("...d,dv->...v", h, w).astype(jnp.float32)


def forward(params, cfg: ArchConfig, tokens=None, embeds=None,
            remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden [B,S,d], moe_aux scalar)."""
    prefix, period, _ = layer_plan(cfg)
    h = embed_tokens(params, cfg, tokens) if embeds is None else embeds
    aux = jnp.zeros((), jnp.float32)
    for p, s in zip(params["prefix"], prefix):
        h, aux = _apply_layer_train(p, cfg, s, h, aux)

    def block(carry, blk_params):
        h, aux = carry
        h = constrain_batch(h)
        for j, s in enumerate(period):
            h, aux = _apply_layer_train(blk_params[f"pos{j}"], cfg, s, h, aux)
        return (constrain_batch(h), aux), None

    body = jax.checkpoint(block) if remat else block
    (h, aux), _ = jax.lax.scan(body, (h, aux), params["stack"])
    return h, aux


def chunked_ce(params, cfg: ArchConfig, h: jax.Array, labels: jax.Array,
               chunk: int = 512, mask: jax.Array | None = None) -> jax.Array:
    """Cross-entropy without materializing full [B, S, V] f32 logits.

    For 129k-256k vocabularies the f32 logits tensor dominates HBM (gemma-2b
    train_4k: 16.8 GiB/device).  Scanning the unembed+softmax over sequence
    chunks bounds the live logits buffer to [B, chunk, V/model]; the scan
    body is rematerialized so backward recomputes each chunk's logits
    instead of saving them (the "fused CE" every production LM framework
    ships, here in pure JAX)."""
    B, S = labels.shape
    c = min(chunk, S)
    if S % c:
        c = S
    nc = S // c
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hc = h.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, c).transpose(1, 0, 2)

    def body(acc, xs):
        hh, ll, mm = xs
        hh = constrain_batch(hh)
        logits = unembed(params, cfg, hh)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, ll[..., None], axis=-1)[..., 0]
        return acc + (ce * mm).sum(), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)


def lm_loss(params, cfg: ArchConfig, tokens=None, labels=None, embeds=None,
            aux_coef: float = 0.01, remat: bool = True) -> jax.Array:
    h, aux = forward(params, cfg, tokens=tokens, embeds=embeds, remat=remat)
    loss = chunked_ce(params, cfg, h, labels)
    if cfg.mtp:
        # multi-token prediction: predict t+2 from (h_t, embed(label_t))
        emb_next = embed_tokens(params, cfg, labels)
        mixed = jnp.einsum(
            "bsd,dk->bsk",
            jnp.concatenate([rmsnorm(h, params["mtp"]["ln"]), emb_next], -1),
            params["mtp"]["proj"])
        labels2 = jnp.roll(labels, -1, axis=1)
        mask2 = jnp.ones_like(labels2, jnp.float32).at[:, -1].set(0.0)
        loss = loss + 0.3 * chunked_ce(params, cfg, mixed, labels2, mask=mask2)
    return loss + aux_coef * aux


# ---------------------------------------------------------------- decode ---
def _cache_init_layer(cfg: ArchConfig, s: LayerSpec, batch: int,
                      max_seq: int, dtype) -> dict:
    if s.mixer == "mamba":
        state, tail = mamba2.mamba_state_init(cfg, batch, dtype)
        return {"state": state, "conv": tail}
    if s.mixer == "mla":
        return mla.mla_cache_init(cfg, batch, max_seq, dtype)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, max_seq, K, hd), dtype),
            "v": jnp.zeros((batch, max_seq, K, hd), dtype)}


def cache_init(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    prefix, period, n_rep = layer_plan(cfg)
    pre = [_cache_init_layer(cfg, s, batch, max_seq, dtype) for s in prefix]
    one = lambda: {f"pos{j}": _cache_init_layer(cfg, s, batch, max_seq, dtype)
                   for j, s in enumerate(period)}
    stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_rep, *x.shape)), one())
    return {"prefix": pre, "stack": stack}


def _attn_decode(p, cfg: ArchConfig, h, cache, length):
    B = h.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", h, p["wq"])
    k = jnp.einsum("bsd,dke->bske", h, p["wk"])
    v = jnp.einsum("bsd,dke->bske", h, p["wv"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                             length, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                             length, axis=1)
    out = decode_attention(q, kc, vc, length + 1)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), {"k": kc, "v": vc}


def _apply_layer_decode(p, cfg: ArchConfig, s: LayerSpec, h, cache, length):
    x = rmsnorm(h, p["ln1"])
    if s.mixer == "mamba":
        mixed, (st, tail) = mamba2.mamba_decode(p["mamba"], cfg, x,
                                                cache["state"], cache["conv"])
        cache = {"state": st, "conv": tail}
    elif s.mixer == "mla":
        mixed, cache = mla.mla_attention_decode(p["attn"], cfg, x, cache, length)
    else:
        mixed, cache = _attn_decode(p["attn"], cfg, x, cache, length)
    h = h + mixed
    if "ffn" in p:
        x = rmsnorm(h, p["ln2"])
        if s.ffn == "moe":
            y, _ = moe.moe_ffn(p["ffn"], cfg, x)
        else:
            y = gated_ffn(x, p["ffn"]["w_gate"], p["ffn"].get("w_up"),
                          p["ffn"]["w_down"], cfg.act)
        h = h + y
    return h, cache


def decode_step(params, cfg: ArchConfig, token, cache, length,
                embeds=None):
    """One decode step.  token [B] int32 (or embeds [B,1,d]); returns
    (logits [B,V], new_cache)."""
    prefix, period, _ = layer_plan(cfg)
    h = (embed_tokens(params, cfg, token[:, None]) if embeds is None else embeds)
    new_prefix = []
    for p, s, c in zip(params["prefix"], prefix, cache["prefix"]):
        h, c = _apply_layer_decode(p, cfg, s, h, c, length)
        new_prefix.append(c)

    def block(h, xs):
        blk_params, blk_cache = xs
        h = constrain_batch(h)
        new_cache = {}
        for j, s in enumerate(period):
            h, new_cache[f"pos{j}"] = _apply_layer_decode(
                blk_params[f"pos{j}"], cfg, s, h, blk_cache[f"pos{j}"], length)
        return constrain_batch(h), new_cache

    h, new_stack = jax.lax.scan(block, h, (params["stack"], cache["stack"]))
    logits = unembed(params, cfg, h[:, 0])
    return logits, {"prefix": new_prefix, "stack": new_stack}


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            max_seq: int | None = None):
    """Prefill: full forward + caches populated for positions [0, S).

    Returns (last-position logits [B,V], cache).  Caches are padded to
    ``max_seq`` (default S) so decode can continue at length=S.  Used by
    the prefill_32k cells.
    """
    prefix, period, n_rep = layer_plan(cfg)
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    h = embed_tokens(params, cfg, tokens) if embeds is None else embeds
    dtype = h.dtype
    pad_s = (max_seq or S) - S

    def padseq(a):
        return jnp.pad(a, ((0, 0), (0, pad_s)) + ((0, 0),) * (a.ndim - 2)) \
            if pad_s else a

    def mix_with_cache(p, s, h):
        x = rmsnorm(h, p["ln1"])
        if s.mixer == "mamba":
            mixed, (st, tail) = mamba2.mamba_forward(p["mamba"], cfg, x)
            cache = {"state": st, "conv": tail}
        elif s.mixer == "mla":
            pos = jnp.broadcast_to(jnp.arange(S), (B, S))
            c_kv, k_rope = mla._compress_kv(p["attn"], cfg, x, pos)
            mixed = mla.mla_attention_train(p["attn"], cfg, x)
            cache = {"c_kv": padseq(c_kv.astype(dtype)),
                     "k_rope": padseq(k_rope.astype(dtype))}
        else:
            pos = jnp.broadcast_to(jnp.arange(S), (B, S))
            q = jnp.einsum("bsd,dhe->bshe", x, p["attn"]["wq"])
            k = jnp.einsum("bsd,dke->bske", x, p["attn"]["wk"])
            v = jnp.einsum("bsd,dke->bske", x, p["attn"]["wv"])
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
            out = flash_attention(q, k, v, causal=True)
            mixed = jnp.einsum("bshe,hed->bsd", out, p["attn"]["wo"])
            cache = {"k": padseq(k.astype(dtype)), "v": padseq(v.astype(dtype))}
        h = h + mixed
        if "ffn" in p:
            xf = rmsnorm(h, p["ln2"])
            if s.ffn == "moe":
                y, _ = moe.moe_ffn(p["ffn"], cfg, xf)
            else:
                y = gated_ffn(xf, p["ffn"]["w_gate"], p["ffn"].get("w_up"),
                              p["ffn"]["w_down"], cfg.act)
            h = h + y
        return h, cache

    new_prefix = []
    for p, s in zip(params["prefix"], prefix):
        h, c = mix_with_cache(p, s, h)
        new_prefix.append(c)

    def block(h, blk_params):
        h = constrain_batch(h)
        caches = {}
        for j, s in enumerate(period):
            h, caches[f"pos{j}"] = mix_with_cache(blk_params[f"pos{j}"], s, h)
        return constrain_batch(h), caches

    h, stack_caches = jax.lax.scan(block, h, params["stack"])
    logits = unembed(params, cfg, h[:, -1])
    return logits, {"prefix": new_prefix, "stack": stack_caches}
