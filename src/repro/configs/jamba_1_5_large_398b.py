"""Jamba-1.5-Large 398B — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536, act="silu",
    moe=True, n_experts=16, experts_per_token=2, moe_period=2,
    ssm=True, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    attn_layer_period=8, attn_layer_offset=4,
    long_context=True, fog_groups=4,
)
