"""Chameleon-34B — early-fusion VLM over VQ image+text tokens; VQ frontend
stubbed to precomputed patch embeddings [arXiv:2405.09818; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, act="silu",
    frontend="vlm", fog_groups=4,
)
