"""Gemma-2B — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=256000, act="gelu", head_dim=256,
    tie_embeddings=True, rope_theta=10000.0, fog_groups=3,
)
