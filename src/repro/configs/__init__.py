from repro.configs.base import ArchConfig, param_count
from repro.configs.registry import ARCHS, get_arch, smoke_config

__all__ = ["ArchConfig", "param_count", "ARCHS", "get_arch", "smoke_config"]
