"""Mamba2-2.7B — attention-free SSD [arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280, act="silu",
    ssm=True, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    long_context=True, fog_groups=4,
)
