"""Granite-34B-Code — llama-arch, MQA (kv=1) [arXiv:2405.04324; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, act="gelu", gated_ffn=False,
    rope_theta=10000.0, fog_groups=4,
)
