"""DeepSeek-V3 671B — MLA + 1 shared + 256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf].  d_ff=2048 is the per-expert (fine-grained) width;
the 3 leading layers are dense with d_ff=18432 per the paper."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280, act="silu",
    moe=True, n_experts=256, experts_per_token=8, n_shared_experts=1,
    moe_d_ff=2048, moe_first_k_dense=3, capacity_factor=1.25,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128, head_dim=192,
    mtp=True, fog_groups=4,
)
