"""The paper's own system: FoG-of-random-forest configuration (§4.1).

Not an LM architecture — this is the classifier the paper builds.  The
values reflect the paper's min-EDP design pick (16 DTs in an 8x2 topology,
threshold as the run-time knob) and drive examples/quickstart.py,
benchmarks/table1_*, fig4, fig5.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class FogRFConfig:
    n_trees: int = 16
    n_groves: int = 8           # the paper's selected 8x2 topology
    grove_size: int = 2
    max_depth: int = 8          # per-dataset depths in benchmarks/common.py
    threshold: float = 0.5      # FoG_opt operating point (accuracy-optimal)
    max_hops: int = 8           # = n_groves: the whole forest at most
    datasets: tuple = ("isolet", "penbased", "mnist", "letter", "segmentation")


CONFIG = FogRFConfig()
