"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs import (
    tinyllama_1_1b, minicpm3_4b, granite_34b, gemma_2b, mamba2_2_7b,
    musicgen_large, grok1_314b, deepseek_v3_671b, chameleon_34b,
    jamba_1_5_large_398b,
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        tinyllama_1_1b.CONFIG,
        minicpm3_4b.CONFIG,
        granite_34b.CONFIG,
        gemma_2b.CONFIG,
        mamba2_2_7b.CONFIG,
        musicgen_large.CONFIG,
        grok1_314b.CONFIG,
        deepseek_v3_671b.CONFIG,
        chameleon_34b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced config of the same family: small width/depth/experts/vocab,
    same structural features (GQA ratios, MLA, MoE pattern, hybrid period)."""
    c = get_arch(name)
    kv = max(1, min(c.n_kv_heads, 2))
    heads = max(kv * 2, 4)
    over: dict = dict(
        n_layers=max(2, min(c.n_layers, 4)),
        d_model=128, n_heads=heads, n_kv_heads=kv, head_dim=32,
        d_ff=256, vocab_size=512, fog_groups=2,
    )
    if c.ssm:
        over.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if c.attn_layer_period:       # hybrid: keep 1:k-1 interleave
            over.update(n_layers=2 * c.attn_layer_period,
                        attn_layer_period=c.attn_layer_period)
        else:
            over.update(n_layers=4)
    if c.moe:
        over.update(n_experts=min(c.n_experts, 8),
                    experts_per_token=min(c.experts_per_token, 2),
                    moe_d_ff=64 if c.moe_d_ff else 0,
                    moe_first_k_dense=min(c.moe_first_k_dense, 1),
                    # drop-free routing so decode==forward exactly in tests
                    capacity_factor=float(min(c.n_experts, 8)))
        if c.moe_first_k_dense:
            over["n_layers"] = over.get("n_layers", 4) + 1
    if c.mla:
        over.update(q_lora_rank=48, kv_lora_rank=32,
                    qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
                    head_dim=48)
    return dataclasses.replace(c, **over, name=c.name + "-smoke")
