"""MiniCPM3-4B — dense with MLA [hf:openbmb/MiniCPM3-4B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, act="silu",
    mla=True, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64, head_dim=96,
    rope_theta=10000.0, fog_groups=4,
)
