"""Architecture config schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "silu"           # silu -> SwiGLU, gelu -> GeGLU
    gated_ffn: bool = True      # False -> plain 2-matrix MLP (granite, musicgen)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0

    # ---- MoE ----
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim (deepseek-style fine-grained)
    moe_period: int = 1          # MoE every k-th layer (jamba: 2)
    moe_first_k_dense: int = 0   # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    # shard each expert's d_ff over `model` instead of experts (EP): for
    # small expert counts this replaces the dispatch/combine collectives
    # with one row-parallel all-reduce per MoE layer
    moe_tp_within_expert: bool = False

    # ---- MLA ----
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- SSM (mamba2 / hybrid) ----
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0   # hybrid: 1 attention layer per this many
    attn_layer_offset: int = 0

    # ---- extras ----
    mtp: bool = False            # deepseek multi-token prediction head
    frontend: str | None = None  # 'audio' | 'vlm' -> stub embeddings input
    long_context: bool = False   # eligible for the long_500k cell
    # FoG integration: number of layer groves for confidence-gated exit
    fog_groups: int = 4

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if not self.ssm:
            return True
        if self.attn_layer_period == 0:
            return False           # pure SSM
        return i % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe or i < self.moe_first_k_dense:
            return False
        return (i - self.moe_first_k_dense) % self.moe_period == 0

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)


def param_count(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts — analytic, for 6ND."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb
    for i in range(cfg.n_layers):
        if cfg.ssm and not cfg.is_attn_layer(i):
            di, H, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
            # in_proj: d -> 2*di + 2*G*N + H (z, x, B, C, dt), G=1
            layer = d * (2 * di + 2 * N + H) + cfg.ssm_conv * (di + 2 * N) \
                + 2 * H + di + di * d
        elif cfg.mla:
            r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
            dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
            H = cfg.n_heads
            layer = d * r_q + r_q * H * (dn + dr)          # q path
            layer += d * (r_kv + dr)                        # kv compress + k_rope
            layer += r_kv * H * (dn + dv)                   # kv expand
            layer += H * dv * d                             # o_proj
        else:
            H, K = cfg.n_heads, cfg.n_kv_heads
            layer = d * H * hd + 2 * d * K * hd + H * hd * d
        total += layer
        active_layer = layer
        # FFN / MoE
        n_mats = 3 if cfg.gated_ffn else 2
        if cfg.is_moe_layer(i):
            eff = cfg.moe_d_ff or cfg.d_ff
            ffn_one = 3 * d * eff
            total += cfg.n_experts * ffn_one + cfg.n_shared_experts * ffn_one \
                + d * cfg.n_experts
            active_layer += (cfg.experts_per_token + cfg.n_shared_experts) * ffn_one \
                + d * cfg.n_experts
        else:
            ffn = n_mats * d * cfg.d_ff
            total += ffn
            active_layer += ffn
        active += active_layer
    return total, active
