"""Version compatibility shims for the jax APIs this repo leans on.

The codebase targets the modern public surface (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``); older jax (e.g. the 0.4.x baked into
the CPU container) only exposes those under ``jax._src.mesh`` and returns
a bare ``()`` sentinel when no mesh is set.  Everything routes through
here so model/launch code stays version-agnostic.
"""
from __future__ import annotations

import jax


def get_abstract_mesh():
    """The ambient (abstract) mesh, or None when no mesh context is set."""
    try:
        from jax.sharding import get_abstract_mesh as _get
        mesh = _get()
    except ImportError:                      # jax < 0.6
        from jax._src.mesh import get_abstract_mesh as _get
        mesh = _get()
        if isinstance(mesh, tuple):          # old-jax unset sentinel: ()
            mesh = None
        if mesh is None:
            # legacy `with mesh:` context sets the physical resource env
            from jax._src.mesh import thread_resources
            phys = thread_resources.env.physical_mesh
            mesh = None if phys.empty else phys
    if mesh is None or getattr(mesh, "empty", False):
        return None
    return mesh


def set_mesh(mesh):
    """Context manager pinning ``mesh`` as the ambient mesh.

    New jax: ``jax.set_mesh``.  Old jax: the legacy ``with mesh:`` physical
    mesh context (its private ``set_mesh`` turns on the unfinished
    sharding-in-types mode, which breaks 0.4.x tracing — avoid it).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def jit_shardings(mesh, tree):
    """Make a PartitionSpec pytree acceptable to jax.jit in/out_shardings.

    New jax resolves bare PartitionSpecs against the ambient mesh; old jax
    requires concrete ``NamedSharding``s, so bind ``mesh`` here.  ``None``
    leaves (= infer) pass through on both.
    """
    if hasattr(jax, "set_mesh"):
        return tree
    from jax.sharding import NamedSharding, PartitionSpec

    def bind(s):
        return NamedSharding(mesh, s) if isinstance(s, PartitionSpec) else s

    return jax.tree.map(
        bind, tree,
        is_leaf=lambda s: s is None or isinstance(s, PartitionSpec))
