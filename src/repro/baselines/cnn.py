"""CNN baseline (paper §4.1) — a small LeNet-style net in pure JAX.

Tabular UCI datasets are folded to the nearest square "image" (the paper's
CNN also consumes the raw feature vectors; its energy comes from conv MACs,
which is what we count).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.energy import cnn_energy_pj


def image_side(n_features: int) -> int:
    return max(4, int(math.ceil(math.sqrt(n_features))))


def fold_to_image(x: jax.Array, n_features: int) -> jax.Array:
    side = image_side(n_features)
    pad = side * side - n_features
    x = jnp.pad(x, ((0, 0), (0, pad)))
    return x.reshape(-1, side, side, 1)


def init_cnn(key, n_features: int, n_classes: int,
             channels: tuple[int, int] = (8, 16), dense: int = 64):
    side = image_side(n_features)
    k = jax.random.split(key, 4)
    c1, c2 = channels
    params = {
        "conv1": {"w": jax.random.normal(k[0], (3, 3, 1, c1)) * 0.1,
                  "b": jnp.zeros((c1,))},
        "conv2": {"w": jax.random.normal(k[1], (3, 3, c1, c2)) * 0.1,
                  "b": jnp.zeros((c2,))},
    }
    s = -(-(-(-side // 2)) // 2)  # two stride-2 SAME pools: ceil(ceil(s/2)/2)
    flat = s * s * c2
    params["fc1"] = {"w": jax.random.normal(k[2], (flat, dense)) * jnp.sqrt(2.0 / flat),
                     "b": jnp.zeros((dense,))}
    params["fc2"] = {"w": jax.random.normal(k[3], (dense, n_classes)) * 0.1,
                     "b": jnp.zeros((n_classes,))}
    return params


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def cnn_logits(params, x: jax.Array, n_features: int) -> jax.Array:
    img = fold_to_image(x, n_features)
    h = _pool(_conv(img, params["conv1"]["w"], params["conv1"]["b"]))
    h = _pool(_conv(h, params["conv2"]["w"], params["conv2"]["b"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_energy_nj(n_features: int, n_classes: int,
                  channels: tuple[int, int] = (8, 16), dense: int = 64) -> float:
    side = image_side(n_features)
    c1, c2 = channels
    s1 = -(-side // 2)
    s2 = -(-s1 // 2)
    conv1_macs = side * side * 9 * 1 * c1
    conv2_macs = s1 * s1 * 9 * c1 * c2
    dense_macs = s2 * s2 * c2 * dense + dense * n_classes
    acts = side * side * c1 + s1 * s1 * c2 + dense
    return cnn_energy_pj(conv1_macs + conv2_macs, dense_macs, acts) * 1e-3
