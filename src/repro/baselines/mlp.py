"""MLP baseline (paper §4.1) — plain JAX, trained by baselines.train."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.energy import mlp_energy_pj


def init_mlp(key, n_features: int, n_classes: int,
             hidden: tuple[int, ...] = (128, 64)):
    sizes = (n_features, *hidden, n_classes)
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def mlp_logits(params, x: jax.Array) -> jax.Array:
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def mlp_energy_nj(n_features: int, n_classes: int,
                  hidden: tuple[int, ...] = (128, 64)) -> float:
    return mlp_energy_pj([n_features, *hidden, n_classes]) * 1e-3
