"""Shared trainer for the baseline classifiers (paper §4.1 experimental set).

One minibatch-Adam loop drives all four baselines; each baseline supplies a
(params, logits_fn, loss_fn) triple.  Softmax CE for MLP/CNN, multiclass
hinge for the SVMs (that is what makes them SVMs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import cnn as cnn_mod
from repro.baselines import mlp as mlp_mod
from repro.baselines import svm as svm_mod
from repro.data.synth import Dataset
from repro.optim import adamw


def _xent(scores, y):
    return -jnp.mean(jax.nn.log_softmax(scores)[jnp.arange(scores.shape[0]), y])


@dataclasses.dataclass
class TrainedModel:
    name: str
    params: object
    predict: Callable   # (params, x[B,F]) -> labels [B]
    accuracy: float
    energy_nj: float    # modeled energy per classification


def _fit(params, logits_fn, loss_fn, ds: Dataset, *, epochs=30, batch=128,
         lr=1e-3, weight_decay=1e-4, seed=0):
    init, update = adamw(lr=lr, weight_decay=weight_decay)
    state = init(params)
    x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
    n = x.shape[0]
    steps_per_epoch = max(n // batch, 1)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(logits_fn(p, xb), yb))(params)
        params, state = update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(steps_per_epoch):
            idx = perm[i * batch : (i + 1) * batch]
            params, state, _ = step(params, state, x[idx], y[idx])
    return params


def train_svm_lr(ds: Dataset, seed: int = 0) -> TrainedModel:
    params = svm_mod.init_linear_svm(jax.random.key(seed), ds.n_features, ds.n_classes)
    params = _fit(params, svm_mod.linear_svm_scores, svm_mod.multiclass_hinge_loss,
                  ds, lr=3e-3)
    pred_fn = jax.jit(lambda p, x: jnp.argmax(svm_mod.linear_svm_scores(p, x), -1))
    acc = float(np.mean(np.asarray(pred_fn(params, jnp.asarray(ds.x_test))) == ds.y_test))
    return TrainedModel("svm_lr", params, pred_fn, acc,
                        svm_mod.svm_lr_energy_nj(ds.n_features, ds.n_classes))


def train_svm_rbf(ds: Dataset, seed: int = 0, n_rff: int = 512) -> TrainedModel:
    params = svm_mod.init_rbf_svm(jax.random.key(seed), ds.n_features,
                                  ds.n_classes, n_rff=n_rff)
    lifted_scores = lambda p, x: svm_mod.rbf_svm_scores(p, x)
    # only the linear head trains; omega/phase are the fixed RFF lift
    head = _fit(params.linear,
                lambda lin, x: svm_mod.linear_svm_scores(
                    lin, svm_mod.rff_lift(params, x)),
                svm_mod.multiclass_hinge_loss, ds, lr=3e-3)
    params = svm_mod.RFFParams(params.omega, params.phase, head)
    pred_fn = jax.jit(lambda p, x: jnp.argmax(svm_mod.rbf_svm_scores(p, x), -1))
    acc = float(np.mean(np.asarray(pred_fn(params, jnp.asarray(ds.x_test))) == ds.y_test))
    train_scores = np.asarray(lifted_scores(params, jnp.asarray(ds.x_train)))
    n_sv = svm_mod.count_support_vectors(train_scores, ds.y_train)
    return TrainedModel("svm_rbf", params, pred_fn, acc,
                        svm_mod.svm_rbf_energy_nj(ds.n_features, ds.n_classes, n_sv))


def train_mlp(ds: Dataset, seed: int = 0,
              hidden: tuple[int, ...] = (128, 64)) -> TrainedModel:
    params = mlp_mod.init_mlp(jax.random.key(seed), ds.n_features, ds.n_classes, hidden)
    params = _fit(params, mlp_mod.mlp_logits, _xent, ds)
    pred_fn = jax.jit(lambda p, x: jnp.argmax(mlp_mod.mlp_logits(p, x), -1))
    acc = float(np.mean(np.asarray(pred_fn(params, jnp.asarray(ds.x_test))) == ds.y_test))
    return TrainedModel("mlp", params, pred_fn, acc,
                        mlp_mod.mlp_energy_nj(ds.n_features, ds.n_classes, hidden))


def train_cnn(ds: Dataset, seed: int = 0) -> TrainedModel:
    params = cnn_mod.init_cnn(jax.random.key(seed), ds.n_features, ds.n_classes)
    logits = partial(cnn_mod.cnn_logits, n_features=ds.n_features)
    params = _fit(params, logits, _xent, ds, epochs=20)
    pred_fn = jax.jit(lambda p, x: jnp.argmax(logits(p, x), -1))
    acc = float(np.mean(np.asarray(pred_fn(params, jnp.asarray(ds.x_test))) == ds.y_test))
    return TrainedModel("cnn", params, pred_fn, acc,
                        cnn_mod.cnn_energy_nj(ds.n_features, ds.n_classes))


ALL_BASELINES = {
    "svm_lr": train_svm_lr,
    "svm_rbf": train_svm_rbf,
    "mlp": train_mlp,
    "cnn": train_cnn,
}
