from repro.baselines.train import (
    ALL_BASELINES, TrainedModel, train_cnn, train_mlp, train_svm_lr,
    train_svm_rbf,
)

__all__ = ["ALL_BASELINES", "TrainedModel", "train_cnn", "train_mlp",
           "train_svm_lr", "train_svm_rbf"]
