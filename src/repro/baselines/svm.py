"""SVM baselines (paper §4.1): linear (hinge, one-vs-rest) and RBF.

SVM_RBF is trained in the random-Fourier-feature lift (Rahimi-Recht) — a
linear hinge model over D cosine features approximates the RBF kernel
machine; its *energy* is modeled as the paper measures it, i.e. the exact
kernel evaluation against n_sv support vectors (we count the retained
support set: training examples with nonzero hinge slack).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import svm_lr_energy_pj, svm_rbf_energy_pj


def init_linear_svm(key, n_features: int, n_classes: int):
    return {"w": jax.random.normal(key, (n_features, n_classes)) * 0.01,
            "b": jnp.zeros((n_classes,))}


def linear_svm_scores(params, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def multiclass_hinge_loss(scores: jax.Array, y: jax.Array) -> jax.Array:
    """Crammer-Singer multiclass hinge."""
    B = scores.shape[0]
    correct = scores[jnp.arange(B), y]
    margins = scores - correct[:, None] + 1.0
    margins = margins.at[jnp.arange(B), y].set(0.0)
    return jnp.maximum(margins, 0.0).max(axis=-1).mean()


@partial(jax.tree_util.register_dataclass,
         data_fields=("omega", "phase", "linear"), meta_fields=())
@dataclasses.dataclass
class RFFParams:
    omega: jax.Array   # [F, D] random projection
    phase: jax.Array   # [D]
    linear: dict       # linear svm over the lift


def init_rbf_svm(key, n_features: int, n_classes: int,
                 n_rff: int = 512, gamma: float | None = None) -> RFFParams:
    if gamma is None:
        gamma = 1.0 / n_features
    k1, k2, k3 = jax.random.split(key, 3)
    omega = jax.random.normal(k1, (n_features, n_rff)) * jnp.sqrt(2.0 * gamma)
    phase = jax.random.uniform(k2, (n_rff,), maxval=2 * jnp.pi)
    return RFFParams(omega=omega, phase=phase,
                     linear=init_linear_svm(k3, n_rff, n_classes))


def rff_lift(p: RFFParams, x: jax.Array) -> jax.Array:
    d = p.omega.shape[1]
    return jnp.sqrt(2.0 / d) * jnp.cos(x @ p.omega + p.phase)


def rbf_svm_scores(p: RFFParams, x: jax.Array) -> jax.Array:
    return linear_svm_scores(p.linear, rff_lift(p, x))


def count_support_vectors(scores: np.ndarray, y: np.ndarray) -> int:
    """Examples inside or violating the margin == retained support set."""
    B = scores.shape[0]
    correct = scores[np.arange(B), y]
    others = scores.copy()
    others[np.arange(B), y] = -np.inf
    margin = correct - others.max(axis=-1)
    return int((margin < 1.0).sum())


def svm_lr_energy_nj(n_features: int, n_classes: int) -> float:
    return svm_lr_energy_pj(n_features, n_classes) * 1e-3


def svm_rbf_energy_nj(n_features: int, n_classes: int, n_sv: int) -> float:
    return svm_rbf_energy_pj(n_features, n_classes, n_sv) * 1e-3
