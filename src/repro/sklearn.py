"""FogClassifier — a scikit-learn-style facade over the whole FoG pipeline.

One object wraps forest training (Algorithm 1's GCTrain), the grove split,
FogEngine construction, and policy-driven evaluation:

    from repro.sklearn import FogClassifier
    from repro.core import FogPolicy

    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=8)
    clf.fit(X_train, y_train)
    labels = clf.predict(X_test)                       # default policy
    cheap = clf.predict(X_test, policy=FogPolicy(threshold=0.1))
    print(clf.profile())    # mean hops + nJ/classification accounting

Models persist as versioned ForestPack artifacts and quantize in place:

    clf.quantize("int8")                 # 4x smaller tables, int8 SRAM reads
    clf.save("model.npz")                # packed tables + facade state
    clf2 = FogClassifier.load("model.npz")
    clf2.predict(X_test)                 # identical labels, no retraining

The estimator follows sklearn conventions — ``fit`` returns ``self``,
fitted attributes carry a trailing underscore, ``get_params`` /
``set_params`` support grid searches — without importing sklearn (the
container may not have it).  Every runtime knob goes through
:class:`~repro.core.policy.FogPolicy`: the constructor's ``policy`` is the
default, and each ``predict`` / ``predict_proba`` / ``score`` call accepts a
per-call override (including per-lane threshold vectors and hop budgets).

``profile()`` exposes the paper's energy story for everything classified so
far: every evaluation's :class:`~repro.core.engine.EvalReport` carries its
own hop counts and :class:`~repro.core.energy.EnergyModel` pricing, and the
profile aggregates them.

Energy budgets are first-class: ``set_energy_budget(nj, X_cal, y_cal)``
calibrates a Pareto frontier over the runtime knobs
(:mod:`repro.core.frontier`) and pins the highest-accuracy policy meeting
the budget; ``profile()`` then reports measured-vs-budget, and ``save``
persists the frontier so a loaded model serves under the trained budget
(and can hand the frontier straight to a serving ``EnergyGovernor``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EvalReport, FogEngine
from repro.core.frontier import Frontier, build_frontier, default_grid
from repro.core.grove import split
from repro.core.policy import PRECISIONS, FogPolicy
from repro.forest.pack import ForestPack
from repro.forest.train import TrainConfig, train_random_forest

_PARAMS = ("n_trees", "grove_size", "max_depth", "policy", "backend", "seed",
           "train_cfg", "precision", "trainer")


class FogClassifier:
    """Energy-efficient random-forest classifier (Field of Groves).

    Parameters
    ----------
    n_trees:    forest size n (Algorithm 1 line 2)
    grove_size: trees per grove k (the Split factor); n % k must be 0
    max_depth:  tree depth cap for training
    policy:     default :class:`FogPolicy` for prediction calls
    backend:    default engine backend ("reference" | "pallas" | "fused")
    seed:       training seed, and the fixed start-grove draw for predict
                (fixed so repeated predictions are deterministic)
    train_cfg:  optional full :class:`TrainConfig`; n_trees/max_depth/seed
                above override its corresponding fields
    precision:  default packed-table dtype ("fp32" | "bf16" | "int8") —
                see :meth:`quantize`; per-call policies may still override
    trainer:    ``"host"`` (numpy CART) | ``"device"`` (level-wise
                histogram induction, :mod:`repro.forest.grow`); ``None``
                defers to ``train_cfg.trainer``
    """

    def __init__(self, n_trees: int = 16, grove_size: int = 2,
                 max_depth: int = 8, *, policy: FogPolicy | None = None,
                 backend: str = "reference", seed: int = 0,
                 train_cfg: TrainConfig | None = None,
                 precision: str = "fp32", trainer: str | None = None):
        self.n_trees = n_trees
        self.grove_size = grove_size
        self.max_depth = max_depth
        self.policy = policy if policy is not None else FogPolicy()
        self.backend = backend
        self.seed = seed
        self.train_cfg = train_cfg
        self.precision = precision
        self.trainer = trainer

    # -- sklearn param protocol ------------------------------------------
    def get_params(self, deep: bool = True) -> dict:
        return {k: getattr(self, k) for k in _PARAMS}

    def set_params(self, **params) -> "FogClassifier":
        for k, v in params.items():
            if k not in _PARAMS:
                raise ValueError(f"unknown parameter {k!r}; "
                                 f"valid: {_PARAMS}")
            setattr(self, k, v)
        return self

    # -- fitted artifacts --------------------------------------------------
    # gc_/forest_ are properties so a model loaded from a packed artifact
    # can serve without ever dequantizing: the fp32 views realize only on
    # first access (fit() assigns them directly through the setters).
    @property
    def gc_(self):
        gc = getattr(self, "_gc", None)
        if gc is None:
            if not hasattr(self, "engine_"):
                raise AttributeError("gc_ (classifier is not fitted)")
            gc = self._gc = self.engine_.gcs[0]
        return gc

    @gc_.setter
    def gc_(self, value):
        self._gc = value

    @property
    def forest_(self):
        forest = getattr(self, "_forest", None)
        if forest is None:
            if not hasattr(self, "engine_"):
                raise AttributeError("forest_ (classifier is not fitted)")
            forest = self._forest = self.gc_.as_forest()
        return forest

    @forest_.setter
    def forest_(self, value):
        self._forest = value

    # -- estimator API ----------------------------------------------------
    def fit(self, X, y, n_classes: int | None = None) -> "FogClassifier":
        """GCTrain(n, k, X, y): train the forest, split it into groves,
        build the engine."""
        if self.n_trees % self.grove_size:
            raise ValueError(
                f"n_trees={self.n_trees} must be divisible by "
                f"grove_size={self.grove_size}")
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.int32)
        if n_classes is None:
            n_classes = int(y.max()) + 1
        cfg = self.train_cfg if self.train_cfg is not None else TrainConfig()
        cfg = dataclasses.replace(cfg, n_trees=self.n_trees,
                                  max_depth=self.max_depth, seed=self.seed)
        if self.trainer is not None:
            cfg = dataclasses.replace(cfg, trainer=self.trainer)
        self.forest_ = train_random_forest(X, y, n_classes, cfg)
        self.gc_ = split(self.forest_, self.grove_size)
        self.engine_ = FogEngine(self.gc_, backend=self.backend,
                                 policy=self.policy,
                                 precision=self.precision)
        self.n_classes_ = n_classes
        self.n_features_in_ = X.shape[1]
        self._hops: list[tuple[np.ndarray, str]] = []
        return self

    def _check_fitted(self) -> None:
        if not hasattr(self, "engine_"):
            raise RuntimeError("FogClassifier is not fitted; call fit(X, y)")

    def evaluate(self, X, *, policy: FogPolicy | None = None,
                 key: jax.Array | None = None) -> EvalReport:
        """Full Algorithm-2 evaluation: the EvalReport (proba/label/hops
        plus per-lane ``energy_pj`` and the pricing EnergyModel).

        Start groves are drawn from ``key`` (default: a fixed seed-derived
        key, so repeated calls are deterministic).  Hop counts feed the
        profile accounting.
        """
        self._check_fitted()
        if key is None:
            key = jax.random.key(self.seed)
        res = self.engine_.eval(jnp.asarray(X, jnp.float32), key,
                                policy=policy)
        # the report carries the model it was priced with (the precision
        # the batch actually ran at), so profile() just aggregates reports
        self._hops.append((np.asarray(res.hops), res.model))
        return res

    def predict(self, X, *, policy: FogPolicy | None = None,
                key: jax.Array | None = None) -> np.ndarray:
        """Predicted labels [B]."""
        return np.asarray(self.evaluate(X, policy=policy, key=key).label)

    def predict_proba(self, X, *, policy: FogPolicy | None = None,
                      key: jax.Array | None = None) -> np.ndarray:
        """Hop-normalized class probabilities [B, C]."""
        return np.asarray(self.evaluate(X, policy=policy, key=key).proba)

    def score(self, X, y, *, policy: FogPolicy | None = None,
              key: jax.Array | None = None) -> float:
        """Mean accuracy on (X, y) under the given (or default) policy."""
        return float(np.mean(self.predict(X, policy=policy, key=key)
                             == np.asarray(y)))

    # -- the paper's energy story -----------------------------------------
    def profile(self) -> dict:
        """Hop/energy accounting over everything classified since fit.

        Returns mean hops per input, the modeled energy per classification
        (nJ, each batch priced by its own EvalReport's
        :class:`~repro.core.energy.EnergyModel` — an int8 batch reads fewer
        SRAM bytes per node than an fp32 one of the same hops), totals, and
        the hop histogram — the per-input adaptive-energy distribution that
        is the paper's whole point.  When an energy budget is pinned
        (:meth:`set_energy_budget`), the profile also reports
        measured-vs-budget.
        """
        self._check_fitted()
        budget = getattr(self, "energy_budget_nj_", None)
        if not self._hops:
            out = {"n_classified": 0, "mean_hops": 0.0,
                   "energy_nj_per_classification": 0.0,
                   "total_energy_nj": 0.0, "hops_histogram": {}}
        else:
            hops = np.concatenate([h for h, _ in self._hops])
            total_pj = sum(model.report(h).total_pj
                           for h, model in self._hops)
            vals, counts = np.unique(hops, return_counts=True)
            out = {
                "n_classified": int(hops.size),
                "mean_hops": float(hops.mean()),
                "energy_nj_per_classification": total_pj * 1e-3 / hops.size,
                "total_energy_nj": total_pj * 1e-3,
                "hops_histogram": {int(v): int(c)
                                   for v, c in zip(vals, counts)},
            }
        if budget is not None:
            out["energy_budget_nj"] = float(budget)
            # None until traffic exists: "no evidence yet" is not a breach
            out["within_budget"] = (
                None if out["n_classified"] == 0
                else out["energy_nj_per_classification"] <= budget)
        return out

    def reset_profile(self) -> None:
        """Clear the hop/energy accounting."""
        self._check_fitted()
        self._hops.clear()

    # -- energy budgets ----------------------------------------------------
    def set_energy_budget(self, energy_budget_nj: float, X_cal, y_cal, *,
                          policies=None, key: jax.Array | None = None,
                          ) -> "FogClassifier":
        """Calibrate-and-pin: build the Pareto frontier over the runtime
        knobs on (X_cal, y_cal) and make the highest-accuracy policy
        meeting ``energy_budget_nj`` the default for every subsequent
        ``predict``/``score`` call (paper Fig. 5's operating-point
        selection, pinned on the estimator).

        The calibrated frontier is kept on ``self.frontier_`` (and
        persisted by :meth:`save`), so a serving ``EnergyGovernor`` can
        walk the same ladder the budget was picked from.  The profile
        accounting is reset: measured-vs-budget must describe traffic
        served UNDER the pinned policy, not batches evaluated before the
        budget existed.  Raises ValueError when no policy on the frontier
        fits the budget.  Returns ``self`` (sklearn chaining idiom).
        """
        self._check_fitted()
        if self.policy.per_lane:
            raise ValueError(
                "cannot calibrate a budget on a per-lane default policy "
                "(its threshold/hop_budget vectors are batch-shaped); set "
                "scalar knobs and pass per-lane vectors per call")
        if policies is None:
            # the default grid sweeps threshold x precision ON TOP OF the
            # estimator's configured policy, so knobs the grid does not
            # vary (max_hops, hop_budget, backend, ...) survive the pin
            policies = default_grid(base=self.policy)
        frontier = build_frontier(
            self.engine_, np.asarray(X_cal, np.float32),
            np.asarray(y_cal), policies,
            key if key is not None else jax.random.key(self.seed))
        # select BEFORE committing any state: an unmeetable budget must
        # leave the previous (frontier, budget, policy) triple intact
        point = frontier.under_budget(float(energy_budget_nj))
        self.frontier_ = frontier
        self.energy_budget_nj_ = float(energy_budget_nj)
        self.policy = point.policy
        self.engine_.policy = point.policy
        self.reset_profile()
        return self

    def governor(self, energy_budget_nj: float | None = None, **kw):
        """An :class:`~repro.serve.governor.EnergyGovernor` over this
        model's calibrated frontier (requires :meth:`set_energy_budget`
        first, or a loaded artifact that persisted one), priced by the
        engine's own EnergyModel — ready to hand to
        ``ContinuousBatcher(governor=...)``."""
        from repro.serve.governor import EnergyGovernor
        self._check_fitted()
        if getattr(self, "frontier_", None) is None:
            raise RuntimeError(
                "no calibrated frontier; call set_energy_budget(nj, X_cal, "
                "y_cal) first (or load an artifact that persisted one)")
        budget = (energy_budget_nj if energy_budget_nj is not None
                  else getattr(self, "energy_budget_nj_", None))
        model = self.engine_.energy_model(self.engine_.precision,
                                          self.n_features_in_)
        return EnergyGovernor(self.frontier_, budget, model=model, **kw)

    # -- precision & persistence ------------------------------------------
    def quantize(self, precision: str = "int8") -> "FogClassifier":
        """Switch the default evaluation precision (no retraining).

        The engine's TableCache packs the trained tables at ``precision``
        lazily; subsequent ``predict``/``save`` calls use it by default.
        A default policy that pins its own ``precision`` is re-pinned too
        (the policy knob outranks the engine default, so leaving it would
        silently keep the old dtype).  Returns ``self`` (sklearn chaining
        idiom).
        """
        self._check_fitted()
        if precision not in PRECISIONS:
            raise ValueError(f"unknown precision {precision!r}; "
                             f"pick from {PRECISIONS}")
        self.precision = precision
        self.engine_.precision = precision
        if self.policy.precision is not None:
            self.policy = self.policy.replace(precision=precision)
            self.engine_.policy = self.policy
        return self

    def save(self, path, *, precision: str | None = None):
        """Persist the fitted model as a versioned ForestPack ``.npz``.

        The artifact holds the packed tables at the classifier's default
        precision (or an explicit ``precision=``) plus the facade state
        needed to reconstruct the estimator — including the default
        FogPolicy and, when :meth:`set_energy_budget` calibrated one, the
        energy budget and its Pareto frontier — so the loaded model serves
        under the trained budget; ``FogClassifier.load`` round-trips it
        bit-exactly at the saved precision.  (``train_cfg`` is
        training-time-only state and is not persisted.)  A per-lane default
        policy is batch-shaped and cannot travel with the model.
        """
        self._check_fitted()
        if self.policy.per_lane:
            raise ValueError(
                "cannot save a per-lane default policy (its threshold/"
                "hop_budget vectors are batch-shaped); set scalar knobs on "
                "the default policy and pass per-lane vectors per call")
        # the artifact's pack matches what the model must be able to
        # serve.  With a calibrated frontier aboard, that is EVERY rung:
        # the pack is saved at the highest-fidelity precision any rung
        # uses (an fp32 pack re-quantizes int8 rungs bit-exactly; an int8
        # pack cannot reconstruct an fp32 rung's tables, which would let
        # the governor climb onto rungs whose calibration no longer
        # describes what runs).  Without a frontier, the pinned policy's
        # precision (else the estimator default) keeps the artifact as
        # small as its one operating point needs.
        frontier = getattr(self, "frontier_", None)
        rung_precs = (None if frontier is None else
                      {p.policy.precision for p in frontier.points})
        prec = precision
        if prec is None:
            if rung_precs is not None:
                prec = next(q for q in PRECISIONS
                            if q in rung_precs or None in rung_precs)
            else:
                prec = (self.policy.precision if self.policy.precision
                        is not None else self.precision)
        elif rung_precs is not None:
            # an explicit precision may not strand frontier rungs that
            # need higher fidelity: after load their tables would be
            # rebuilt from the lossier pack and the stored calibration
            # would no longer describe what runs
            needed = next(q for q in PRECISIONS
                          if q in rung_precs or None in rung_precs)
            if PRECISIONS.index(prec) > PRECISIONS.index(needed):
                raise ValueError(
                    f"cannot save at precision={prec!r}: the calibrated "
                    f"frontier carries {needed} rungs whose tables an "
                    f"{prec} pack cannot reconstruct; save without "
                    "precision=, or recalibrate on an all-"
                    f"{prec} grid first")
        pack = self.engine_.tables.pack(prec)
        extra = {
            "estimator": "FogClassifier",
            "n_trees": self.n_trees, "grove_size": self.grove_size,
            "max_depth": self.max_depth, "backend": self.backend,
            "seed": self.seed, "n_classes": self.n_classes_,
            "n_features_in": self.n_features_in_,
            "policy": self.policy.to_dict(),
        }
        if getattr(self, "frontier_", None) is not None:
            extra["frontier"] = self.frontier_.to_dict()
        if getattr(self, "energy_budget_nj_", None) is not None:
            extra["energy_budget_nj"] = self.energy_budget_nj_
        return pack.save(path, extra=extra)

    @classmethod
    def load(cls, path) -> "FogClassifier":
        """Reconstruct a fitted classifier from a ``save`` artifact.

        The loaded engine evaluates the stored pack directly (its precision
        becomes the default), so an int8 artifact serves int8 without ever
        materializing fp32 tables on the accelerator.
        """
        pack, extra = ForestPack.load_with_meta(path)
        if extra.get("estimator") != "FogClassifier":
            raise ValueError(
                f"{path} is a ForestPack artifact but not a FogClassifier "
                f"save (estimator={extra.get('estimator')!r})")
        policy = FogPolicy(**extra["policy"]) if "policy" in extra else None
        clf = cls(n_trees=extra["n_trees"], grove_size=extra["grove_size"],
                  max_depth=extra["max_depth"], backend=extra["backend"],
                  seed=extra["seed"], precision=pack.precision,
                  policy=policy)
        # gc_/forest_ stay lazy: the engine evaluates the stored pack
        # directly, so loading an int8 artifact never materializes fp32
        # tables unless a caller asks for the dequantized views
        clf.engine_ = FogEngine(pack, backend=clf.backend, policy=clf.policy)
        clf.n_classes_ = extra["n_classes"]
        clf.n_features_in_ = extra["n_features_in"]
        if "frontier" in extra:
            clf.frontier_ = Frontier.from_dict(extra["frontier"])
            try:
                # under_budget/ladder assume the Pareto invariant; a
                # corrupted or hand-edited artifact must fail at load, not
                # silently resolve budgets to a lower-accuracy point
                clf.frontier_.check_monotone()
            except AssertionError as e:
                raise ValueError(
                    f"{path}: persisted frontier is corrupt: {e}") from e
        if "energy_budget_nj" in extra:
            clf.energy_budget_nj_ = float(extra["energy_budget_nj"])
        clf._hops = []
        return clf

    # -- repr --------------------------------------------------------------
    def __repr__(self) -> str:
        # engine metadata, not gc_: repr must never trigger a dequantize
        fitted = (f", fitted {self.engine_.n_groves}x{self.grove_size}"
                  if hasattr(self, "engine_") else "")
        return (f"FogClassifier(n_trees={self.n_trees}, "
                f"grove_size={self.grove_size}, max_depth={self.max_depth}, "
                f"backend={self.backend!r}{fitted})")
