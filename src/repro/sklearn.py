"""FogClassifier — a scikit-learn-style facade over the whole FoG pipeline.

One object wraps forest training (Algorithm 1's GCTrain), the grove split,
FogEngine construction, and policy-driven evaluation:

    from repro.sklearn import FogClassifier
    from repro.core import FogPolicy

    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=8)
    clf.fit(X_train, y_train)
    labels = clf.predict(X_test)                       # default policy
    cheap = clf.predict(X_test, policy=FogPolicy(threshold=0.1))
    print(clf.profile())    # mean hops + nJ/classification accounting

The estimator follows sklearn conventions — ``fit`` returns ``self``,
fitted attributes carry a trailing underscore, ``get_params`` /
``set_params`` support grid searches — without importing sklearn (the
container may not have it).  Every runtime knob goes through
:class:`~repro.core.policy.FogPolicy`: the constructor's ``policy`` is the
default, and each ``predict`` / ``predict_proba`` / ``score`` call accepts a
per-call override (including per-lane threshold vectors and hop budgets).

``profile()`` exposes the paper's energy story for everything classified so
far: per-input hop counts are recorded at each evaluation and the energies
come from :func:`~repro.core.energy.fog_energy`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import fog_energy
from repro.core.engine import FogEngine, FogResult
from repro.core.grove import split
from repro.core.policy import FogPolicy
from repro.forest.train import TrainConfig, train_random_forest

_PARAMS = ("n_trees", "grove_size", "max_depth", "policy", "backend", "seed",
           "train_cfg")


class FogClassifier:
    """Energy-efficient random-forest classifier (Field of Groves).

    Parameters
    ----------
    n_trees:    forest size n (Algorithm 1 line 2)
    grove_size: trees per grove k (the Split factor); n % k must be 0
    max_depth:  tree depth cap for training
    policy:     default :class:`FogPolicy` for prediction calls
    backend:    default engine backend ("reference" | "pallas" | "fused")
    seed:       training seed, and the fixed start-grove draw for predict
                (fixed so repeated predictions are deterministic)
    train_cfg:  optional full :class:`TrainConfig`; n_trees/max_depth/seed
                above override its corresponding fields
    """

    def __init__(self, n_trees: int = 16, grove_size: int = 2,
                 max_depth: int = 8, *, policy: FogPolicy | None = None,
                 backend: str = "reference", seed: int = 0,
                 train_cfg: TrainConfig | None = None):
        self.n_trees = n_trees
        self.grove_size = grove_size
        self.max_depth = max_depth
        self.policy = policy if policy is not None else FogPolicy()
        self.backend = backend
        self.seed = seed
        self.train_cfg = train_cfg

    # -- sklearn param protocol ------------------------------------------
    def get_params(self, deep: bool = True) -> dict:
        return {k: getattr(self, k) for k in _PARAMS}

    def set_params(self, **params) -> "FogClassifier":
        for k, v in params.items():
            if k not in _PARAMS:
                raise ValueError(f"unknown parameter {k!r}; "
                                 f"valid: {_PARAMS}")
            setattr(self, k, v)
        return self

    # -- estimator API ----------------------------------------------------
    def fit(self, X, y, n_classes: int | None = None) -> "FogClassifier":
        """GCTrain(n, k, X, y): train the forest, split it into groves,
        build the engine."""
        if self.n_trees % self.grove_size:
            raise ValueError(
                f"n_trees={self.n_trees} must be divisible by "
                f"grove_size={self.grove_size}")
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.int32)
        if n_classes is None:
            n_classes = int(y.max()) + 1
        cfg = self.train_cfg if self.train_cfg is not None else TrainConfig()
        cfg = dataclasses.replace(cfg, n_trees=self.n_trees,
                                  max_depth=self.max_depth, seed=self.seed)
        self.forest_ = train_random_forest(X, y, n_classes, cfg)
        self.gc_ = split(self.forest_, self.grove_size)
        self.engine_ = FogEngine(self.gc_, backend=self.backend,
                                 policy=self.policy)
        self.n_classes_ = n_classes
        self.n_features_in_ = X.shape[1]
        self._hops: list[np.ndarray] = []
        return self

    def _check_fitted(self) -> None:
        if not hasattr(self, "engine_"):
            raise RuntimeError("FogClassifier is not fitted; call fit(X, y)")

    def evaluate(self, X, *, policy: FogPolicy | None = None,
                 key: jax.Array | None = None) -> FogResult:
        """Full Algorithm-2 evaluation: the FogResult (proba/label/hops).

        Start groves are drawn from ``key`` (default: a fixed seed-derived
        key, so repeated calls are deterministic).  Hop counts feed the
        profile accounting.
        """
        self._check_fitted()
        if key is None:
            key = jax.random.key(self.seed)
        res = self.engine_.eval(jnp.asarray(X, jnp.float32), key,
                                policy=policy)
        self._hops.append(np.asarray(res.hops))
        return res

    def predict(self, X, *, policy: FogPolicy | None = None,
                key: jax.Array | None = None) -> np.ndarray:
        """Predicted labels [B]."""
        return np.asarray(self.evaluate(X, policy=policy, key=key).label)

    def predict_proba(self, X, *, policy: FogPolicy | None = None,
                      key: jax.Array | None = None) -> np.ndarray:
        """Hop-normalized class probabilities [B, C]."""
        return np.asarray(self.evaluate(X, policy=policy, key=key).proba)

    def score(self, X, y, *, policy: FogPolicy | None = None,
              key: jax.Array | None = None) -> float:
        """Mean accuracy on (X, y) under the given (or default) policy."""
        return float(np.mean(self.predict(X, policy=policy, key=key)
                             == np.asarray(y)))

    # -- the paper's energy story -----------------------------------------
    def profile(self) -> dict:
        """Hop/energy accounting over everything classified since fit.

        Returns mean hops per input, the modeled energy per classification
        (nJ, from :func:`fog_energy`'s per-op 40/45nm accounting), totals,
        and the hop histogram — the per-input adaptive-energy distribution
        that is the paper's whole point.
        """
        self._check_fitted()
        if not self._hops:
            return {"n_classified": 0, "mean_hops": 0.0,
                    "energy_nj_per_classification": 0.0,
                    "total_energy_nj": 0.0, "hops_histogram": {}}
        hops = np.concatenate(self._hops)
        rep = fog_energy(hops, self.gc_.grove_size, self.gc_.depth,
                         self.gc_.n_classes, self.n_features_in_)
        vals, counts = np.unique(hops, return_counts=True)
        return {
            "n_classified": int(hops.size),
            "mean_hops": float(hops.mean()),
            "energy_nj_per_classification": rep.per_example_nj,
            "total_energy_nj": rep.total_pj * 1e-3,
            "hops_histogram": {int(v): int(c) for v, c in zip(vals, counts)},
        }

    def reset_profile(self) -> None:
        """Clear the hop/energy accounting."""
        self._check_fitted()
        self._hops.clear()

    # -- repr --------------------------------------------------------------
    def __repr__(self) -> str:
        fitted = f", fitted {self.gc_.n_groves}x{self.gc_.grove_size}" \
            if hasattr(self, "gc_") else ""
        return (f"FogClassifier(n_trees={self.n_trees}, "
                f"grove_size={self.grove_size}, max_depth={self.max_depth}, "
                f"backend={self.backend!r}{fitted})")
