"""Serving steps: prefill + decode (+ FoG early-exit decode), pjit-ready."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.core.policy import FogPolicy, margin_backend
from repro.launch.mesh import dp_axes
from repro.launch.sharding import cache_shardings, param_shardings
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog
from repro.train.loop import SHAPES, input_specs


def make_serve_step(cfg: ArchConfig, mesh, shape: str, *, fog: bool = False,
                    policy: FogPolicy | None = None,
                    fog_thresh: float = 0.5, fog_backend: str = "reference",
                    param_dtype=jnp.bfloat16):
    """Jitted one-token decode with in/out shardings.

    Returns (jitted_fn, (params_shape, cache_shape, inputs_shape)).
    fn(params, cache, token|embeds, length) -> (logits, new_cache[, hops])

    With ``fog=True`` the decode step takes the per-lane runtime knobs as
    *traced* inputs — fn(params, cache, token|embeds, length, thresh [B],
    budget [B]) — so a single compiled program serves mixed-QoS traffic;
    ``inputs_shape`` gains matching ``fog_thresh`` / ``fog_budget``
    entries.  ``policy`` supplies the static knobs (confidence backend);
    the legacy ``fog_thresh`` / ``fog_backend`` kwargs are folded into a
    policy when none is given.
    """
    sp = SHAPES[shape]
    assert sp.kind == "decode", shape
    B, S = sp.global_batch, sp.seq_len
    if policy is None:
        policy = FogPolicy(threshold=fog_thresh, backend=fog_backend)
    gate_backend = margin_backend(policy.backend)

    params_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, param_dtype), jax.random.key(0))
    p_specs = param_shardings(cfg, mesh, params_shape)
    cache_shape = jax.eval_shape(
        partial(T.cache_init, cfg, B, S, param_dtype))
    c_specs = cache_shardings(cfg, mesh, cache_shape)
    inp = input_specs(cfg, shape)
    dp = dp_axes(mesh)
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bdp = dp if B % dp_size == 0 else ()   # batch=1 (long_500k): replicate
    i_specs = {k: (P(bdp, *([None] * (len(v.shape) - 1))) if v.shape else P())
               for k, v in inp.items()}

    logit_m = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    if fog:
        def step(params, cache, token, length, thresh, budget, embeds=None):
            lane_policy = policy.replace(threshold=thresh, hop_budget=budget)
            logits, cache, hops = decode_step_fog(
                params, cfg, token, cache, length, lane_policy,
                embeds=embeds, backend=gate_backend)
            return logits, cache, hops
        out_specs = (P(bdp, logit_m), c_specs, P(bdp))
        inp = dict(inp)
        inp["fog_thresh"] = jax.ShapeDtypeStruct((B,), jnp.float32)
        inp["fog_budget"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        knob_specs = (P(bdp), P(bdp))

        if cfg.frontend:
            def wrapped(params, cache, embeds, length, thresh, budget):
                return step(params, cache, None, length, thresh, budget,
                            embeds=embeds)
            in_specs = (p_specs, c_specs, i_specs["embeds"], P(), *knob_specs)
        else:
            def wrapped(params, cache, token, length, thresh, budget):
                return step(params, cache, token, length, thresh, budget)
            in_specs = (p_specs, c_specs, i_specs["token"], P(), *knob_specs)
    else:
        def step(params, cache, token, length, embeds=None):
            logits, cache = T.decode_step(params, cfg, token, cache, length,
                                          embeds=embeds)
            return logits, cache
        out_specs = (P(bdp, logit_m), c_specs)

        if cfg.frontend:
            def wrapped(params, cache, embeds, length):
                return step(params, cache, None, length, embeds=embeds)
            in_specs = (p_specs, c_specs, i_specs["embeds"], P())
        else:
            def wrapped(params, cache, token, length):
                return step(params, cache, token, length)
            in_specs = (p_specs, c_specs, i_specs["token"], P())

    jitted = jax.jit(
        wrapped,
        in_shardings=compat.jit_shardings(mesh, in_specs),
        out_shardings=compat.jit_shardings(mesh, out_specs))
    return jitted, (params_shape, cache_shape, inp)


def make_prefill_step(cfg: ArchConfig, mesh, shape: str, *,
                      param_dtype=jnp.bfloat16):
    """Jitted prefill for the prefill_32k cells."""
    sp = SHAPES[shape]
    assert sp.kind == "prefill", shape
    params_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, param_dtype), jax.random.key(0))
    p_specs = param_shardings(cfg, mesh, params_shape)
    inp = input_specs(cfg, shape)
    dp = dp_axes(mesh)
    i_specs = {k: P(dp, *([None] * (len(v.shape) - 1))) for k, v in inp.items()}

    def step(params, **inputs):
        return T.prefill(params, cfg, tokens=inputs.get("tokens"),
                         embeds=inputs.get("embeds"))

    key = "embeds" if cfg.frontend else "tokens"

    def wrapped(params, x):
        return step(params, **{key: x})

    jitted = jax.jit(
        wrapped,
        in_shardings=compat.jit_shardings(mesh, (p_specs, i_specs[key])),
        out_shardings=None)
    return jitted, (params_shape, inp)
