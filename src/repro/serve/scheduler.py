"""Batched request scheduler for serving (continuous batching + FoG).

A slot-based continuous-batching scheduler: a fixed decode batch of
``n_slots`` lanes; finished/empty lanes are refilled from the request queue
each step (the standard vLLM-style slot model, minus paged KV — caches here
are dense per-slot rings).  With FoG decode enabled, per-step grove usage
(hops) is accumulated per request, giving the per-request energy/FLOP
accounting that mirrors the paper's per-input hop counter.

Mixed-QoS serving: every :class:`Request` may carry its own
:class:`~repro.core.policy.FogPolicy` (threshold / hop budget).  Each step
the scheduler assembles the slots' scalar policies into one per-lane batch
policy (:func:`repro.core.policy.assemble`) and hands it to a policy-aware
``decode_fn(tokens, lengths, policy)`` — one continuous batch, one compiled
program, every lane buying its own accuracy/energy point.  Legacy two-arg
``decode_fn(tokens, lengths)`` callables keep working unchanged.

Mixed-precision serving: a request's policy may additionally set
``precision`` ("fp32" | "bf16" | "int8" packed tables).  Precision selects
a compiled program, so it cannot ride the per-lane vectors; instead the
scheduler buckets the step's slots by precision and dispatches ``decode_fn``
once per distinct precision present (each call still carries the full
per-lane threshold/budget vectors; each slot's outputs are harvested from
its own precision's call).  A homogeneous batch — the common case — still
costs exactly one dispatch.

Data-parallel serving: hand the batcher a
:class:`~repro.serve.dispatch.DeviceDispatcher` instead of a ``decode_fn``
and each precision group's dispatch fans out across the dispatcher's device
replicas (fixed per-device slot spans, per-device dispatch queues, one
deferred ``jax.block_until_ready`` at harvest) — the slot model, policy
assembly and telemetry are unchanged; only the execution plane widens.

Admission control: ``max_queue`` bounds the request queue.  When it is
full, ``shed_policy`` decides who pays: ``"reject"`` sheds the incoming
request (``submit`` returns False), ``"oldest"`` evicts the oldest queued
request to admit the new one.  Shed requests are marked ``req.shed``,
collected in ``batcher.shed_requests``, and counted in
``ServeStats.n_shed`` / ``shed_rate`` — overload becomes a measured,
bounded signal instead of an unbounded latency tail.

Energy governance: install an :class:`~repro.serve.governor.EnergyGovernor`
and the batcher serves under an nJ/classification SLO — each step's default
policy is the governor's active ladder rung, every step's hop telemetry
feeds its rolling estimate, and the governor steps down the ladder (tighten
threshold -> int8 -> cut hop budget) on a breach, back up when headroom
returns.  A request may carry ``energy_budget_nj`` instead of an explicit
policy: the governor resolves it against the calibrated frontier into the
highest-accuracy rung fitting that budget (hop budget clamped so the
contract is hard).  Telemetry lives in :class:`ServeStats` — the old
``HopMeter`` plumbing survives only as a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import heapq
import inspect
import time
import warnings
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HopMeter
from repro.core.policy import (BUDGET_DEFAULT, NO_BUDGET, THRESH_DEFAULT,
                               FogPolicy, assemble, lane_knobs)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new_tokens: int = 32
    # per-request QoS contract (scalar threshold / hop budget); None = the
    # batcher's default policy
    policy: FogPolicy | None = None
    # per-request energy contract: resolved at submit() into a policy via
    # the batcher's governor (mutually exclusive with an explicit policy)
    energy_budget_nj: float | None = None
    # registry tenant this request evaluates against (None = the batcher's
    # single built-in model, the pre-registry behavior)
    model: str | None = None
    # QoS tier label for per-tier shed/done/energy telemetry (ServeStats
    # breaks out counters per distinct label)
    tier: str = "default"
    # the registry version serving this request: resolved ONCE at slot
    # assignment (registry.route) and pinned, so a hot-swap mid-decode
    # never migrates an in-flight request between versions.  Pre-set it to
    # bypass routing.
    version: int | None = None
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    hops: list = dataclasses.field(default_factory=list)
    done: bool = False
    # set by admission control when the request is dropped under overload
    shed: bool = False
    # wall-clock stamps for latency accounting: submit() stamps t_submit
    # (shed requests included — the shed tail is part of the latency
    # story), completion stamps t_done.  Callers may pre-stamp t_submit.
    t_submit: float | None = None
    t_done: float | None = None

    @property
    def tenant(self) -> str | None:
        """Alias of ``model`` (the registry/ledger vocabulary)."""
        return self.model


@dataclasses.dataclass
class ServeStats:
    """Fleet-level serving telemetry (replaces the deprecated HopMeter):
    hop counts of every decoded event, plus modeled pJ when a governor (or
    its energy model) is installed to price them."""

    total_hops: int = 0
    n_events: int = 0
    total_pj: float = 0.0
    has_energy: bool = False
    # events that actually carried a pJ price — the mean_energy_nj
    # denominator.  Mixing priced and unpriced updates (governor installed
    # mid-run, hops-only telemetry) must not deflate the mean.
    n_priced: int = 0
    # admission-control counters (bounded queue)
    n_offered: int = 0
    n_shed: int = 0
    # per-QoS-tier breakdown: tier label -> {n_done, n_shed, n_events,
    # total_pj, n_priced}.  Canary judging and gold-tier SLOs need the
    # split the fleet totals average away.
    tiers: dict = dataclasses.field(default_factory=dict)

    def _tier(self, tier: str) -> dict:
        t = self.tiers.get(tier)
        if t is None:
            t = self.tiers[tier] = {"n_done": 0, "n_shed": 0, "n_events": 0,
                                    "total_pj": 0.0, "n_priced": 0}
        return t

    def note_shed(self, tier: str = "default") -> None:
        self.n_shed += 1
        self._tier(tier)["n_shed"] += 1

    def note_done(self, tier: str = "default") -> None:
        self._tier(tier)["n_done"] += 1

    def note_done_many(self, counts: dict) -> None:
        """Batched :meth:`note_done`: ``{tier: completions}`` — one dict
        walk per harvest instead of a lookup per completed lane."""
        for tier, k in counts.items():
            self._tier(tier)["n_done"] += k

    def update(self, hops, energy_pj=None, tiers=None) -> None:
        """Fold one batch of decoded events in.  ``energy_pj`` may carry
        NaN for events nothing could price (a ledgered batch with an
        unledgered tenant) — only finite entries feed the energy totals.
        ``tiers`` optionally labels each event with its request's QoS tier
        for the per-tier breakdown."""
        h = np.asarray(hops)
        self.total_hops += int(h.sum())
        self.n_events += int(h.size)
        priced = None
        if energy_pj is not None:
            e = np.asarray(energy_pj, np.float64)
            priced = np.isfinite(e)
            self.total_pj += float(e[priced].sum())
            self.n_priced += int(priced.sum())
            if priced.any():
                self.has_energy = True
        if tiers is not None:
            e = (np.asarray(energy_pj, np.float64)
                 if energy_pj is not None else None)
            for i, tier in enumerate(tiers):
                t = self._tier(tier)
                t["n_events"] += 1
                if priced is not None and priced[i]:
                    t["total_pj"] += float(e[i])
                    t["n_priced"] += 1

    def tier_summary(self) -> dict:
        """{tier: {n_done, n_shed, n_events, mean_energy_nj}} — the
        per-tier view the fleet means hide."""
        return {tier: {"n_done": t["n_done"], "n_shed": t["n_shed"],
                       "n_events": t["n_events"],
                       "mean_energy_nj": t["total_pj"] * 1e-3
                       / max(1, t["n_priced"])}
                for tier, t in sorted(self.tiers.items())}

    def reset(self) -> None:
        self.total_hops = 0
        self.n_events = 0
        self.total_pj = 0.0
        self.has_energy = False
        self.n_priced = 0
        self.n_offered = 0
        self.n_shed = 0
        self.tiers = {}

    @property
    def mean_hops(self) -> float:
        return self.total_hops / max(1, self.n_events)

    @property
    def mean_energy_nj(self) -> float:
        """Mean modeled nJ per PRICED decoded event (0.0 until priced
        telemetry arrives).  Unpriced events (no governor / hops-only
        updates) are excluded from the denominator."""
        return self.total_pj * 1e-3 / max(1, self.n_priced)

    @property
    def shed_rate(self) -> float:
        """Fraction of offered requests shed by admission control."""
        return self.n_shed / max(1, self.n_offered)

    def summary(self, n_groves: int) -> str:
        s = (f"hops/event {self.mean_hops:.2f} "
             f"(grove fraction {self.mean_hops / max(1, n_groves):.2f}, "
             f"{self.n_events} events)")
        if self.has_energy:
            s += f", {self.mean_energy_nj:.3f} nJ/event"
        if self.n_shed:
            s += (f", shed {self.n_shed}/{self.n_offered} "
                  f"({100 * self.shed_rate:.1f}%)")
        return s


@dataclasses.dataclass
class SlotState:
    request: Request | None = None
    length: int = 0               # tokens already in this slot's cache


def _policy_mode(decode_fn: Callable) -> str:
    """How decode_fn accepts the batch policy.

    ``"positional"``  three-plus positional params (or ``*args``): called
                      ``decode_fn(tokens, lengths, policy)``
    ``"keyword"``     a KEYWORD_ONLY ``policy`` param (also reachable
                      through ``functools.partial`` / ``jax.jit`` wrappers,
                      whose signatures follow ``__wrapped__``): called
                      ``decode_fn(tokens, lengths, policy=policy)``
    ``"legacy"``      two-arg decode; never sees a policy
    """
    try:
        params = inspect.signature(decode_fn).parameters.values()
    except (TypeError, ValueError):   # builtins / C callables: assume legacy
        return "legacy"
    positional = [p for p in params
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    if (len(positional) >= 3
            or any(p.kind == p.VAR_POSITIONAL for p in params)):
        return "positional"
    if any(p.kind == p.KEYWORD_ONLY and p.name == "policy" for p in params):
        return "keyword"
    return "legacy"


def _takes_policy(decode_fn: Callable) -> bool:
    """Does decode_fn accept a policy argument (positional or kw-only)?"""
    return _policy_mode(decode_fn) != "legacy"


def _takes_bucket(decode_fn: Callable) -> bool:
    """Does decode_fn accept a ``bucket`` keyword ((model, version)
    routing for registry-backed multi-tenant serving)?"""
    try:
        params = inspect.signature(decode_fn).parameters
    except (TypeError, ValueError):
        return False
    p = params.get("bucket")
    return p is not None and p.kind in (p.KEYWORD_ONLY,
                                        p.POSITIONAL_OR_KEYWORD)


class ContinuousBatcher:
    """Drives decode_fn over a fixed slot batch, refilling as lanes finish.

    decode_fn(tokens [n_slots] int32, lengths [n_slots] int32
              [, policy: FogPolicy with per-lane [n_slots] knobs])
        -> (logits [n_slots, V], hops [n_slots] | None)
        (the policy param may be positional or KEYWORD_ONLY ``*, policy``)
    prefill_fn(slot, prompt) -> int  (returns prompt length in cache)
    default_policy: applied to slots whose request carries no policy (and
        to empty lanes); its static knobs select the compiled program.
    governor: optional EnergyGovernor — when set, the *governor's active
        rung* replaces default_policy each step, per-step hop telemetry
        feeds its rolling estimate, and requests may carry
        ``energy_budget_nj`` contracts.
    dispatcher: optional :class:`~repro.serve.dispatch.DeviceDispatcher` —
        the data-parallel execution plane.  Mutually exclusive with
        ``decode_fn`` (pass ``decode_fn=None``); always policy-aware.
    max_queue: admission-control bound on the request queue (None =
        unbounded, the pre-existing behavior).
    shed_policy: who is shed when the queue is full — ``"reject"`` the
        incoming request (submit returns False) or evict the ``"oldest"``
        queued request.
    meter: DEPRECATED — pass nothing and read ``batcher.stats`` instead.
    """

    def __init__(self, n_slots: int, decode_fn: Callable | None,
                 prefill_fn: Callable, eos_id: int = 1,
                 meter=None, default_policy: FogPolicy | None = None,
                 governor=None, dispatcher=None,
                 max_queue: int | None = None, shed_policy: str = "reject",
                 registry=None, pipeline: bool = False,
                 telemetry_every: int = 1):
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.eos_id = eos_id
        self.completed: list[Request] = []
        self.default_policy = (default_policy if default_policy is not None
                               else FogPolicy())
        if self.default_policy.per_lane:
            raise ValueError(
                "default_policy must carry scalar knobs; the batcher "
                "assembles the per-lane vectors itself each step")
        # ``governor`` accepts either one EnergyGovernor (fleet-wide SLO)
        # or a TenantLedger (per-tenant SLOs, one governor per tenant)
        self.ledger = None
        if governor is not None and hasattr(governor, "governor_for"):
            self.ledger = governor
            governor = None
        self.governor = governor
        self.dispatcher = dispatcher
        self.registry = registry
        self._packed = False
        if dispatcher is not None:
            if decode_fn is not None:
                raise ValueError(
                    "pass either decode_fn or dispatcher, not both (the "
                    "dispatcher owns the per-device decode replicas)")
            dispatcher.bind(n_slots)
            self._packed = dispatcher.packed
            self._policy_mode = "dispatch"
        else:
            if decode_fn is None:
                raise ValueError(
                    "decode_fn is required when no dispatcher is given")
            self._policy_mode = _policy_mode(decode_fn)
        self._policy_aware = self._policy_mode != "legacy"
        if shed_policy not in ("reject", "oldest"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             "pick 'reject' or 'oldest'")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None = unbounded)")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.shed_requests: list[Request] = []
        # the dispatcher's drained Pending list from the last step (device /
        # precision / lane bookkeeping for the load harness)
        self.last_dispatches: list = []
        # maintained per-lane decode inputs (empty lanes stay 0): rebuilding
        # these with a per-slot Python loop every step is measurable serial
        # time at serving-scale slot counts
        self._tokens = np.zeros((n_slots,), np.int32)
        self._lengths = np.zeros((n_slots,), np.int32)
        if governor is not None:
            # a governor that can never act must be rejected loudly — a
            # silently unenforced SLO is worse than no governor at all
            if governor.model is None:
                raise ValueError(
                    "the batcher's governor needs an energy model to price "
                    "hop telemetry; construct EnergyGovernor(..., "
                    "model=...)")
            if not self._policy_aware:
                raise ValueError(
                    "a governor needs a policy-aware decode_fn(tokens, "
                    "lengths, policy) — a legacy two-arg decode_fn would "
                    "never serve the governor's rung policy")
        if self.ledger is not None and not self._policy_aware:
            raise ValueError(
                "a tenant ledger needs a policy-aware decode path — a "
                "legacy two-arg decode_fn would never serve any tenant's "
                "rung policy")
        # can this execution plane route (model, version) buckets?  The
        # dispatcher introspects its replicas at bind; a plain decode_fn
        # must take a ``bucket`` keyword itself.
        if dispatcher is not None:
            self._bucket_aware = dispatcher.bucket_aware
        else:
            self._bucket_aware = _takes_bucket(decode_fn)
        # -- packed fast path (device-resident slot state) ----------------
        if pipeline and not self._packed:
            raise ValueError(
                "pipeline=True needs a packed dispatcher (replicas built "
                "from ForestReplicaServer.packed_factory — resident slot "
                "state is what makes overlapping dispatch with host "
                "bookkeeping safe)")
        if telemetry_every < 1:
            raise ValueError("telemetry_every must be >= 1")
        if telemetry_every > 1 and not self._packed:
            raise ValueError(
                "telemetry_every > 1 needs the packed dispatch path "
                "(the legacy step accounts inline)")
        self.pipeline = bool(pipeline)
        self.telemetry_every = int(telemetry_every)
        # empty-slot min-heap + occupancy mask: the packed step never walks
        # all n_slots in Python — refill pops the heap, harvest walks only
        # the occupied lanes, bucket membership is maintained incrementally
        self._free: list[int] = list(range(n_slots))
        self._occ_mask = np.zeros((n_slots,), bool)
        self._n_active = 0
        self._bucket_lanes: dict[tuple, set[int]] = {}
        self._lane_key: list[tuple | None] = [None] * n_slots
        self._inflight = False
        self._inflight_occ: np.ndarray | None = None
        self._tel_buf: list[tuple] = []
        self._steps_since_flush = 0
        # per-phase host-time accumulators (ns) for the packed step — the
        # bench-serve-profile breakdown reads these
        self.phase_ns = {"harvest": 0, "bookkeep": 0, "telemetry": 0,
                         "refill": 0, "dispatch": 0}
        self.n_steps = 0
        # fleet-level FoG accounting: hop counts (and, with a governor's
        # energy model, modeled pJ) of every decoded token
        self.stats = ServeStats()
        if meter is not None:
            warnings.warn(
                "ContinuousBatcher(meter=...) is deprecated; per-step "
                "telemetry lives in batcher.stats (and the governor's "
                "rolling estimate)", DeprecationWarning, stacklevel=2)
        self._meter = meter

    @property
    def meter(self):
        """DEPRECATED — legacy readers of the always-present HopMeter get
        a shim seeded from ``stats`` (same totals), plus the warning."""
        if self._meter is None:
            warnings.warn(
                "ContinuousBatcher.meter is deprecated; read "
                "batcher.stats (ServeStats) instead",
                DeprecationWarning, stacklevel=2)
            m = HopMeter.__new__(HopMeter)   # we already warned just above
            m.total_hops = self.stats.total_hops
            m.n_events = self.stats.n_events
            self._meter = m
        return self._meter

    def submit(self, req: Request) -> bool:
        """Validate, resolve energy contracts, then admit or shed.

        Returns True if the request was admitted to the queue, False if it
        was shed by admission control (``shed_policy="reject"`` with a full
        queue).  Invalid requests still raise — shedding is a load signal,
        not an error-swallowing path.
        """
        # stamp at the door: shed requests carry a submit time too (the
        # shed tail is part of the latency story), admitted requests keep
        # any pre-stamp the harness set
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        if req.model is not None:
            if self.registry is None and req.version is None:
                raise ValueError(
                    f"request {req.rid}: Request.model={req.model!r} needs "
                    "a registry to resolve the serving version (construct "
                    "ContinuousBatcher(..., registry=ModelRegistry(dir)), "
                    "or pre-set Request.version)")
            if not self._bucket_aware:
                raise ValueError(
                    f"request {req.rid}: Request.model={req.model!r} needs "
                    "a bucket-aware decode path (a decode_fn/replica "
                    "taking bucket=) to route (model, version) buckets")
        if req.energy_budget_nj is not None:
            if req.policy is not None:
                raise ValueError(
                    f"request {req.rid}: pass either policy or "
                    "energy_budget_nj, not both (the budget is resolved "
                    "into a policy)")
            gov = self.governor
            if gov is None and self.ledger is not None:
                gov = self.ledger.governor_for(req.tenant)
            if gov is None:
                raise ValueError(
                    f"request {req.rid}: energy_budget_nj needs a "
                    "governor (construct ContinuousBatcher(..., "
                    "governor=EnergyGovernor(frontier, ...)) or ledger "
                    "an EnergyGovernor for this request's tenant)")
            pol = gov.policy_for_budget(req.energy_budget_nj)
            # the per-request contract is the per-lane/bucketed knobs only
            # (threshold, hop budget, precision); any static knobs the
            # ladder rung inherited from the fleet default (backend,
            # max_hops, ...) stay with the fleet default — they select the
            # compiled program and would otherwise trip the static-knob
            # rejection below
            req.policy = FogPolicy(threshold=pol.threshold,
                                   hop_budget=pol.hop_budget,
                                   precision=pol.precision)
        if req.policy is not None:
            if req.policy.per_lane:
                raise ValueError(
                    f"request {req.rid}: per-request policies are scalar "
                    "contracts; the batcher assembles the per-lane vectors")
            # precision is static too, but the batcher handles it by
            # dispatching one program per precision group (see step())
            rejected = tuple(k for k in req.policy.static_overrides
                             if k != "precision")
            if rejected:
                raise ValueError(
                    f"request {req.rid}: policy sets static knobs "
                    f"{rejected} — those select the "
                    "compiled program and cannot vary per request; set "
                    "them on the batcher's default_policy (per-request "
                    "knobs are threshold, hop_budget and precision)")
        self.stats.n_offered += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.shed_policy == "reject":
                self._shed(req)
                return False
            # "oldest": evict the head of the queue to admit the newcomer
            self._shed(self.queue.popleft())
        self.queue.append(req)
        return True

    def _shed(self, req: Request) -> None:
        req.shed = True
        self.shed_requests.append(req)
        self.stats.note_shed(req.tier)

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.popleft()
                if (req.model is not None and req.version is None
                        and self.registry is not None):
                    # resolve the serving version HERE, once: the request
                    # rides this version to completion even if a publish
                    # hot-swaps the tenant's live version mid-decode
                    req.version = self.registry.route(req.model, req.rid)
                slot.request = req
                slot.length = self.prefill_fn(i, req.prompt)
                self._tokens[i] = req.prompt[-1]
                self._lengths[i] = slot.length

    @property
    def active(self) -> int:
        if self._packed:
            return self._n_active
        return sum(1 for s in self.slots if s.request is not None)

    def _tenant_rung(self, req: Request) -> FogPolicy | None:
        """The ledgered rung policy billing this request's tenant (None
        when no ledger, or the ledger knows neither tenant nor default)."""
        if self.ledger is None:
            return None
        gov = self.ledger.governor_for(req.tenant)
        return None if gov is None else gov.current

    def lane_policy(self) -> FogPolicy:
        """The current batch policy: slot policies stacked into per-lane
        threshold / hop-budget vectors.  A slot without its own policy gets
        its tenant's ledgered rung (ledger mode), else the default — the
        fleet governor's active ladder rung when one is installed."""
        default = (self.governor.current if self.governor is not None
                   else self.default_policy)
        pols: list[FogPolicy | None] = []
        for s in self.slots:
            if s.request is None or s.request.policy is not None:
                pols.append(s.request.policy if s.request else None)
            else:
                pols.append(self._tenant_rung(s.request))
        return assemble(pols, default=default)

    def _bucket_groups(self) -> dict:
        """Slot indices keyed by ``(model, version, precision)`` — the
        serving bucket.  One decode dispatch per key; the legacy
        single-model batch degenerates to ``(None, None, precision)`` keys
        (precision None = the default program), so a homogeneous batch
        still costs exactly one dispatch."""
        groups: dict[tuple, list[int]] = {}
        for i, s in enumerate(self.slots):
            if s.request is None:
                key = (None, None, None)
            else:
                req = s.request
                prec = (req.policy.precision if req.policy is not None
                        else None)
                if prec is None:
                    rung = self._tenant_rung(req)
                    if rung is not None:
                        prec = rung.precision
                key = (req.model, req.version, prec)
            groups.setdefault(key, []).append(i)
        none_key = (None, None, None)
        none_idxs = groups.get(none_key)
        if none_idxs is not None and len(groups) > 1 and all(
                self.slots[i].request is None for i in none_idxs):
            # lanes in the default group are all empty: don't spend a
            # dispatch on them, fold into an arbitrary real group (outputs
            # discarded)
            groups.pop(none_key)
            next(iter(groups.values())).extend(none_idxs)
        return groups

    # -- the packed fast path ---------------------------------------------
    #
    # With a packed dispatcher (ForestReplicaServer.packed_factory) the hot
    # loop stops re-assembling and re-uploading per-step state: feature
    # rows and per-lane policy vectors are PERSISTENT device buffers,
    # admits/retires stage donated splices, each bucket dispatch traces
    # only the step's default-rung scalars, and one launch returns packed
    # (next, hops, energy) per span — no logits download, no host argmax,
    # no host pricing.  ``pipeline=True`` double-buffers the loop: step t's
    # dispatch is harvested at the START of step t+1, so the host's
    # refill/splice/bookkeeping for t+1 overlaps device compute of t.  The
    # request -> (slot, dispatch) mapping is IDENTICAL to the synchronous
    # mode (completions are processed before the next refill in both), so
    # the pipelined path is bit-equivalent under a fixed seed — only the
    # wall-clock interleaving changes (see tests/test_serve_equivalence).
    #
    # Telemetry is buffered and replayed in order every ``telemetry_every``
    # steps (and at :meth:`flush`): the governor/ledger/registry see
    # exactly the per-step batches they would have seen live, just later —
    # rung transitions therefore take effect at flush boundaries.

    def _step_packed(self) -> int:
        pc = time.perf_counter_ns
        t0 = pc()
        if self._inflight:
            self._process_harvest()
        t1 = pc()
        self._refill_packed()
        t2 = pc()
        self.phase_ns["refill"] += t2 - t1
        if self._n_active:
            self._dispatch_packed()
            t3 = pc()
            self.phase_ns["dispatch"] += t3 - t2
            if not self.pipeline:
                self._process_harvest()
        self.n_steps += 1
        return self._n_active

    def _refill_packed(self) -> None:
        if not self.queue or not self._free:
            return
        q, free = self.queue, self._free
        slots, occ_mask = self.slots, self._occ_mask
        registry, ledger = self.registry, self.ledger
        lane_key, bucket_lanes = self._lane_key, self._bucket_lanes
        heappop, popleft = heapq.heappop, q.popleft
        lanes, rows, thrs, buds = [], [], [], []
        n_admitted = 0
        while q and free:
            i = heappop(free)
            req = popleft()
            if (req.model is not None and req.version is None
                    and registry is not None):
                # pin the serving version at slot assignment, exactly like
                # the legacy refill (hot-swap never migrates in-flight work)
                req.version = registry.route(req.model, req.rid)
            slot = slots[i]
            slot.request = req
            slot.length = 1          # one resident feature row per slot
            occ_mask[i] = True
            n_admitted += 1
            pol = req.policy
            if pol is not None:
                thr, bud = lane_knobs(pol)
                prec = pol.precision
            else:
                rung = (None if ledger is None
                        else self._tenant_rung(req))
                if rung is not None:
                    # tenant-ledger lanes are stamped CONCRETE at their
                    # tenant's current rung (re-stamped when a flush
                    # transitions that governor); fleet-default lanes stay
                    # sentinels and track the rung in-jit every dispatch
                    thr, bud = lane_knobs(rung)
                    prec = rung.precision
                else:
                    thr, bud = THRESH_DEFAULT, BUDGET_DEFAULT
                    prec = None
            lanes.append(i)
            rows.append(req.prompt)
            thrs.append(thr)
            buds.append(bud)
            key = (req.model, req.version, prec)
            lane_key[i] = key
            bucket = bucket_lanes.get(key)
            if bucket is None:
                bucket = bucket_lanes[key] = set()
            bucket.add(i)
        self._n_active += n_admitted
        if lanes:
            # one vectorized staging write per replica for the whole burst
            self.dispatcher.admit_lanes(
                np.asarray(lanes, np.int64),
                np.asarray(rows, np.float32), thrs, buds)

    def _retire_lane(self, i: int) -> None:
        """Host-side slot bookkeeping of one freed lane (the device-side
        dead-stamp is batched by the caller via ``retire_lanes``)."""
        s = self.slots[i]
        s.request = None
        s.length = 0
        self._occ_mask[i] = False
        self._n_active -= 1
        heapq.heappush(self._free, i)
        key = self._lane_key[i]
        if key is not None:
            self._bucket_lanes[key].discard(i)
            self._lane_key[i] = None

    def _dispatch_packed(self) -> None:
        default = (self.governor.current if self.governor is not None
                   else self.default_policy)
        def_thr = float(np.asarray(default.threshold))
        def_bud = (int(np.asarray(default.hop_budget))
                   if default.hop_budget is not None else NO_BUDGET)
        for key in list(self._bucket_lanes):
            lanes = self._bucket_lanes[key]
            if not lanes:
                del self._bucket_lanes[key]
                continue
            model, version, prec = key
            eff_prec = prec if prec is not None else default.precision
            bucket = None if model is None else (model, version)
            self.dispatcher.dispatch_packed(
                lanes, def_thr, def_bud, precision=eff_prec, bucket=bucket)
        self._inflight = True
        self._inflight_occ = np.flatnonzero(self._occ_mask)

    def _process_harvest(self) -> None:
        pc = time.perf_counter_ns
        t0 = pc()
        nxt, hops, energy, pend = self.dispatcher.harvest_packed(
            len(self.slots))
        self.last_dispatches = pend
        self._inflight = False
        occ = self._inflight_occ
        t1 = pc()
        self.phase_ns["harvest"] += t1 - t0
        occ_l = occ.tolist()
        nxt_l = nxt[occ].tolist()
        hops_l = hops[occ].tolist()
        now = time.perf_counter()
        reqs = []
        retired = []
        done_tiers: dict[str, int] = {}
        slots, eos = self.slots, self.eos_id
        completed_append = self.completed.append
        retire = self._retire_lane
        for j, i in enumerate(occ_l):
            s = slots[i]
            req = s.request
            reqs.append(req)
            tok = nxt_l[j]
            gen = req.generated
            gen.append(tok)
            req.hops.append(hops_l[j])
            s.length += 1
            if tok == eos or len(gen) >= req.max_new_tokens:
                req.done = True
                if req.t_submit is not None:
                    req.t_done = now
                tier = req.tier
                done_tiers[tier] = done_tiers.get(tier, 0) + 1
                completed_append(req)
                retire(i)
                retired.append(i)
        if done_tiers:
            self.stats.note_done_many(done_tiers)
        if retired:
            # one bulk dead-stamp per replica (an admit in the same step
            # simply overwrites the staged entry)
            self.dispatcher.retire_lanes(retired)
        t2 = pc()
        self.phase_ns["bookkeep"] += t2 - t1
        if occ.size:
            self._tel_buf.append((hops[occ], energy[occ], reqs, occ))
            self._steps_since_flush += 1
            if self._steps_since_flush >= self.telemetry_every:
                self._flush_telemetry()
        self.phase_ns["telemetry"] += pc() - t2

    def _flush_telemetry(self) -> None:
        """Replay the buffered per-step telemetry batches IN ORDER: the
        governor/ledger observe+step per batch exactly as the inline path
        would have, the fleet stats and registry per-version stats fold in
        the same events — deferral changes when the consumers see the
        telemetry (flush boundaries), never what they see."""
        buf, self._tel_buf = self._tel_buf, []
        self._steps_since_flush = 0
        if not buf:
            return
        ledger_trans = None
        if self.ledger is not None:
            govs = [g for _, g in self.ledger.items()]
            if self.ledger.default is not None:
                govs.append(self.ledger.default)
            ledger_trans = [(g, len(g.transitions)) for g in govs]
        fleet_batches = []
        for hops, energy, reqs, lanes in buf:
            tiers = [r.tier for r in reqs]
            devices = (self.dispatcher.lane_devices(lanes)
                       if self.dispatcher is not None else None)
            e = energy
            if self.governor is not None:
                fleet_batches.append((e, devices))
            elif self.ledger is not None:
                # per-tenant governance, NaN for lanes no governor bills —
                # identical grouping to the legacy inline _account
                e = energy.copy()
                by_tenant: dict[str | None, list[int]] = {}
                for i, r in enumerate(reqs):
                    by_tenant.setdefault(r.tenant, []).append(i)
                for tenant, idxs in by_tenant.items():
                    gov = self.ledger.governor_for(tenant)
                    if gov is None:
                        e[idxs] = np.nan
                        continue
                    gov.ingest([(e[idxs],
                                 None if devices is None
                                 else devices[idxs])])
            self.stats.update(hops, e, tiers=tiers)
            if self.registry is not None:
                by_version: dict[tuple, list[int]] = {}
                for i, r in enumerate(reqs):
                    if r.model is not None and r.version is not None:
                        by_version.setdefault(
                            (r.model, r.version), []).append(i)
                for (tenant, version), idxs in by_version.items():
                    self.registry.stats_for(tenant, version).update(
                        hops[idxs], e[idxs],
                        tiers=[tiers[i] for i in idxs])
        if self.governor is not None and fleet_batches:
            self.governor.ingest(fleet_batches)
        if ledger_trans is not None and any(
                len(g.transitions) != n for g, n in ledger_trans):
            # a tenant rung moved: its concrete lane stamps (and rung-
            # precision bucket keys) are stale — re-stamp the occupied
            # default-policy lanes
            self._restamp_default_lanes()

    def _restamp_default_lanes(self) -> None:
        lanes, thrs, buds = [], [], []
        for i in np.flatnonzero(self._occ_mask).tolist():
            req = self.slots[i].request
            if req is None or req.policy is not None:
                continue
            rung = self._tenant_rung(req)
            if rung is None:
                continue
            thr, bud = lane_knobs(rung)
            lanes.append(i)
            thrs.append(thr)
            buds.append(bud)
            key = (req.model, req.version, rung.precision)
            if key != self._lane_key[i]:
                self._bucket_lanes[self._lane_key[i]].discard(i)
                self._lane_key[i] = key
                self._bucket_lanes.setdefault(key, set()).add(i)
        if lanes:
            self.dispatcher.admit_lanes(lanes, None, thrs, buds)

    def flush(self) -> None:
        """Drain the pipelined loop: harvest any in-flight dispatch, then
        replay ALL buffered telemetry.  After flush() the governor/ledger/
        registry/stats state is exactly what the synchronous per-step loop
        would hold — call it before reading telemetry mid-run and once
        after the last step.  A no-op on the legacy (non-packed) path,
        which accounts inline."""
        if not self._packed:
            return
        if self._inflight:
            self._process_harvest()
        self._flush_telemetry()

    def step(self) -> int:
        """One decode step across all active slots.  Returns #active.

        On the packed path with ``pipeline=True`` this harvests the
        PREVIOUS step's dispatch and issues this step's — completions
        surface one ``step()`` call later; :meth:`flush` drains the tail.
        """
        if self._packed:
            return self._step_packed()
        self._refill()
        occ = [i for i, s in enumerate(self.slots) if s.request is not None]
        if not occ:
            return 0
        tokens = self._tokens
        lengths = self._lengths
        if self._policy_mode == "dispatch":
            # data-parallel plane: enqueue every (model, version,
            # precision) bucket without blocking (per-device async
            # dispatch), then harvest everything behind ONE deferred
            # block_until_ready
            base = self.lane_policy()
            for (model, version, prec), idxs in self._bucket_groups().items():
                pol = base if prec is None else base.replace(precision=prec)
                bucket = None if model is None else (model, version)
                self.dispatcher.dispatch(tokens, lengths, pol, idxs,
                                         bucket=bucket)
            logits, hops, self.last_dispatches = self.dispatcher.harvest(
                len(self.slots))
        elif self._policy_aware:
            base = self.lane_policy()
            groups = self._bucket_groups()
            n = len(self.slots)
            logits, hops = None, None
            for (model, version, prec), idxs in groups.items():
                pol = base if prec is None else base.replace(precision=prec)
                bucket = None if model is None else (model, version)
                lg, hp = self._call_decode(tokens, lengths, pol,
                                           bucket=bucket)
                if len(groups) == 1:
                    logits, hops = lg, hp
                    break
                if logits is None:
                    logits = np.zeros(np.shape(lg), np.float32)
                    hops = None if hp is None else np.zeros((n,), np.int64)
                idxs = np.asarray(idxs)
                logits[idxs] = np.asarray(lg)[idxs]
                if hp is not None:
                    hops[idxs] = np.asarray(hp)[idxs]
        else:
            logits, hops = self.decode_fn(jnp.asarray(tokens),
                                          jnp.asarray(lengths))
        if isinstance(logits, np.ndarray):
            # dispatcher harvests host-side; keep the argmax off-device too
            nxt = np.argmax(logits, axis=-1)
        else:
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        hops = np.asarray(hops) if hops is not None else None
        if hops is None and self.governor is not None:
            raise ValueError(
                "the governor needs hop telemetry but decode_fn returned "
                "hops=None; the energy SLO cannot be enforced")
        occa = np.asarray(occ, np.int64)
        self._tokens[occa] = nxt[occa]
        self._lengths[occa] += 1
        # bulk host conversion: per-item ``int(arr[i])`` reads are ~10x the
        # cost of one tolist() at serving-scale slot counts
        nxt_l = nxt.tolist()
        hops_l = hops.tolist() if hops is not None else None
        step_hops = []
        now = time.perf_counter()
        for i in occ:
            s = self.slots[i]
            req = s.request
            tok = nxt_l[i]
            req.generated.append(tok)
            if hops_l is not None:
                h = hops_l[i]
                req.hops.append(h)
                step_hops.append((h, req, i))
            s.length += 1
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                if req.t_submit is not None:
                    req.t_done = now
                self.stats.note_done(req.tier)
                self.completed.append(req)
                self.slots[i] = SlotState()
                self._tokens[i] = 0
                self._lengths[i] = 0
        if step_hops:
            self._account(step_hops)
        return self.active

    def _call_decode(self, tokens, lengths, pol, bucket=None):
        """One decode dispatch, honoring the fn's policy calling convention
        (positional third arg vs KEYWORD_ONLY ``policy``) and passing the
        (model, version) bucket only to bucket-aware fns."""
        kw = {}
        if bucket is not None:
            kw["bucket"] = bucket
        if self._policy_mode == "keyword":
            return self.decode_fn(jnp.asarray(tokens), jnp.asarray(lengths),
                                  policy=pol, **kw)
        return self.decode_fn(jnp.asarray(tokens), jnp.asarray(lengths),
                              pol, **kw)

    def _account(self, step_hops: list) -> None:
        """Fold one step's active-lane (hops, request, lane) tuples into
        the fleet telemetry and let the governance plane react.  Each lane
        is priced at ITS OWN effective precision — the request policy's,
        falling back to its billing governor's active rung — so
        mixed-precision batches are billed at the byte widths they
        actually dispatched and an int8 step-down shows up as a measured
        saving.  With a TenantLedger the telemetry is grouped by tenant
        first: each tenant's governor sees only its own traffic, so one
        tenant's expensive burst can never walk another tenant's ladder.
        On the data-parallel plane each sample is additionally labeled
        with its serving device for per-device rolling estimates; with a
        registry, each (tenant, version) group also feeds its per-version
        ServeStats (the canary-judging evidence)."""
        hops = np.asarray([h for h, _, _ in step_hops])
        tiers = [req.tier for _, req, _ in step_hops]
        lanes = [lane for _, _, lane in step_hops]
        devices = (self.dispatcher.lane_devices(lanes)
                   if self.dispatcher is not None else None)
        energy_pj = None

        def price_into(out, gov, entries):
            """Price ``entries`` (index, req) with one governor, grouping
            by effective precision (one lane_pj call per precision)."""
            rung_prec = gov.current.precision
            groups: dict[str | None, list[int]] = {}
            for i, req in entries:
                prec = (req.policy.precision if req is not None
                        and req.policy is not None else None)
                groups.setdefault(
                    prec if prec is not None else rung_prec, []).append(i)
            for prec, idxs in groups.items():
                out[idxs] = np.asarray(
                    gov.model_for(prec).lane_pj(hops[idxs]))

        if self.governor is not None:
            energy_pj = np.empty(len(step_hops), np.float64)
            price_into(energy_pj, self.governor,
                       [(i, req) for i, (_, req, _) in enumerate(step_hops)])
            self.governor.observe(energy_pj=energy_pj, devices=devices)
            self.governor.step()
        elif self.ledger is not None:
            # per-tenant governance: group by tenant, price each group at
            # its own governor's models, observe/step each independently.
            # NaN marks lanes no governor bills (unledgered tenant, no
            # default) — counted as events, excluded from energy means.
            energy_pj = np.full(len(step_hops), np.nan)
            by_tenant: dict[str | None, list[int]] = {}
            for i, (_, req, _) in enumerate(step_hops):
                by_tenant.setdefault(req.tenant, []).append(i)
            for tenant, idxs in by_tenant.items():
                gov = self.ledger.governor_for(tenant)
                if gov is None:
                    continue
                price_into(energy_pj, gov,
                           [(i, step_hops[i][1]) for i in idxs])
                gov.observe(energy_pj=energy_pj[idxs],
                            devices=None if devices is None
                            else devices[idxs])
                gov.step()
        self.stats.update(hops, energy_pj, tiers=tiers)
        if self._meter is not None:      # deprecated shim path
            self._meter.update(hops)
        if self.registry is not None:
            by_version: dict[tuple, list[int]] = {}
            for i, (_, req, _) in enumerate(step_hops):
                if req.model is not None and req.version is not None:
                    by_version.setdefault(
                        (req.model, req.version), []).append(i)
            for (tenant, version), idxs in by_version.items():
                self.registry.stats_for(tenant, version).update(
                    hops[idxs],
                    None if energy_pj is None else energy_pj[idxs],
                    tiers=[tiers[i] for i in idxs])

    def run(self, max_steps: int = 10000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        self.flush()
        return self.completed
