"""Batched request scheduler for serving (continuous batching + FoG).

A slot-based continuous-batching scheduler: a fixed decode batch of
``n_slots`` lanes; finished/empty lanes are refilled from the request queue
each step (the standard vLLM-style slot model, minus paged KV — caches here
are dense per-slot rings).  With FoG decode enabled, per-step grove usage
(hops) is accumulated per request, giving the per-request energy/FLOP
accounting that mirrors the paper's per-input hop counter.

Mixed-QoS serving: every :class:`Request` may carry its own
:class:`~repro.core.policy.FogPolicy` (threshold / hop budget).  Each step
the scheduler assembles the slots' scalar policies into one per-lane batch
policy (:func:`repro.core.policy.assemble`) and hands it to a policy-aware
``decode_fn(tokens, lengths, policy)`` — one continuous batch, one compiled
program, every lane buying its own accuracy/energy point.  Legacy two-arg
``decode_fn(tokens, lengths)`` callables keep working unchanged.

Mixed-precision serving: a request's policy may additionally set
``precision`` ("fp32" | "bf16" | "int8" packed tables).  Precision selects
a compiled program, so it cannot ride the per-lane vectors; instead the
scheduler buckets the step's slots by precision and dispatches ``decode_fn``
once per distinct precision present (each call still carries the full
per-lane threshold/budget vectors; each slot's outputs are harvested from
its own precision's call).  A homogeneous batch — the common case — still
costs exactly one dispatch.

Data-parallel serving: hand the batcher a
:class:`~repro.serve.dispatch.DeviceDispatcher` instead of a ``decode_fn``
and each precision group's dispatch fans out across the dispatcher's device
replicas (fixed per-device slot spans, per-device dispatch queues, one
deferred ``jax.block_until_ready`` at harvest) — the slot model, policy
assembly and telemetry are unchanged; only the execution plane widens.

Admission control: ``max_queue`` bounds the request queue.  When it is
full, ``shed_policy`` decides who pays: ``"reject"`` sheds the incoming
request (``submit`` returns False), ``"oldest"`` evicts the oldest queued
request to admit the new one.  Shed requests are marked ``req.shed``,
collected in ``batcher.shed_requests``, and counted in
``ServeStats.n_shed`` / ``shed_rate`` — overload becomes a measured,
bounded signal instead of an unbounded latency tail.

Energy governance: install an :class:`~repro.serve.governor.EnergyGovernor`
and the batcher serves under an nJ/classification SLO — each step's default
policy is the governor's active ladder rung, every step's hop telemetry
feeds its rolling estimate, and the governor steps down the ladder (tighten
threshold -> int8 -> cut hop budget) on a breach, back up when headroom
returns.  A request may carry ``energy_budget_nj`` instead of an explicit
policy: the governor resolves it against the calibrated frontier into the
highest-accuracy rung fitting that budget (hop budget clamped so the
contract is hard).  Telemetry lives in :class:`ServeStats` — the old
``HopMeter`` plumbing survives only as a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
import warnings
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HopMeter
from repro.core.policy import FogPolicy, assemble


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new_tokens: int = 32
    # per-request QoS contract (scalar threshold / hop budget); None = the
    # batcher's default policy
    policy: FogPolicy | None = None
    # per-request energy contract: resolved at submit() into a policy via
    # the batcher's governor (mutually exclusive with an explicit policy)
    energy_budget_nj: float | None = None
    # registry tenant this request evaluates against (None = the batcher's
    # single built-in model, the pre-registry behavior)
    model: str | None = None
    # QoS tier label for per-tier shed/done/energy telemetry (ServeStats
    # breaks out counters per distinct label)
    tier: str = "default"
    # the registry version serving this request: resolved ONCE at slot
    # assignment (registry.route) and pinned, so a hot-swap mid-decode
    # never migrates an in-flight request between versions.  Pre-set it to
    # bypass routing.
    version: int | None = None
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    hops: list = dataclasses.field(default_factory=list)
    done: bool = False
    # set by admission control when the request is dropped under overload
    shed: bool = False
    # wall-clock stamps for latency accounting: submit() stamps t_submit
    # (shed requests included — the shed tail is part of the latency
    # story), completion stamps t_done.  Callers may pre-stamp t_submit.
    t_submit: float | None = None
    t_done: float | None = None

    @property
    def tenant(self) -> str | None:
        """Alias of ``model`` (the registry/ledger vocabulary)."""
        return self.model


@dataclasses.dataclass
class ServeStats:
    """Fleet-level serving telemetry (replaces the deprecated HopMeter):
    hop counts of every decoded event, plus modeled pJ when a governor (or
    its energy model) is installed to price them."""

    total_hops: int = 0
    n_events: int = 0
    total_pj: float = 0.0
    has_energy: bool = False
    # events that actually carried a pJ price — the mean_energy_nj
    # denominator.  Mixing priced and unpriced updates (governor installed
    # mid-run, hops-only telemetry) must not deflate the mean.
    n_priced: int = 0
    # admission-control counters (bounded queue)
    n_offered: int = 0
    n_shed: int = 0
    # per-QoS-tier breakdown: tier label -> {n_done, n_shed, n_events,
    # total_pj, n_priced}.  Canary judging and gold-tier SLOs need the
    # split the fleet totals average away.
    tiers: dict = dataclasses.field(default_factory=dict)

    def _tier(self, tier: str) -> dict:
        t = self.tiers.get(tier)
        if t is None:
            t = self.tiers[tier] = {"n_done": 0, "n_shed": 0, "n_events": 0,
                                    "total_pj": 0.0, "n_priced": 0}
        return t

    def note_shed(self, tier: str = "default") -> None:
        self.n_shed += 1
        self._tier(tier)["n_shed"] += 1

    def note_done(self, tier: str = "default") -> None:
        self._tier(tier)["n_done"] += 1

    def update(self, hops, energy_pj=None, tiers=None) -> None:
        """Fold one batch of decoded events in.  ``energy_pj`` may carry
        NaN for events nothing could price (a ledgered batch with an
        unledgered tenant) — only finite entries feed the energy totals.
        ``tiers`` optionally labels each event with its request's QoS tier
        for the per-tier breakdown."""
        h = np.asarray(hops)
        self.total_hops += int(h.sum())
        self.n_events += int(h.size)
        priced = None
        if energy_pj is not None:
            e = np.asarray(energy_pj, np.float64)
            priced = np.isfinite(e)
            self.total_pj += float(e[priced].sum())
            self.n_priced += int(priced.sum())
            if priced.any():
                self.has_energy = True
        if tiers is not None:
            e = (np.asarray(energy_pj, np.float64)
                 if energy_pj is not None else None)
            for i, tier in enumerate(tiers):
                t = self._tier(tier)
                t["n_events"] += 1
                if priced is not None and priced[i]:
                    t["total_pj"] += float(e[i])
                    t["n_priced"] += 1

    def tier_summary(self) -> dict:
        """{tier: {n_done, n_shed, n_events, mean_energy_nj}} — the
        per-tier view the fleet means hide."""
        return {tier: {"n_done": t["n_done"], "n_shed": t["n_shed"],
                       "n_events": t["n_events"],
                       "mean_energy_nj": t["total_pj"] * 1e-3
                       / max(1, t["n_priced"])}
                for tier, t in sorted(self.tiers.items())}

    def reset(self) -> None:
        self.total_hops = 0
        self.n_events = 0
        self.total_pj = 0.0
        self.has_energy = False
        self.n_priced = 0
        self.n_offered = 0
        self.n_shed = 0
        self.tiers = {}

    @property
    def mean_hops(self) -> float:
        return self.total_hops / max(1, self.n_events)

    @property
    def mean_energy_nj(self) -> float:
        """Mean modeled nJ per PRICED decoded event (0.0 until priced
        telemetry arrives).  Unpriced events (no governor / hops-only
        updates) are excluded from the denominator."""
        return self.total_pj * 1e-3 / max(1, self.n_priced)

    @property
    def shed_rate(self) -> float:
        """Fraction of offered requests shed by admission control."""
        return self.n_shed / max(1, self.n_offered)

    def summary(self, n_groves: int) -> str:
        s = (f"hops/event {self.mean_hops:.2f} "
             f"(grove fraction {self.mean_hops / max(1, n_groves):.2f}, "
             f"{self.n_events} events)")
        if self.has_energy:
            s += f", {self.mean_energy_nj:.3f} nJ/event"
        if self.n_shed:
            s += (f", shed {self.n_shed}/{self.n_offered} "
                  f"({100 * self.shed_rate:.1f}%)")
        return s


@dataclasses.dataclass
class SlotState:
    request: Request | None = None
    length: int = 0               # tokens already in this slot's cache


def _policy_mode(decode_fn: Callable) -> str:
    """How decode_fn accepts the batch policy.

    ``"positional"``  three-plus positional params (or ``*args``): called
                      ``decode_fn(tokens, lengths, policy)``
    ``"keyword"``     a KEYWORD_ONLY ``policy`` param (also reachable
                      through ``functools.partial`` / ``jax.jit`` wrappers,
                      whose signatures follow ``__wrapped__``): called
                      ``decode_fn(tokens, lengths, policy=policy)``
    ``"legacy"``      two-arg decode; never sees a policy
    """
    try:
        params = inspect.signature(decode_fn).parameters.values()
    except (TypeError, ValueError):   # builtins / C callables: assume legacy
        return "legacy"
    positional = [p for p in params
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    if (len(positional) >= 3
            or any(p.kind == p.VAR_POSITIONAL for p in params)):
        return "positional"
    if any(p.kind == p.KEYWORD_ONLY and p.name == "policy" for p in params):
        return "keyword"
    return "legacy"


def _takes_policy(decode_fn: Callable) -> bool:
    """Does decode_fn accept a policy argument (positional or kw-only)?"""
    return _policy_mode(decode_fn) != "legacy"


def _takes_bucket(decode_fn: Callable) -> bool:
    """Does decode_fn accept a ``bucket`` keyword ((model, version)
    routing for registry-backed multi-tenant serving)?"""
    try:
        params = inspect.signature(decode_fn).parameters
    except (TypeError, ValueError):
        return False
    p = params.get("bucket")
    return p is not None and p.kind in (p.KEYWORD_ONLY,
                                        p.POSITIONAL_OR_KEYWORD)


class ContinuousBatcher:
    """Drives decode_fn over a fixed slot batch, refilling as lanes finish.

    decode_fn(tokens [n_slots] int32, lengths [n_slots] int32
              [, policy: FogPolicy with per-lane [n_slots] knobs])
        -> (logits [n_slots, V], hops [n_slots] | None)
        (the policy param may be positional or KEYWORD_ONLY ``*, policy``)
    prefill_fn(slot, prompt) -> int  (returns prompt length in cache)
    default_policy: applied to slots whose request carries no policy (and
        to empty lanes); its static knobs select the compiled program.
    governor: optional EnergyGovernor — when set, the *governor's active
        rung* replaces default_policy each step, per-step hop telemetry
        feeds its rolling estimate, and requests may carry
        ``energy_budget_nj`` contracts.
    dispatcher: optional :class:`~repro.serve.dispatch.DeviceDispatcher` —
        the data-parallel execution plane.  Mutually exclusive with
        ``decode_fn`` (pass ``decode_fn=None``); always policy-aware.
    max_queue: admission-control bound on the request queue (None =
        unbounded, the pre-existing behavior).
    shed_policy: who is shed when the queue is full — ``"reject"`` the
        incoming request (submit returns False) or evict the ``"oldest"``
        queued request.
    meter: DEPRECATED — pass nothing and read ``batcher.stats`` instead.
    """

    def __init__(self, n_slots: int, decode_fn: Callable | None,
                 prefill_fn: Callable, eos_id: int = 1,
                 meter=None, default_policy: FogPolicy | None = None,
                 governor=None, dispatcher=None,
                 max_queue: int | None = None, shed_policy: str = "reject",
                 registry=None):
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.eos_id = eos_id
        self.completed: list[Request] = []
        self.default_policy = (default_policy if default_policy is not None
                               else FogPolicy())
        if self.default_policy.per_lane:
            raise ValueError(
                "default_policy must carry scalar knobs; the batcher "
                "assembles the per-lane vectors itself each step")
        # ``governor`` accepts either one EnergyGovernor (fleet-wide SLO)
        # or a TenantLedger (per-tenant SLOs, one governor per tenant)
        self.ledger = None
        if governor is not None and hasattr(governor, "governor_for"):
            self.ledger = governor
            governor = None
        self.governor = governor
        self.dispatcher = dispatcher
        self.registry = registry
        if dispatcher is not None:
            if decode_fn is not None:
                raise ValueError(
                    "pass either decode_fn or dispatcher, not both (the "
                    "dispatcher owns the per-device decode replicas)")
            dispatcher.bind(n_slots)
            self._policy_mode = "dispatch"
        else:
            if decode_fn is None:
                raise ValueError(
                    "decode_fn is required when no dispatcher is given")
            self._policy_mode = _policy_mode(decode_fn)
        self._policy_aware = self._policy_mode != "legacy"
        if shed_policy not in ("reject", "oldest"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             "pick 'reject' or 'oldest'")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None = unbounded)")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.shed_requests: list[Request] = []
        # the dispatcher's drained Pending list from the last step (device /
        # precision / lane bookkeeping for the load harness)
        self.last_dispatches: list = []
        # maintained per-lane decode inputs (empty lanes stay 0): rebuilding
        # these with a per-slot Python loop every step is measurable serial
        # time at serving-scale slot counts
        self._tokens = np.zeros((n_slots,), np.int32)
        self._lengths = np.zeros((n_slots,), np.int32)
        if governor is not None:
            # a governor that can never act must be rejected loudly — a
            # silently unenforced SLO is worse than no governor at all
            if governor.model is None:
                raise ValueError(
                    "the batcher's governor needs an energy model to price "
                    "hop telemetry; construct EnergyGovernor(..., "
                    "model=...)")
            if not self._policy_aware:
                raise ValueError(
                    "a governor needs a policy-aware decode_fn(tokens, "
                    "lengths, policy) — a legacy two-arg decode_fn would "
                    "never serve the governor's rung policy")
        if self.ledger is not None and not self._policy_aware:
            raise ValueError(
                "a tenant ledger needs a policy-aware decode path — a "
                "legacy two-arg decode_fn would never serve any tenant's "
                "rung policy")
        # can this execution plane route (model, version) buckets?  The
        # dispatcher introspects its replicas at bind; a plain decode_fn
        # must take a ``bucket`` keyword itself.
        if dispatcher is not None:
            self._bucket_aware = dispatcher.bucket_aware
        else:
            self._bucket_aware = _takes_bucket(decode_fn)
        # fleet-level FoG accounting: hop counts (and, with a governor's
        # energy model, modeled pJ) of every decoded token
        self.stats = ServeStats()
        if meter is not None:
            warnings.warn(
                "ContinuousBatcher(meter=...) is deprecated; per-step "
                "telemetry lives in batcher.stats (and the governor's "
                "rolling estimate)", DeprecationWarning, stacklevel=2)
        self._meter = meter

    @property
    def meter(self):
        """DEPRECATED — legacy readers of the always-present HopMeter get
        a shim seeded from ``stats`` (same totals), plus the warning."""
        if self._meter is None:
            warnings.warn(
                "ContinuousBatcher.meter is deprecated; read "
                "batcher.stats (ServeStats) instead",
                DeprecationWarning, stacklevel=2)
            m = HopMeter.__new__(HopMeter)   # we already warned just above
            m.total_hops = self.stats.total_hops
            m.n_events = self.stats.n_events
            self._meter = m
        return self._meter

    def submit(self, req: Request) -> bool:
        """Validate, resolve energy contracts, then admit or shed.

        Returns True if the request was admitted to the queue, False if it
        was shed by admission control (``shed_policy="reject"`` with a full
        queue).  Invalid requests still raise — shedding is a load signal,
        not an error-swallowing path.
        """
        # stamp at the door: shed requests carry a submit time too (the
        # shed tail is part of the latency story), admitted requests keep
        # any pre-stamp the harness set
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        if req.model is not None:
            if self.registry is None and req.version is None:
                raise ValueError(
                    f"request {req.rid}: Request.model={req.model!r} needs "
                    "a registry to resolve the serving version (construct "
                    "ContinuousBatcher(..., registry=ModelRegistry(dir)), "
                    "or pre-set Request.version)")
            if not self._bucket_aware:
                raise ValueError(
                    f"request {req.rid}: Request.model={req.model!r} needs "
                    "a bucket-aware decode path (a decode_fn/replica "
                    "taking bucket=) to route (model, version) buckets")
        if req.energy_budget_nj is not None:
            if req.policy is not None:
                raise ValueError(
                    f"request {req.rid}: pass either policy or "
                    "energy_budget_nj, not both (the budget is resolved "
                    "into a policy)")
            gov = self.governor
            if gov is None and self.ledger is not None:
                gov = self.ledger.governor_for(req.tenant)
            if gov is None:
                raise ValueError(
                    f"request {req.rid}: energy_budget_nj needs a "
                    "governor (construct ContinuousBatcher(..., "
                    "governor=EnergyGovernor(frontier, ...)) or ledger "
                    "an EnergyGovernor for this request's tenant)")
            pol = gov.policy_for_budget(req.energy_budget_nj)
            # the per-request contract is the per-lane/bucketed knobs only
            # (threshold, hop budget, precision); any static knobs the
            # ladder rung inherited from the fleet default (backend,
            # max_hops, ...) stay with the fleet default — they select the
            # compiled program and would otherwise trip the static-knob
            # rejection below
            req.policy = FogPolicy(threshold=pol.threshold,
                                   hop_budget=pol.hop_budget,
                                   precision=pol.precision)
        if req.policy is not None:
            if req.policy.per_lane:
                raise ValueError(
                    f"request {req.rid}: per-request policies are scalar "
                    "contracts; the batcher assembles the per-lane vectors")
            # precision is static too, but the batcher handles it by
            # dispatching one program per precision group (see step())
            rejected = tuple(k for k in req.policy.static_overrides
                             if k != "precision")
            if rejected:
                raise ValueError(
                    f"request {req.rid}: policy sets static knobs "
                    f"{rejected} — those select the "
                    "compiled program and cannot vary per request; set "
                    "them on the batcher's default_policy (per-request "
                    "knobs are threshold, hop_budget and precision)")
        self.stats.n_offered += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.shed_policy == "reject":
                self._shed(req)
                return False
            # "oldest": evict the head of the queue to admit the newcomer
            self._shed(self.queue.popleft())
        self.queue.append(req)
        return True

    def _shed(self, req: Request) -> None:
        req.shed = True
        self.shed_requests.append(req)
        self.stats.note_shed(req.tier)

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.popleft()
                if (req.model is not None and req.version is None
                        and self.registry is not None):
                    # resolve the serving version HERE, once: the request
                    # rides this version to completion even if a publish
                    # hot-swaps the tenant's live version mid-decode
                    req.version = self.registry.route(req.model, req.rid)
                slot.request = req
                slot.length = self.prefill_fn(i, req.prompt)
                self._tokens[i] = req.prompt[-1]
                self._lengths[i] = slot.length

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.request is not None)

    def _tenant_rung(self, req: Request) -> FogPolicy | None:
        """The ledgered rung policy billing this request's tenant (None
        when no ledger, or the ledger knows neither tenant nor default)."""
        if self.ledger is None:
            return None
        gov = self.ledger.governor_for(req.tenant)
        return None if gov is None else gov.current

    def lane_policy(self) -> FogPolicy:
        """The current batch policy: slot policies stacked into per-lane
        threshold / hop-budget vectors.  A slot without its own policy gets
        its tenant's ledgered rung (ledger mode), else the default — the
        fleet governor's active ladder rung when one is installed."""
        default = (self.governor.current if self.governor is not None
                   else self.default_policy)
        pols: list[FogPolicy | None] = []
        for s in self.slots:
            if s.request is None or s.request.policy is not None:
                pols.append(s.request.policy if s.request else None)
            else:
                pols.append(self._tenant_rung(s.request))
        return assemble(pols, default=default)

    def _bucket_groups(self) -> dict:
        """Slot indices keyed by ``(model, version, precision)`` — the
        serving bucket.  One decode dispatch per key; the legacy
        single-model batch degenerates to ``(None, None, precision)`` keys
        (precision None = the default program), so a homogeneous batch
        still costs exactly one dispatch."""
        groups: dict[tuple, list[int]] = {}
        for i, s in enumerate(self.slots):
            if s.request is None:
                key = (None, None, None)
            else:
                req = s.request
                prec = (req.policy.precision if req.policy is not None
                        else None)
                if prec is None:
                    rung = self._tenant_rung(req)
                    if rung is not None:
                        prec = rung.precision
                key = (req.model, req.version, prec)
            groups.setdefault(key, []).append(i)
        none_key = (None, None, None)
        none_idxs = groups.get(none_key)
        if none_idxs is not None and len(groups) > 1 and all(
                self.slots[i].request is None for i in none_idxs):
            # lanes in the default group are all empty: don't spend a
            # dispatch on them, fold into an arbitrary real group (outputs
            # discarded)
            groups.pop(none_key)
            next(iter(groups.values())).extend(none_idxs)
        return groups

    def step(self) -> int:
        """One decode step across all active slots.  Returns #active."""
        self._refill()
        occ = [i for i, s in enumerate(self.slots) if s.request is not None]
        if not occ:
            return 0
        tokens = self._tokens
        lengths = self._lengths
        if self._policy_mode == "dispatch":
            # data-parallel plane: enqueue every (model, version,
            # precision) bucket without blocking (per-device async
            # dispatch), then harvest everything behind ONE deferred
            # block_until_ready
            base = self.lane_policy()
            for (model, version, prec), idxs in self._bucket_groups().items():
                pol = base if prec is None else base.replace(precision=prec)
                bucket = None if model is None else (model, version)
                self.dispatcher.dispatch(tokens, lengths, pol, idxs,
                                         bucket=bucket)
            logits, hops, self.last_dispatches = self.dispatcher.harvest(
                len(self.slots))
        elif self._policy_aware:
            base = self.lane_policy()
            groups = self._bucket_groups()
            n = len(self.slots)
            logits, hops = None, None
            for (model, version, prec), idxs in groups.items():
                pol = base if prec is None else base.replace(precision=prec)
                bucket = None if model is None else (model, version)
                lg, hp = self._call_decode(tokens, lengths, pol,
                                           bucket=bucket)
                if len(groups) == 1:
                    logits, hops = lg, hp
                    break
                if logits is None:
                    logits = np.zeros(np.shape(lg), np.float32)
                    hops = None if hp is None else np.zeros((n,), np.int64)
                idxs = np.asarray(idxs)
                logits[idxs] = np.asarray(lg)[idxs]
                if hp is not None:
                    hops[idxs] = np.asarray(hp)[idxs]
        else:
            logits, hops = self.decode_fn(jnp.asarray(tokens),
                                          jnp.asarray(lengths))
        if isinstance(logits, np.ndarray):
            # dispatcher harvests host-side; keep the argmax off-device too
            nxt = np.argmax(logits, axis=-1)
        else:
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        hops = np.asarray(hops) if hops is not None else None
        if hops is None and self.governor is not None:
            raise ValueError(
                "the governor needs hop telemetry but decode_fn returned "
                "hops=None; the energy SLO cannot be enforced")
        occa = np.asarray(occ, np.int64)
        self._tokens[occa] = nxt[occa]
        self._lengths[occa] += 1
        # bulk host conversion: per-item ``int(arr[i])`` reads are ~10x the
        # cost of one tolist() at serving-scale slot counts
        nxt_l = nxt.tolist()
        hops_l = hops.tolist() if hops is not None else None
        step_hops = []
        now = time.perf_counter()
        for i in occ:
            s = self.slots[i]
            req = s.request
            tok = nxt_l[i]
            req.generated.append(tok)
            if hops_l is not None:
                h = hops_l[i]
                req.hops.append(h)
                step_hops.append((h, req, i))
            s.length += 1
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                if req.t_submit is not None:
                    req.t_done = now
                self.stats.note_done(req.tier)
                self.completed.append(req)
                self.slots[i] = SlotState()
                self._tokens[i] = 0
                self._lengths[i] = 0
        if step_hops:
            self._account(step_hops)
        return self.active

    def _call_decode(self, tokens, lengths, pol, bucket=None):
        """One decode dispatch, honoring the fn's policy calling convention
        (positional third arg vs KEYWORD_ONLY ``policy``) and passing the
        (model, version) bucket only to bucket-aware fns."""
        kw = {}
        if bucket is not None:
            kw["bucket"] = bucket
        if self._policy_mode == "keyword":
            return self.decode_fn(jnp.asarray(tokens), jnp.asarray(lengths),
                                  policy=pol, **kw)
        return self.decode_fn(jnp.asarray(tokens), jnp.asarray(lengths),
                              pol, **kw)

    def _account(self, step_hops: list) -> None:
        """Fold one step's active-lane (hops, request, lane) tuples into
        the fleet telemetry and let the governance plane react.  Each lane
        is priced at ITS OWN effective precision — the request policy's,
        falling back to its billing governor's active rung — so
        mixed-precision batches are billed at the byte widths they
        actually dispatched and an int8 step-down shows up as a measured
        saving.  With a TenantLedger the telemetry is grouped by tenant
        first: each tenant's governor sees only its own traffic, so one
        tenant's expensive burst can never walk another tenant's ladder.
        On the data-parallel plane each sample is additionally labeled
        with its serving device for per-device rolling estimates; with a
        registry, each (tenant, version) group also feeds its per-version
        ServeStats (the canary-judging evidence)."""
        hops = np.asarray([h for h, _, _ in step_hops])
        tiers = [req.tier for _, req, _ in step_hops]
        lanes = [lane for _, _, lane in step_hops]
        devices = (self.dispatcher.lane_devices(lanes)
                   if self.dispatcher is not None else None)
        energy_pj = None

        def price_into(out, gov, entries):
            """Price ``entries`` (index, req) with one governor, grouping
            by effective precision (one lane_pj call per precision)."""
            rung_prec = gov.current.precision
            groups: dict[str | None, list[int]] = {}
            for i, req in entries:
                prec = (req.policy.precision if req is not None
                        and req.policy is not None else None)
                groups.setdefault(
                    prec if prec is not None else rung_prec, []).append(i)
            for prec, idxs in groups.items():
                out[idxs] = np.asarray(
                    gov.model_for(prec).lane_pj(hops[idxs]))

        if self.governor is not None:
            energy_pj = np.empty(len(step_hops), np.float64)
            price_into(energy_pj, self.governor,
                       [(i, req) for i, (_, req, _) in enumerate(step_hops)])
            self.governor.observe(energy_pj=energy_pj, devices=devices)
            self.governor.step()
        elif self.ledger is not None:
            # per-tenant governance: group by tenant, price each group at
            # its own governor's models, observe/step each independently.
            # NaN marks lanes no governor bills (unledgered tenant, no
            # default) — counted as events, excluded from energy means.
            energy_pj = np.full(len(step_hops), np.nan)
            by_tenant: dict[str | None, list[int]] = {}
            for i, (_, req, _) in enumerate(step_hops):
                by_tenant.setdefault(req.tenant, []).append(i)
            for tenant, idxs in by_tenant.items():
                gov = self.ledger.governor_for(tenant)
                if gov is None:
                    continue
                price_into(energy_pj, gov,
                           [(i, step_hops[i][1]) for i in idxs])
                gov.observe(energy_pj=energy_pj[idxs],
                            devices=None if devices is None
                            else devices[idxs])
                gov.step()
        self.stats.update(hops, energy_pj, tiers=tiers)
        if self._meter is not None:      # deprecated shim path
            self._meter.update(hops)
        if self.registry is not None:
            by_version: dict[tuple, list[int]] = {}
            for i, (_, req, _) in enumerate(step_hops):
                if req.model is not None and req.version is not None:
                    by_version.setdefault(
                        (req.model, req.version), []).append(i)
            for (tenant, version), idxs in by_version.items():
                self.registry.stats_for(tenant, version).update(
                    hops[idxs],
                    None if energy_pj is None else energy_pj[idxs],
                    tiers=[tiers[i] for i in idxs])

    def run(self, max_steps: int = 10000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
