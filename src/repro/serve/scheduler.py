"""Batched request scheduler for serving (continuous batching + FoG).

A slot-based continuous-batching scheduler: a fixed decode batch of
``n_slots`` lanes; finished/empty lanes are refilled from the request queue
each step (the standard vLLM-style slot model, minus paged KV — caches here
are dense per-slot rings).  With FoG decode enabled, per-step grove usage
(hops) is accumulated per request, giving the per-request energy/FLOP
accounting that mirrors the paper's per-input hop counter.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HopMeter


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new_tokens: int = 32
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    hops: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    request: Request | None = None
    length: int = 0               # tokens already in this slot's cache


class ContinuousBatcher:
    """Drives decode_fn over a fixed slot batch, refilling as lanes finish.

    decode_fn(tokens [n_slots] int32, lengths [n_slots] int32)
        -> (logits [n_slots, V], hops [n_slots] | None)
    prefill_fn(slot, prompt) -> int  (returns prompt length in cache)
    """

    def __init__(self, n_slots: int, decode_fn: Callable,
                 prefill_fn: Callable, eos_id: int = 1,
                 meter: HopMeter | None = None):
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.eos_id = eos_id
        self.completed: list[Request] = []
        # fleet-level FoG accounting: hop counts of every decoded token feed
        # the same meter the engine's energy model reads
        self.meter = meter if meter is not None else HopMeter()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.popleft()
                slot.request = req
                slot.length = self.prefill_fn(i, req.prompt)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.request is not None)

    def step(self) -> int:
        """One decode step across all active slots.  Returns #active."""
        self._refill()
        if self.active == 0:
            return 0
        tokens = np.zeros((len(self.slots),), np.int32)
        lengths = np.zeros((len(self.slots),), np.int32)
        for i, s in enumerate(self.slots):
            if s.request is not None:
                last = (s.request.generated[-1] if s.request.generated
                        else s.request.prompt[-1])
                tokens[i] = last
                lengths[i] = s.length
        logits, hops = self.decode_fn(jnp.asarray(tokens), jnp.asarray(lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        hops = np.asarray(hops) if hops is not None else None
        for i, s in enumerate(self.slots):
            req = s.request
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            if hops is not None:
                h = int(hops[i])
                req.hops.append(h)
                self.meter.update(h)
            s.length += 1
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.completed.append(req)
                self.slots[i] = SlotState()
        return self.active

    def run(self, max_steps: int = 10000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
