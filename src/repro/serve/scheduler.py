"""Batched request scheduler for serving (continuous batching + FoG).

A slot-based continuous-batching scheduler: a fixed decode batch of
``n_slots`` lanes; finished/empty lanes are refilled from the request queue
each step (the standard vLLM-style slot model, minus paged KV — caches here
are dense per-slot rings).  With FoG decode enabled, per-step grove usage
(hops) is accumulated per request, giving the per-request energy/FLOP
accounting that mirrors the paper's per-input hop counter.

Mixed-QoS serving: every :class:`Request` may carry its own
:class:`~repro.core.policy.FogPolicy` (threshold / hop budget).  Each step
the scheduler assembles the slots' scalar policies into one per-lane batch
policy (:func:`repro.core.policy.assemble`) and hands it to a policy-aware
``decode_fn(tokens, lengths, policy)`` — one continuous batch, one compiled
program, every lane buying its own accuracy/energy point.  Legacy two-arg
``decode_fn(tokens, lengths)`` callables keep working unchanged.

Mixed-precision serving: a request's policy may additionally set
``precision`` ("fp32" | "bf16" | "int8" packed tables).  Precision selects
a compiled program, so it cannot ride the per-lane vectors; instead the
scheduler buckets the step's slots by precision and dispatches ``decode_fn``
once per distinct precision present (each call still carries the full
per-lane threshold/budget vectors; each slot's outputs are harvested from
its own precision's call).  A homogeneous batch — the common case — still
costs exactly one dispatch.
"""
from __future__ import annotations

import dataclasses
import inspect
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HopMeter
from repro.core.policy import FogPolicy, assemble


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new_tokens: int = 32
    # per-request QoS contract (scalar threshold / hop budget); None = the
    # batcher's default policy
    policy: FogPolicy | None = None
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    hops: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    request: Request | None = None
    length: int = 0               # tokens already in this slot's cache


def _takes_policy(decode_fn: Callable) -> bool:
    """Does decode_fn accept a third (policy) argument?"""
    try:
        params = inspect.signature(decode_fn).parameters.values()
    except (TypeError, ValueError):   # builtins / C callables: assume legacy
        return False
    positional = [p for p in params
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    return (len(positional) >= 3
            or any(p.kind == p.VAR_POSITIONAL for p in params))


class ContinuousBatcher:
    """Drives decode_fn over a fixed slot batch, refilling as lanes finish.

    decode_fn(tokens [n_slots] int32, lengths [n_slots] int32
              [, policy: FogPolicy with per-lane [n_slots] knobs])
        -> (logits [n_slots, V], hops [n_slots] | None)
    prefill_fn(slot, prompt) -> int  (returns prompt length in cache)
    default_policy: applied to slots whose request carries no policy (and
        to empty lanes); its static knobs select the compiled program.
    """

    def __init__(self, n_slots: int, decode_fn: Callable,
                 prefill_fn: Callable, eos_id: int = 1,
                 meter: HopMeter | None = None,
                 default_policy: FogPolicy | None = None):
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.eos_id = eos_id
        self.completed: list[Request] = []
        self.default_policy = (default_policy if default_policy is not None
                               else FogPolicy())
        if self.default_policy.per_lane:
            raise ValueError(
                "default_policy must carry scalar knobs; the batcher "
                "assembles the per-lane vectors itself each step")
        self._policy_aware = _takes_policy(decode_fn)
        # fleet-level FoG accounting: hop counts of every decoded token feed
        # the same meter the engine's energy model reads
        self.meter = meter if meter is not None else HopMeter()

    def submit(self, req: Request) -> None:
        if req.policy is not None:
            if req.policy.per_lane:
                raise ValueError(
                    f"request {req.rid}: per-request policies are scalar "
                    "contracts; the batcher assembles the per-lane vectors")
            # precision is static too, but the batcher handles it by
            # dispatching one program per precision group (see step())
            rejected = tuple(k for k in req.policy.static_overrides
                             if k != "precision")
            if rejected:
                raise ValueError(
                    f"request {req.rid}: policy sets static knobs "
                    f"{rejected} — those select the "
                    "compiled program and cannot vary per request; set "
                    "them on the batcher's default_policy (per-request "
                    "knobs are threshold, hop_budget and precision)")
        self.queue.append(req)

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.popleft()
                slot.request = req
                slot.length = self.prefill_fn(i, req.prompt)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.request is not None)

    def lane_policy(self) -> FogPolicy:
        """The current batch policy: slot policies stacked into per-lane
        threshold / hop-budget vectors (empty lanes get the default)."""
        return assemble(
            [s.request.policy if s.request is not None else None
             for s in self.slots],
            default=self.default_policy)

    def _precision_groups(self) -> dict:
        """Slot indices keyed by requested precision (None = the default
        program).  One decode dispatch per key — see the module docstring."""
        groups: dict[str | None, list[int]] = {}
        for i, s in enumerate(self.slots):
            p = (s.request.policy.precision
                 if s.request is not None and s.request.policy is not None
                 else None)
            groups.setdefault(p, []).append(i)
        none_idxs = groups.get(None)
        if none_idxs is not None and len(groups) > 1 and all(
                self.slots[i].request is None for i in none_idxs):
            # lanes in the None group are all empty: don't spend a dispatch
            # on them, fold into an arbitrary real group (outputs discarded)
            groups.pop(None)
            next(iter(groups.values())).extend(none_idxs)
        return groups

    def step(self) -> int:
        """One decode step across all active slots.  Returns #active."""
        self._refill()
        if self.active == 0:
            return 0
        tokens = np.zeros((len(self.slots),), np.int32)
        lengths = np.zeros((len(self.slots),), np.int32)
        for i, s in enumerate(self.slots):
            if s.request is not None:
                last = (s.request.generated[-1] if s.request.generated
                        else s.request.prompt[-1])
                tokens[i] = last
                lengths[i] = s.length
        if self._policy_aware:
            base = self.lane_policy()
            groups = self._precision_groups()
            n = len(self.slots)
            logits, hops = None, None
            for prec, idxs in groups.items():
                pol = base if prec is None else base.replace(precision=prec)
                lg, hp = self.decode_fn(jnp.asarray(tokens),
                                        jnp.asarray(lengths), pol)
                if len(groups) == 1:
                    logits, hops = lg, hp
                    break
                if logits is None:
                    logits = np.zeros(np.shape(lg), np.float32)
                    hops = None if hp is None else np.zeros((n,), np.int64)
                idxs = np.asarray(idxs)
                logits[idxs] = np.asarray(lg)[idxs]
                if hp is not None:
                    hops[idxs] = np.asarray(hp)[idxs]
        else:
            logits, hops = self.decode_fn(jnp.asarray(tokens),
                                          jnp.asarray(lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        hops = np.asarray(hops) if hops is not None else None
        for i, s in enumerate(self.slots):
            req = s.request
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            if hops is not None:
                h = int(hops[i])
                req.hops.append(h)
                self.meter.update(h)
            s.length += 1
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.completed.append(req)
                self.slots[i] = SlotState()
        return self.active

    def run(self, max_steps: int = 10000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
