"""EnergyGovernor — hold a serving-side nJ/classification SLO at run time.

The paper's knobs (threshold, hop budget, precision) trade accuracy for
energy *per evaluation*; this module closes the loop for a *service*: the
governor tracks a rolling nJ/classification estimate from evaluation
telemetry (:class:`~repro.core.engine.EvalReport` energy, or raw hop counts
priced by an energy model) and walks a calibrated **policy ladder** —
quality-descending rungs, canonically a :class:`~repro.core.frontier.
Frontier`'s Pareto points — stepping down (tighten threshold -> drop to
int8 -> cut hop budget) whenever the rolling estimate breaches the budget
and stepping back up when sustained headroom returns:

    frontier = build_frontier(engine, x_cal, y_cal)
    gov = EnergyGovernor(frontier, budget_nj=2.0,
                         model=engine.energy_model())
    batcher = ContinuousBatcher(..., governor=gov)   # serves under the SLO

Per-request contracts ride the same calibration: ``Request(...,
energy_budget_nj=1.0)`` is resolved by :meth:`policy_for_budget` into the
highest-accuracy rung fitting that budget, with the hop budget additionally
clamped so the contract holds even for adversarially hard inputs.

Step-down is immediate (an SLO breach must not persist); step-up requires
``patience`` consecutive compliant observations below ``headroom x budget``
(hysteresis, so the governor does not flap around the boundary).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.frontier import Frontier
from repro.core.policy import FogPolicy


class EnergyGovernor:
    """Walks a quality-descending policy ladder to hold an energy SLO.

    ladder:     a :class:`Frontier` (its Pareto points become the rungs,
                best-accuracy first) or an explicit quality-descending
                ``[FogPolicy]`` list
    budget_nj:  the SLO — rolling mean nJ/classification to stay under;
                None disables stepping (the governor only meters)
    model:      prices raw hop observations (``observe(hops=...)``) —
                anything with ``lane_pj(hops)`` / ``hops_within(pj)``
                (:class:`~repro.core.energy.EnergyModel` or
                :class:`~repro.core.energy.AffineEnergy`).  Optional when
                observations already carry pJ.
    window:     EWMA horizon in classifications for the rolling estimate
    headroom:   step back up only below ``headroom * budget_nj``
    patience:   consecutive compliant observations required to step up
    cooldown:   observations before a rung that *measured* over budget may
                be probed again (default ``4 * window``) — breach memory
                keeps an uncalibrated ladder from flapping, the expiry lets
                quality recover when the traffic mix eases
    warmup:     observations a freshly-entered rung must accumulate before
                the governor acts on its estimate again (default
                ``max(1, window // 8)``) — the EWMA restarts at each
                transition, so without a warmup a single outlier example
                could reseed it, trigger another step-down, and falsely
                stamp the rung's breach memory from a 1-sample estimate
    """

    def __init__(self, ladder: "Frontier | Sequence[FogPolicy]",
                 budget_nj: float | None, model=None, *,
                 window: int = 256, headroom: float = 0.8,
                 patience: int = 3, cooldown: int | None = None,
                 warmup: int | None = None):
        if isinstance(ladder, Frontier):
            self.frontier: Frontier | None = ladder
            rungs = ladder.ladder()
            self._rungs = [p.policy for p in rungs]
            self._predicted_nj = [p.energy_nj for p in rungs]
        else:
            self.frontier = None
            self._rungs = list(ladder)
            self._predicted_nj = None
        if not self._rungs:
            raise ValueError("governor needs at least one ladder rung")
        for i, p in enumerate(self._rungs):
            if p.per_lane:
                raise ValueError(
                    f"ladder rung {i} carries per-lane knobs; rungs are "
                    "scalar policies (the batcher assembles lane vectors)")
        self.budget_nj = budget_nj
        self.model = model
        self.window = int(window)
        self.headroom = float(headroom)
        self.patience = int(patience)
        self.cooldown = (int(cooldown) if cooldown is not None
                         else 4 * self.window)
        self.warmup = (int(warmup) if warmup is not None
                       else max(1, self.window // 8))
        self.rolling_nj: float | None = None
        self._seen = 0
        self._rung_obs = 0
        self._ok_streak = 0
        # per-device rolling estimates (data-parallel serving): the FLEET
        # estimate drives the ladder — one SLO, one control loop — but the
        # per-device view survives rung transitions and exposes skew (a
        # device drawing hard traffic, a straggling replica) that the fleet
        # mean would average away
        self.device_nj: dict[int, float] = {}
        self._device_obs: dict[int, int] = {}
        self._models: dict[str, object] = {}
        # measured cost of rungs that breached the budget, with the
        # observation count at the breach: an uncalibrated ladder learns
        # which rungs are unaffordable the first time it probes them (no
        # flapping), and the cooldown expiry re-admits them once the
        # breach evidence is stale
        self._measured_nj: dict[int, tuple[float, int]] = {}
        # start on the highest rung already predicted to meet the budget
        # (calibration said the rest overspend — don't serve them first)
        self.rung = 0
        if budget_nj is not None and self._predicted_nj is not None:
            fits = [i for i, e in enumerate(self._predicted_nj)
                    if e <= budget_nj]
            self.rung = fits[0] if fits else len(self._rungs) - 1
        self.transitions: list[tuple[int, int, float]] = []  # (from, to, nj)

    # -- state ------------------------------------------------------------
    @property
    def current(self) -> FogPolicy:
        """The active rung's policy (what the batcher serves this step)."""
        return self._rungs[self.rung]

    @property
    def n_rungs(self) -> int:
        return len(self._rungs)

    # -- telemetry --------------------------------------------------------
    def model_for(self, precision: str | None):
        """The pricing model at ``precision`` (derived from ``self.model``
        and cached): an int8 rung's hops must be priced at int8 byte
        widths, or stepping down a precision rung would never show a
        measured saving.  Falls back to the base model when the model
        carries no topology (AffineEnergy prices every precision alike)."""
        if precision is None or self.model is None:
            return self.model
        cached = self._models.get(precision)
        if cached is None:
            import dataclasses
            try:
                cached = dataclasses.replace(self.model,
                                             precision=precision)
            except (TypeError, ValueError):
                cached = self.model
            self._models[precision] = cached
        return cached

    def price(self, hops) -> np.ndarray:
        """Per-example pJ for raw hop telemetry, priced at the ACTIVE
        rung's precision (what the serving batcher feeds the stats)."""
        if self.model is None:
            raise ValueError(
                "pricing raw hop counts needs an energy model; "
                "construct EnergyGovernor(..., model=...)")
        return np.asarray(
            self.model_for(self.current.precision).lane_pj(
                np.asarray(hops)))

    def observe(self, hops=None, energy_pj=None, devices=None) -> float:
        """Fold one batch of telemetry into the rolling estimate.

        Pass ``energy_pj`` (per-example pJ, e.g. ``EvalReport.energy_pj``)
        when available, else ``hops`` to be priced at the active rung's
        precision.  ``devices`` optionally labels each example with the
        serving device index (data-parallel plane) to additionally feed the
        per-device rolling estimates (``device_nj``) — the fleet-wide
        estimate, and the ladder it drives, are unaffected.  Returns the
        updated rolling nJ/classification.
        """
        if energy_pj is None:
            if hops is None:
                raise ValueError("observe() needs hops or energy_pj")
            energy_pj = self.price(hops)
        e = np.asarray(energy_pj, np.float64)
        batch_nj = float(e.mean()) * 1e-3
        n = int(e.size)
        total = self._rung_obs + n
        if self.rolling_nj is None:
            self.rolling_nj = batch_nj
        else:
            # sample-weighted while the rung has seen fewer than `window`
            # examples (exact cumulative mean — a 1-example first batch
            # must not outweigh the 32 that follow), EWMA after
            alpha = min(1.0, n / max(1, min(total, self.window)))
            self.rolling_nj += alpha * (batch_nj - self.rolling_nj)
        self._seen += n
        self._rung_obs = total
        if devices is not None:
            d = np.asarray(devices).reshape(-1)
            if d.shape != e.reshape(-1).shape:
                raise ValueError(
                    f"devices labels {d.shape} must match the energy "
                    f"samples {e.reshape(-1).shape}")
            flat = e.reshape(-1)
            for dev in np.unique(d):
                vals = flat[d == dev]
                self._observe_device(int(dev), float(vals.mean()) * 1e-3,
                                     int(vals.size))
        return self.rolling_nj

    def _observe_device(self, dev: int, batch_nj: float, n: int) -> None:
        """Per-device EWMA, same warm-start weighting as the fleet
        estimate.  Survives rung transitions: it tracks the device, not
        the rung."""
        prev = self.device_nj.get(dev)
        obs = self._device_obs.get(dev, 0) + n
        if prev is None:
            self.device_nj[dev] = batch_nj
        else:
            alpha = min(1.0, n / max(1, min(obs, self.window)))
            self.device_nj[dev] = prev + alpha * (batch_nj - prev)
        self._device_obs[dev] = obs

    def ingest(self, batches) -> FogPolicy:
        """Replay deferred telemetry: ``batches`` is an ordered iterable of
        ``(energy_pj, devices)`` per-step batches (devices may be None).
        Each batch is observed and followed by one control-loop
        :meth:`step`, exactly as if it had been fed live — the batcher's
        deferred-telemetry ``flush()`` drains through here, so deferral
        shifts WHEN the governor acts (flush boundaries) but never what it
        sees.  Returns the active policy after the replay."""
        for energy_pj, devices in batches:
            self.observe(energy_pj=energy_pj, devices=devices)
            self.step()
        return self.current

    def device_summary(self) -> dict:
        """Per-device view: ``{device: {"nj": rolling, "n": observations}}``
        plus the fleet spread (max - min rolling nJ across devices) under
        the ``"spread_nj"`` key of the returned dict's ``None`` entry."""
        out: dict = {dev: {"nj": nj, "n": self._device_obs[dev]}
                     for dev, nj in sorted(self.device_nj.items())}
        if self.device_nj:
            vals = list(self.device_nj.values())
            out[None] = {"spread_nj": max(vals) - min(vals)}
        return out

    # -- the control loop -------------------------------------------------
    def step(self) -> FogPolicy:
        """One governor decision after the latest ``observe``: step down on
        a breach, step up after sustained headroom.  Returns the (possibly
        new) active policy."""
        if self.budget_nj is None or self.rolling_nj is None:
            return self.current
        if self._rung_obs < self.warmup:
            # fresh rung, fresh estimate: don't act (or stamp breach
            # memory) off a handful of possibly-outlier examples
            return self.current
        if self.rolling_nj > self.budget_nj:
            self._ok_streak = 0
            # remember what this rung measured at the breach: the governor
            # will not climb back onto it until the evidence goes stale
            self._measured_nj[self.rung] = (self.rolling_nj, self._seen)
            if self.rung < len(self._rungs) - 1:
                self._move(self.rung + 1)
        elif self.rolling_nj <= self.headroom * self.budget_nj:
            self._ok_streak += 1
            if self._ok_streak >= self.patience and self.rung > 0:
                # only climb onto a rung neither calibration nor a recent
                # measured breach says is unaffordable
                up = self.rung - 1
                pred = (self._predicted_nj[up]
                        if self._predicted_nj is not None else None)
                if ((pred is None or pred <= self.budget_nj)
                        and not self._recently_breached(up)):
                    self._move(up)
                    self._ok_streak = 0
        else:
            self._ok_streak = 0
        return self.current

    def _recently_breached(self, rung: int) -> bool:
        entry = self._measured_nj.get(rung)
        if entry is None:
            return False
        nj, seen_at = entry
        if self._seen - seen_at >= self.cooldown:
            del self._measured_nj[rung]      # stale evidence: probe again
            return False
        return nj > self.budget_nj

    def _move(self, to: int) -> None:
        self.transitions.append((self.rung, to, self.rolling_nj))
        self.rung = to
        # the EWMA estimated the OLD rung's cost; carrying it across the
        # transition would misattribute stale breaches to the new rung
        # (cascading one expensive burst down the whole ladder and falsely
        # stamping every rung on the way) — start the estimate fresh
        self.rolling_nj = None
        self._rung_obs = 0
        self._ok_streak = 0

    # -- per-request contracts --------------------------------------------
    def policy_for_budget(self, energy_budget_nj: float) -> FogPolicy:
        """Resolve a per-request nJ contract into a scalar policy: the
        highest-accuracy calibrated rung fitting the budget, with the hop
        budget clamped (via the energy model) so even adversarially hard
        inputs cannot overspend it.

        Without a frontier the best rung is taken instead of the cheapest:
        the hop clamp alone already enforces the budget, so giving up
        threshold quality too would punish the request twice.  Only a
        model-less, frontier-less governor degrades to the cheapest rung
        (nothing can price the clamp).

        Raises ValueError when the budget is below even ONE hop's cost at
        the cheapest rung's precision: the first hop is always spent, so
        such a contract is unhonorable and silently overspending it would
        make the "hard" per-request guarantee a lie."""
        if self.frontier is not None:
            try:
                pol = self.frontier.under_budget(energy_budget_nj).policy
            except ValueError:
                pol = self._rungs[-1]      # cheapest rung: best effort
        elif self.model is not None:
            pol = self._rungs[0]           # clamp enforces the budget
        else:
            pol = self._rungs[-1]
        if self.model is not None:
            budget_pj = energy_budget_nj * 1e3
            if budget_pj < self.model_for(pol.precision).per_hop_pj:
                # maybe a cheaper table dtype on the bottom rung still fits
                pol = self._rungs[-1]
                if budget_pj < self.model_for(pol.precision).per_hop_pj:
                    raise ValueError(
                        f"energy budget {energy_budget_nj:.4f} nJ is below "
                        f"one hop's cost "
                        f"({self.model_for(pol.precision).per_hop_pj * 1e-3:.4f}"
                        f" nJ) — the first hop is always spent, so this "
                        "per-request contract cannot be honored")
            cap = self.model_for(pol.precision).hops_within(budget_pj)
            if pol.hop_budget is not None:
                cap = min(cap, int(np.asarray(pol.hop_budget).item()))
            pol = pol.replace(hop_budget=cap)
        return pol

    def summary(self) -> str:
        nj = ("n/a" if self.rolling_nj is None
              else f"{self.rolling_nj:.3f}")
        budget = ("none" if self.budget_nj is None
                  else f"{self.budget_nj:.3f}")
        s = (f"rolling {nj} nJ / budget {budget} nJ, rung "
             f"{self.rung + 1}/{len(self._rungs)}, "
             f"{len(self.transitions)} transitions, "
             f"{self._seen} classifications")
        if self.device_nj:
            vals = list(self.device_nj.values())
            s += (f", {len(vals)} devices "
                  f"(spread {max(vals) - min(vals):.3f} nJ)")
        return s


class TenantLedger:
    """Per-tenant energy budget ledger: one independent
    :class:`EnergyGovernor` per tenant behind a single serving process.

    Each tenant's governor walks its own ladder under its own nJ budget —
    one tenant's expensive traffic steps *that tenant's* rung down and
    leaves every other tenant's estimate untouched (the batcher groups hop
    telemetry by tenant before feeding it here).  The optional ``default``
    governor serves requests whose tenant has no ledger entry; without
    one, unledgered tenants serve the batcher's default policy unpriced.

        ledger = TenantLedger()
        ledger.add("alpha", EnergyGovernor(ladder_a, 2.0, model=model_a))
        ledger.add("beta",  EnergyGovernor(ladder_b, 0.8, model=model_b))
        batcher = ContinuousBatcher(..., governor=ledger, registry=reg)
    """

    def __init__(self, default: EnergyGovernor | None = None):
        if default is not None and default.model is None:
            raise ValueError(
                "the ledger's default governor needs an energy model to "
                "price hop telemetry")
        self._governors: dict[str, EnergyGovernor] = {}
        self.default = default

    def add(self, tenant: str, governor: EnergyGovernor) -> EnergyGovernor:
        """Install one tenant's governor (replacing any previous one)."""
        if governor.model is None:
            raise ValueError(
                f"tenant {tenant!r}: a ledgered governor needs an energy "
                "model to price hop telemetry; construct "
                "EnergyGovernor(..., model=...)")
        self._governors[tenant] = governor
        return governor

    def governor_for(self, tenant: str | None) -> EnergyGovernor | None:
        """The governor billing ``tenant`` (the default when unledgered)."""
        if tenant is not None and tenant in self._governors:
            return self._governors[tenant]
        return self.default

    def tenants(self) -> list[str]:
        return sorted(self._governors)

    def items(self):
        return sorted(self._governors.items())

    def summary(self) -> str:
        lines = [f"{t}: {g.summary()}" for t, g in self.items()]
        return "\n".join(lines) if lines else "no ledgered tenants"


def default_ladder(base: FogPolicy, model=None,
                   budget_nj: float | None = None) -> list[FogPolicy]:
    """An uncalibrated quality-descending ladder when no frontier exists:
    the ISSUE's rung order — tighten threshold, drop to int8, cut the hop
    budget (sized from the model + budget when both are given, else 2)."""
    t = float(np.asarray(base.threshold).mean())
    tight = base.replace(threshold=t * 0.5)
    int8 = tight.replace(precision="int8")
    if model is not None and budget_nj is not None:
        cap = model.hops_within(budget_nj * 1e3)
    else:
        cap = 2
    return [base, tight, int8, int8.replace(hop_budget=cap)]
