"""Data-parallel serving plane: replicate the model, shard the batch.

The ring backend shards *groves* across a mesh; this module shards the
*batch*.  A :class:`DeviceDispatcher` owns N replicas of one decode
program, each bound to its own device and to a fixed contiguous span of
the batcher's slots (slot ``i`` lives on device ``i // span`` forever, so
per-device state — packed tables, KV caches, feature buffers — never
migrates and every replica compiles exactly one program shape).

Dispatch is asynchronous: each step the
:class:`~repro.serve.scheduler.ContinuousBatcher` calls
:meth:`DeviceDispatcher.dispatch` once per precision group; the dispatcher
slices the group's span inputs, enqueues one decode call per (device,
precision) on that device's dispatch queue, and returns WITHOUT blocking —
JAX's async dispatch lets every replica compute concurrently.
:meth:`harvest` drains the queues with a single deferred
``jax.block_until_ready`` over everything in flight, then scatters the
per-span outputs back into full ``[n_slots]`` arrays.  A precision group
that touches a span dispatches the FULL span (fixed shape, no recompile
churn — the per-lane threshold/budget vectors are traced inputs) and only
the group's lanes are harvested from it, mirroring the single-device
bucketed dispatch in ``scheduler.step``.

Replication is plain device placement: :func:`replicate` ``device_put``\\ s
a pytree (e.g. a :class:`~repro.forest.pack.ForestPack`) onto each serve
device; committed inputs then pin each replica's computation to its own
device.  On CPU-only hosts (CI), force a mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — see
:func:`repro.launch.mesh.serve_devices`.

:class:`ForestReplicaServer` is the canonical factory for the paper's
workload: forest classification serving, one pending feature row per slot,
a ForestPack replica (per precision) per device.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import NO_BUDGET, FogPolicy, LanePolicies


def replicate(tree, devices: Sequence) -> list:
    """One committed copy of ``tree`` per device (model replication for the
    data-parallel plane)."""
    return [jax.device_put(tree, d) for d in devices]


@dataclasses.dataclass
class Pending:
    """One in-flight decode call on one device's dispatch queue."""

    device: int                  # dispatcher device index
    precision: str | None        # the precision group this call serves
    lanes: np.ndarray            # global lane indices to harvest from it
    local: np.ndarray            # those lanes' offsets inside the span
    logits: object               # [span, C] device array (not yet ready)
    hops: object                 # [span] device array | None
    dispatched_at: float = 0.0
    # the (model, version) registry bucket this call serves (None = the
    # single built-in model)
    bucket: tuple | None = None
    # packed-protocol outputs (the resident fast path): argmax labels and
    # per-lane modeled pJ computed inside the dispatch, so harvest never
    # downloads [span, C] logits or re-prices hops on the host
    nxt: object | None = None    # [span] int32 device array
    energy: object | None = None  # [span] float32 device array


class DeviceDispatcher:
    """Fan one continuous batch out over per-device decode replicas.

    decode_factory(index, device, span) -> decode_fn(tokens [span],
        lengths [span], policy with [span] lane vectors) -> (logits, hops)
        The factory builds ONE replica: it places that replica's state on
        ``device`` and must return without blocking on results (outputs are
        harvested later).  ``tokens``/``lengths`` arrive as numpy slices;
        the replica is responsible for ``jax.device_put`` onto its device.
    devices: the serve devices (default: every local device — force >1 on
        CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    The dispatcher is bound to a slot count by the batcher
    (:meth:`bind`); ``n_slots`` must divide evenly over the devices.
    """

    def __init__(self, decode_factory: Callable, devices: Sequence | None = None):
        if devices is None:
            devices = jax.devices()
        if not devices:
            raise ValueError("DeviceDispatcher needs at least one device")
        self.devices = list(devices)
        self.decode_factory = decode_factory
        self.span: int | None = None
        self._fns: list[Callable] | None = None
        # per-device dispatch queues, drained at harvest time
        self._queues: list[list[Pending]] = [[] for _ in self.devices]

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def bind(self, n_slots: int) -> None:
        """Partition ``n_slots`` into per-device spans and build the
        replicas (idempotent for the same slot count)."""
        if self.span is not None:
            if self.span * self.n_devices != n_slots:
                raise ValueError(
                    f"dispatcher already bound to "
                    f"{self.span * self.n_devices} slots, cannot rebind "
                    f"to {n_slots}")
            return
        if n_slots % self.n_devices:
            raise ValueError(
                f"n_slots={n_slots} must divide evenly over "
                f"{self.n_devices} devices (fixed per-device spans)")
        self.span = n_slots // self.n_devices
        self._fns = [self.decode_factory(i, d, self.span)
                     for i, d in enumerate(self.devices)]
        from repro.serve.scheduler import _takes_bucket
        self._fn_buckets = [_takes_bucket(fn) for fn in self._fns]

    @property
    def bucket_aware(self) -> bool:
        """Can the replicas route (model, version) buckets?  True only
        when EVERY replica decode fn takes a ``bucket`` keyword."""
        if self._fns is None:
            raise ValueError("dispatcher not bound; construct the batcher "
                             "(or call bind) first")
        return all(self._fn_buckets)

    def device_of(self, lane: int) -> int:
        """Which device serves a global lane index."""
        if self.span is None:
            raise ValueError("dispatcher not bound; construct the batcher "
                             "(or call bind) first")
        return lane // self.span

    def lane_devices(self, lanes) -> np.ndarray:
        """Vectorized :meth:`device_of` (telemetry labeling)."""
        return np.asarray(lanes, np.int64) // self.span

    # -- the dispatch/harvest cycle ---------------------------------------
    def dispatch(self, tokens: np.ndarray, lengths: np.ndarray,
                 policy: FogPolicy, lanes,
                 bucket: tuple | None = None) -> list[Pending]:
        """Enqueue one bucket's lanes, without blocking.

        ``policy`` carries the group's static knobs and the FULL-batch
        per-lane vectors; ``lanes`` are the global lane indices belonging
        to this group; ``bucket`` is the (model, version) registry bucket
        (None = the single built-in model).  Every device whose span
        intersects ``lanes`` gets one decode call over its whole span.
        """
        if self._fns is None:
            self.bind(len(tokens))
        lanes = np.asarray(lanes, np.int64)
        thr = np.asarray(policy.threshold)
        bud = (np.asarray(policy.hop_budget)
               if policy.hop_budget is not None else None)
        out = []
        for d in np.unique(lanes // self.span):
            d = int(d)
            lo, hi = d * self.span, (d + 1) * self.span
            sl = slice(lo, hi)
            span_pol = policy.replace(
                threshold=thr[sl] if thr.ndim else policy.threshold,
                hop_budget=(bud[sl] if bud is not None and bud.ndim
                            else policy.hop_budget))
            mine = lanes[(lanes >= lo) & (lanes < hi)]
            if self._fn_buckets[d]:
                logits, hops = self._fns[d](tokens[sl], lengths[sl],
                                            span_pol, bucket=bucket)
            elif bucket is not None:
                raise ValueError(
                    f"device {d}'s decode replica is not bucket-aware "
                    "(no bucket= parameter) but the batch carries "
                    f"registry bucket {bucket!r}")
            else:
                logits, hops = self._fns[d](tokens[sl], lengths[sl],
                                            span_pol)
            p = Pending(device=d, precision=policy.precision, lanes=mine,
                        local=mine - lo, logits=logits, hops=hops,
                        dispatched_at=time.perf_counter(), bucket=bucket)
            self._queues[d].append(p)
            out.append(p)
        return out

    # -- the packed (device-resident) cycle -------------------------------
    @property
    def packed(self) -> bool:
        """True when every replica speaks the packed protocol: resident
        slot state updated via :meth:`admit_lane` / :meth:`retire_lane`
        splices, dispatches that take only the step's default knobs, and
        ``(next, hops, energy)`` outputs (see
        :meth:`ForestReplicaServer.packed_factory`)."""
        if self._fns is None:
            raise ValueError("dispatcher not bound; construct the batcher "
                             "(or call bind) first")
        return all(getattr(fn, "packed", False) for fn in self._fns)

    def admit_lane(self, lane: int, row, thr: float, bud: int) -> None:
        """Stage one lane's feature row + resolved policy knobs onto its
        replica (applied as a donated device splice at the next dispatch).
        ``row=None`` re-stamps the policy knobs only (rung re-stamps after
        a deferred-telemetry flush)."""
        self._fns[lane // self.span].admit(lane % self.span, row, thr, bud)

    def admit_lanes(self, lanes, rows, thr, bud) -> None:
        """Bulk :meth:`admit_lane`: one vectorized staging write per
        intersecting replica instead of a Python call per lane.  ``rows``
        is ``[k, n_features]`` aligned with ``lanes`` (or None for a
        knob-only re-stamp); ``thr`` / ``bud`` are ``[k]``."""
        lanes = np.asarray(lanes, np.int64)
        thr = np.asarray(thr, np.float32)
        bud = np.asarray(bud, np.int32)
        devs = lanes // self.span
        for d in np.unique(devs):
            m = devs == d
            self._fns[int(d)].admit_many(
                lanes[m] - int(d) * self.span,
                None if rows is None else rows[m], thr[m], bud[m])

    def retire_lane(self, lane: int) -> None:
        """Stage one lane DEAD on its replica (freed slot: exits on hop 1
        until re-admitted; an admit in the same step overrides it)."""
        self._fns[lane // self.span].retire(lane % self.span)

    def retire_lanes(self, lanes) -> None:
        """Bulk :meth:`retire_lane` (one staging write per replica)."""
        lanes = np.asarray(lanes, np.int64)
        devs = lanes // self.span
        for d in np.unique(devs):
            self._fns[int(d)].retire_many(
                lanes[devs == d] - int(d) * self.span)

    def dispatch_packed(self, lanes, default_thresh: float,
                        default_budget: int, precision: str | None = None,
                        bucket: tuple | None = None) -> list[Pending]:
        """Enqueue one bucket's lanes on the packed protocol, without
        blocking: every intersecting device runs its whole span from
        RESIDENT state — the only per-dispatch traced inputs are the step's
        default threshold/budget scalars (lanes without explicit policies
        resolve against them in-jit, so a governor rung change costs no
        re-splice)."""
        lanes = np.fromiter(lanes, np.int64, len(lanes)) \
            if not isinstance(lanes, np.ndarray) else lanes.astype(np.int64)
        out = []
        for d in np.unique(lanes // self.span):
            d = int(d)
            lo = d * self.span
            mine = lanes[(lanes >= lo) & (lanes < lo + self.span)]
            nxt, hops, energy = self._fns[d](
                np.float32(default_thresh), np.int32(default_budget),
                precision, bucket=bucket)
            p = Pending(device=d, precision=precision, lanes=mine,
                        local=mine - lo, logits=None, hops=hops,
                        dispatched_at=time.perf_counter(), bucket=bucket,
                        nxt=nxt, energy=energy)
            self._queues[d].append(p)
            out.append(p)
        return out

    def harvest_packed(self, n_slots: int):
        """Drain the packed queues: one deferred ``block_until_ready``,
        then scatter each group's lanes into full-batch HOST arrays.

        Returns ``(next [n_slots] int32, hops [n_slots] int64,
        energy_pj [n_slots] float64, dispatches)`` — no logits cross the
        host boundary and nothing is re-priced here."""
        pending = [p for q in self._queues for p in q]
        for q in self._queues:
            q.clear()
        if not pending:
            raise ValueError("harvest_packed() with nothing dispatched")
        jax.block_until_ready([(p.nxt, p.hops, p.energy) for p in pending])
        nxt = np.zeros((n_slots,), np.int32)
        hops = np.zeros((n_slots,), np.int64)
        energy = np.zeros((n_slots,), np.float64)
        for p in pending:
            nxt[p.lanes] = np.asarray(p.nxt)[p.local]
            hops[p.lanes] = np.asarray(p.hops)[p.local]
            energy[p.lanes] = np.asarray(p.energy)[p.local]
        return nxt, hops, energy, pending

    def harvest(self, n_slots: int):
        """Drain every device queue: ONE deferred ``block_until_ready``
        over all in-flight outputs, then scatter the group lanes back into
        full-batch arrays.

        Returns ``(logits [n_slots, C], hops [n_slots] | None,
        dispatches)`` — logits/hops as HOST numpy arrays — where
        ``dispatches`` is the drained :class:`Pending` list (device /
        precision / lane bookkeeping for telemetry and the load harness's
        per-device accounting).
        """
        pending = [p for q in self._queues for p in q]
        for q in self._queues:
            q.clear()
        if not pending:
            raise ValueError("harvest() with nothing dispatched")
        # the single deferred synchronization point of the whole step
        jax.block_until_ready([(p.logits, p.hops) for p in pending])
        hops_present = [p.hops is not None for p in pending]
        if any(hops_present) != all(hops_present):
            raise ValueError(
                "inconsistent decode replicas: some returned hop telemetry "
                "and some returned hops=None")
        logits = None
        hops = None
        for p in pending:
            lg = np.asarray(p.logits)
            if logits is None:
                logits = np.zeros((n_slots,) + lg.shape[1:], lg.dtype)
                if p.hops is not None:
                    hops = np.zeros((n_slots,), np.int64)
            logits[p.lanes] = lg[p.local]
            if p.hops is not None:
                hops[p.lanes] = np.asarray(p.hops)[p.local]
        # numpy on purpose: the scheduler's post-step bookkeeping (argmax,
        # per-lane harvesting) is host-side serial work — handing back
        # device arrays would buy nothing but re-dispatch latency
        return logits, hops, pending


@partial(jax.jit,
         static_argnames=("max_hops", "backend", "block_b"))
def _serve_eval(pack, x, key, step, thresh, budget, max_hops: int,
                backend: str, block_b: int):
    """One span's decode as ONE jitted program: start-grove draw +
    Algorithm-2 evaluation fused into a single dispatch.  The serving loop
    is latency-bound on per-dispatch Python/runtime overhead, so the
    un-jitted conveniences of ``FogEngine.eval`` (policy resolution, report
    pricing, a separate ``sample_starts`` dispatch) are deliberately
    bypassed — ``_eval_core`` is the same conformance-tested state machine
    every backend shares."""
    from repro.core.engine import _eval_core
    start = jax.random.randint(jax.random.fold_in(key, step),
                               (x.shape[0],), 0, pack.n_groves)
    res = _eval_core(pack, x, start, thresh, budget, max_hops, backend,
                     block_b, False)
    return res.proba, res.hops


@partial(jax.jit,
         static_argnames=("max_hops", "backend", "block_b"))
def _serve_eval_packed(pack, x, key, step, thresh, budget, def_thresh,
                       def_budget, per_hop_pj, transfer_pj, max_hops: int,
                       backend: str, block_b: int):
    """The packed protocol's whole decode step as ONE jitted program over
    RESIDENT span state: start-grove draw, per-lane default resolution
    (NaN-threshold / negative-budget lanes take the step's default rung
    scalars), Algorithm-2 evaluation, argmax, and affine energy pricing —
    so a dispatch uploads nothing (the step counter lives on device and
    the default knobs are cached device scalars) and downloads three
    [span] vectors instead of round-tripping rows, policy vectors and
    [span, C] logits.  Returns ``(next, hops, energy, step + 1)`` — the
    caller feeds the incremented counter straight back in, keeping the
    whole dispatch on jax's fast path with zero host->device scalar
    conversions per call."""
    from repro.core.engine import _eval_core
    start = jax.random.randint(jax.random.fold_in(key, step),
                               (x.shape[0],), 0, pack.n_groves)
    thr = jnp.where(jnp.isnan(thresh), def_thresh, thresh)
    bud = jnp.where(budget < 0, def_budget, budget)
    res = _eval_core(pack, x, start, thr, bud, max_hops, backend,
                     block_b, False)
    nxt = jnp.argmax(res.proba, axis=-1).astype(jnp.int32)
    h = res.hops.astype(jnp.float32)
    energy = h * per_hop_pj + jnp.maximum(h - 1.0, 0.0) * transfer_pj
    return nxt, res.hops, energy, step + 1


class ForestReplicaServer:
    """Forest classification serving behind a :class:`DeviceDispatcher`.

    Each slot holds one pending feature row; each device hosts committed
    :class:`~repro.forest.pack.ForestPack` replicas (one per precision in
    ``precisions``, so per-request ``FogPolicy(precision=...)`` contracts
    dispatch against resident tables instead of re-packing mid-step).

        server = ForestReplicaServer(gc, n_features=16)
        disp = DeviceDispatcher(server.factory, devices=serve_devices(4))
        batcher = ContinuousBatcher(128, None, server.prefill,
                                    dispatcher=disp)
        batcher.submit(Request(rid=0, prompt=x_row, max_new_tokens=1))

    ``Request.prompt`` is the feature row (float, ``[n_features]``); the
    decode "logits" are the forest's class probabilities and ``hops`` is
    the paper's per-example energy quantity, so the whole mixed-QoS /
    governor / admission-control machinery applies unchanged.

    Multi-tenant mode: pass ``registry=`` (a
    :class:`~repro.registry.ModelRegistry`) and ``cache=`` (a
    :class:`~repro.registry.PackCache`) and the replicas become
    bucket-aware — a dispatch carrying ``bucket=(tenant, version)``
    evaluates that tenant version's pack, fetched through the VMEM-
    budgeted cache (per-device committed copies, traffic-weighted
    eviction, lazy reload from artifact).  ``gc`` may then be ``None``:
    bucketless dispatches require a built-in model and raise without one.
    """

    def __init__(self, gc, n_features: int, *, backend: str = "fused",
                 precisions: Sequence[str] = ("fp32",), seed: int = 0,
                 registry=None, cache=None):
        from repro.forest.pack import ForestPack
        if (registry is None) != (cache is None):
            raise ValueError(
                "registry mode needs BOTH registry= and cache= (the cache "
                "enforces the VMEM byte budget the replicas load through)")
        self.registry = registry
        self.cache = cache
        if gc is None:
            if registry is None:
                raise ValueError(
                    "ForestReplicaServer needs a grove collection/pack, "
                    "or registry= + cache= for multi-tenant serving")
            self._packs = {}
        elif isinstance(gc, ForestPack):
            self._packs = {gc.precision: gc}
            for p in precisions:
                if p not in self._packs:
                    self._packs[p] = gc.astype(p)
        else:
            self._packs = {p: ForestPack.from_groves(gc, p)
                           for p in precisions}
        self.default_precision = tuple(precisions)[0]
        self.n_features = int(n_features)
        self.backend = backend
        self.seed = seed
        self._buffers: dict[int, np.ndarray] = {}
        self._span: int | None = None
        self._steps: dict[int, int] = {}
        self._energy_models: dict[tuple, object] = {}
        self._devices: dict[int, object] = {}
        # (precision, bucket) -> (per_hop_pj, transfer_pj) float32 scalars
        # traced into the packed dispatch (in-jit affine pricing)
        self._hop_costs: dict[tuple, tuple] = {}

    @property
    def n_groves(self) -> int:
        if not self._packs:
            raise ValueError("registry-mode server has no built-in model; "
                             "ask a bucket's pack for its grove count")
        return self._packs[self.default_precision].n_groves

    def energy_model(self, precision: str | None = None,
                     tenant: str | None = None,
                     version: int | None = None):
        """The pricing :class:`~repro.core.energy.EnergyModel` for one
        precision's packed tables (cached).  In registry mode pass
        ``tenant`` (and optionally ``version``, default live) to price
        that tenant's topology — tenants' forests need not match."""
        from repro.core.energy import EnergyModel
        precision = precision or self.default_precision
        if tenant is not None:
            if self.registry is None:
                raise ValueError("tenant-keyed energy models need a "
                                 "registry-mode server")
            if version is None:
                version = self.registry.live_version(tenant)
            key = (precision, tenant, int(version))
            m = self._energy_models.get(key)
            if m is None:
                pack = self.cache.get(tenant, version, precision)
                m = EnergyModel.from_pack(pack, self.n_features)
                self._energy_models[key] = m
            return m
        key = (precision, None, None)
        m = self._energy_models.get(key)
        if m is None:
            m = EnergyModel.from_pack(self._packs[precision],
                                      self.n_features)
            self._energy_models[key] = m
        return m

    def factory(self, index: int, device, span: int):
        """The :class:`DeviceDispatcher` ``decode_factory`` contract."""
        self._span = span
        buf = np.zeros((span, self.n_features), np.float32)
        self._buffers[index] = buf
        self._devices[index] = device
        packs = {p: jax.device_put(pack, device)
                 for p, pack in self._packs.items()}
        key = jax.device_put(jax.random.key(self.seed + index), device)
        self._steps[index] = 0
        backend = self.backend
        block_b = min(256, span)

        def decode(tokens, lengths, policy, bucket=None):
            # tokens/lengths are the slot-model plumbing; the forest serves
            # the span's feature rows.  A fresh start-grove draw per step
            # keeps the rotation-start randomization honest under
            # continuous refill.  Per-lane knobs are shaped as numpy — the
            # jit call places them beside the committed pack/x, so the
            # whole evaluation runs on THIS replica's device.
            step = self._steps[index] = self._steps[index] + 1
            thr = np.broadcast_to(
                np.asarray(policy.threshold, np.float32), (span,))
            bud = (np.broadcast_to(
                       np.asarray(policy.hop_budget, np.int32), (span,))
                   if policy.hop_budget is not None
                   else np.full((span,), NO_BUDGET, np.int32))
            prec = policy.precision or self.default_precision
            if bucket is not None:
                # registry bucket: this replica's committed copy of the
                # (tenant, version) pack at the group's precision, through
                # the VMEM-budgeted cache (lazy reload after eviction)
                if self.cache is None:
                    raise ValueError(
                        f"replica {index} got bucket {bucket!r} but the "
                        "server has no registry/cache (single-model mode)")
                tenant, version = bucket
                pack = self.cache.device_pack(tenant, version, prec,
                                              index, device)
            elif packs:
                pack = packs[prec]
            else:
                raise ValueError(
                    "registry-mode server got a bucketless dispatch; "
                    "requests must carry Request.model (no built-in "
                    "default model was constructed)")
            x = jax.device_put(buf, device)
            return _serve_eval(pack, x, key, np.int32(step),
                               thr, bud, max_hops=pack.n_groves,
                               backend=backend, block_b=block_b)

        return decode

    def _hop_cost(self, prec: str, bucket, pack):
        """Cached (per_hop_pj, transfer_pj) host floats for one pack's
        topology at one precision — the traced inputs of the in-jit affine
        energy pricing.  Host floats, not device scalars: the server is
        shared by every replica, and a scalar committed to one replica's
        device would be transferred on every other replica's dispatch
        (each replica device_puts its own copy in ``packed_factory``)."""
        key = (prec, bucket)
        c = self._hop_costs.get(key)
        if c is None:
            from repro.core.energy import EnergyModel
            m = EnergyModel.from_pack(pack, self.n_features)
            c = (float(m.per_hop_pj), float(m.transfer_pj))
            self._hop_costs[key] = c
        return c

    def packed_factory(self, index: int, device, span: int):
        """Packed-protocol replica: per-slot feature rows and policy
        vectors live as PERSISTENT device buffers, updated in place via
        donated splices when the batcher admits/retires lanes
        (:func:`~repro.core.engine.splice_slot_state`), and each dispatch
        runs
        :func:`_serve_eval_packed` — start draw, default resolution,
        evaluation, argmax and energy pricing in one launch.  ``step()``
        therefore stops paying per-step row uploads, policy re-assembly and
        logits downloads; only three [span] vectors come back per dispatch.
        """
        from repro.core.engine import splice_slot_state
        self._span = span
        self._devices[index] = device
        packs = {p: jax.device_put(pack, device)
                 for p, pack in self._packs.items()}
        key = jax.device_put(jax.random.key(self.seed + index), device)
        self._steps[index] = 0
        backend = self.backend
        block_b = min(256, span)
        lp = LanePolicies(span)
        # resident state; the splice path DONATES, so references live in one
        # mutable cell the closures rebind.  The per-replica step counter
        # is device-resident too: the eval returns step+1 and the closure
        # feeds it straight back — no host scalar crosses per dispatch.
        state = {
            "x": jax.device_put(
                jnp.zeros((span, self.n_features), jnp.float32), device),
            "thr": jax.device_put(jnp.asarray(lp.thresh), device),
            "bud": jax.device_put(jnp.asarray(lp.budget), device),
            "step": jax.device_put(jnp.int32(1), device),
        }
        # cached device conversions of the step's default knob scalars
        # (governor rungs form a small set; np scalars hash by value)
        knob_cache: dict[tuple, tuple] = {}
        # per-REPLICA device copies of the energy pricing scalars: a copy
        # committed to another replica's device would be re-transferred on
        # every dispatch, which dwarfs the eval enqueue itself
        hop_cache: dict[tuple, tuple] = {}
        # host mirror of the resident feature rows: the staging target for
        # admits (one vectorized write per burst), the row source for the
        # fused splice, and what prefill()-style callers (calibration) read
        mirror = np.zeros((span, self.n_features), np.float32)
        self._buffers[index] = mirror

        def admit_many(locals_, rows, thr, bud) -> None:
            if rows is not None:
                rows = np.asarray(rows, np.float32)
                if rows.shape[-1] != self.n_features:
                    raise ValueError(
                        f"request feature rows have {rows.shape[-1]} "
                        f"features, server expects {self.n_features}")
                mirror[locals_] = rows
            lp.stamp_many(locals_, thr, bud)

        def admit(local: int, row, thr: float, bud: int) -> None:
            admit_many(np.asarray([local]),
                       None if row is None
                       else np.asarray(row, np.float32).reshape(1, -1),
                       thr, bud)

        def retire_many(locals_) -> None:
            lp.retire_many(locals_)

        def retire(local: int) -> None:
            retire_many(np.asarray([local]))

        def _apply_staged() -> None:
            # one FUSED splice over all three buffers, driven by the knob
            # dirty set (every row admit also stamps knobs, so it covers
            # the row writes; rows come from the mirror, which is current
            # for admitted lanes and harmlessly stale for retired ones).
            # donate=False: the PREVIOUS dispatch may still be reading
            # these buffers (double-buffered pipeline) — donating would
            # stall the enqueue until it drains
            if lp.dirty:
                idx, thr, bud = lp.take_dirty()
                state["x"], state["thr"], state["bud"] = splice_slot_state(
                    state["x"], state["thr"], state["bud"],
                    idx, mirror[idx], thr, bud, donate=False)

        def decode(def_thresh, def_budget, precision=None, bucket=None):
            _apply_staged()
            prec = precision or self.default_precision
            if bucket is not None:
                if self.cache is None:
                    raise ValueError(
                        f"replica {index} got bucket {bucket!r} but the "
                        "server has no registry/cache (single-model mode)")
                tenant, version = bucket
                pack = self.cache.device_pack(tenant, version, prec,
                                              index, device)
            elif packs:
                pack = packs[prec]
            else:
                raise ValueError(
                    "registry-mode server got a bucketless dispatch; "
                    "requests must carry Request.model (no built-in "
                    "default model was constructed)")
            hk = (prec, bucket)
            hop = hop_cache.get(hk)
            if hop is None:
                per_hop_pj, transfer_pj = self._hop_cost(prec, bucket, pack)
                hop = hop_cache[hk] = (
                    jax.device_put(jnp.float32(per_hop_pj), device),
                    jax.device_put(jnp.float32(transfer_pj), device))
            per_hop, transfer = hop
            ck = (def_thresh, def_budget)
            knobs = knob_cache.get(ck)
            if knobs is None:
                knobs = knob_cache[ck] = (
                    jax.device_put(jnp.float32(def_thresh), device),
                    jax.device_put(jnp.int32(def_budget), device))
            nxt, hops, energy, state["step"] = _serve_eval_packed(
                pack, state["x"], key, state["step"], state["thr"],
                state["bud"], knobs[0], knobs[1], per_hop, transfer,
                max_hops=pack.n_groves, backend=backend, block_b=block_b)
            return nxt, hops, energy

        decode.packed = True
        decode.admit = admit
        decode.admit_many = admit_many
        decode.retire = retire
        decode.retire_many = retire_many
        return decode

    def prefill(self, slot: int, prompt: np.ndarray) -> int:
        """Store the request's feature row in its slot's device buffer."""
        if self._span is None:
            raise ValueError("server not bound; construct the batcher "
                             "with its DeviceDispatcher first")
        row = np.asarray(prompt, np.float32).reshape(-1)
        if row.shape[0] != self.n_features:
            raise ValueError(
                f"request feature row has {row.shape[0]} features, "
                f"server expects {self.n_features}")
        self._buffers[slot // self._span][slot % self._span] = row
        return 1
