"""Conventional random-forest evaluation (the paper's RF baseline).

Per §3.2.1: "in the conventional RF the DTs return class predictions, which
are later put to a majority vote" — contrast with FoG's probability
averaging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.forest.tree import TensorForest, forest_votes, forest_proba


@jax.jit
def rf_predict(forest: TensorForest, x: jax.Array) -> jax.Array:
    """Majority vote over per-tree hard predictions. [B] int32 labels."""
    return jnp.argmax(forest_votes(forest, x), axis=-1).astype(jnp.int32)


@jax.jit
def rf_predict_proba(forest: TensorForest, x: jax.Array) -> jax.Array:
    """Mean per-tree distribution (used by FoG groves). [B, C]."""
    return forest_proba(forest, x)
