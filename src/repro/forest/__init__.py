from repro.forest.tree import TensorForest, forest_proba, forest_votes, pad_forest
from repro.forest.pack import (PACK_FORMAT_VERSION, PRECISION_BYTES,
                               PRECISIONS, ForestPack)
from repro.forest.train import (TRAINERS, TrainConfig, bin_features,
                                quantile_bin_edges, train_random_forest)
from repro.forest.rf import rf_predict, rf_predict_proba

__all__ = [
    "TensorForest", "forest_proba", "forest_votes", "pad_forest",
    "ForestPack", "PRECISIONS", "PRECISION_BYTES", "PACK_FORMAT_VERSION",
    "TRAINERS", "TrainConfig", "train_random_forest", "quantile_bin_edges",
    "bin_features", "rf_predict", "rf_predict_proba",
]
