from repro.forest.tree import TensorForest, forest_proba, forest_votes, pad_forest
from repro.forest.train import TrainConfig, train_random_forest
from repro.forest.rf import rf_predict, rf_predict_proba

__all__ = [
    "TensorForest", "forest_proba", "forest_votes", "pad_forest",
    "TrainConfig", "train_random_forest", "rf_predict", "rf_predict_proba",
]
