"""Tensorized decision trees.

The paper's ASIC walks pointer trees with a per-node comparator; the
TPU-native equivalent is a *complete* binary tree of depth ``d`` flattened
into dense tensors, traversed with ``d`` gather-compare steps.  A forest of
``t`` trees is three arrays:

  feature   int32   [t, 2**d - 1]      feature index tested at each internal node
  threshold float32 [t, 2**d - 1]      split threshold (x[f] > thr -> right)
  leaf      float32 [t, 2**d, C]       per-leaf class distribution

Nodes below a "real" leaf are padded: feature = 0, threshold = +inf (always
go left) and the real leaf's distribution is replicated to every descendant
leaf slot, so the dense walk returns the same answer as the pointer walk.
Energy accounting matches the ASIC: ``d`` comparisons + ``d`` node reads per
tree per example (only *visited* nodes cost energy).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TensorForest:
    """A forest of ``t`` depth-``d`` complete binary trees over ``C`` classes."""

    feature: jax.Array    # int32 [t, 2**d - 1]
    threshold: jax.Array  # float32 [t, 2**d - 1]
    leaf: jax.Array       # float32 [t, 2**d, C]

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.feature, self.threshold, self.leaf), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- shape helpers ------------------------------------------------------
    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def depth(self) -> int:
        return int(np.log2(self.leaf.shape[1]) + 0.5)

    @property
    def n_classes(self) -> int:
        return self.leaf.shape[2]

    def slice_trees(self, start: int, count: int) -> "TensorForest":
        return TensorForest(
            self.feature[start : start + count],
            self.threshold[start : start + count],
            self.leaf[start : start + count],
        )

    def stack_groves(self, grove_size: int) -> "TensorForest":
        """Reshape [t, ...] -> [n_groves, k, ...] (Algorithm 1's Split)."""
        t = self.n_trees
        assert t % grove_size == 0, (t, grove_size)
        g = t // grove_size
        return TensorForest(
            self.feature.reshape(g, grove_size, -1),
            self.threshold.reshape(g, grove_size, -1),
            self.leaf.reshape(g, grove_size, self.leaf.shape[1], self.leaf.shape[2]),
        )


def traverse_one(feature: jax.Array, threshold: jax.Array, leaf: jax.Array,
                 x: jax.Array) -> jax.Array:
    """Walk one tree for one example.  Returns the leaf distribution [C].

    ``d`` iterations of: gather node, compare, descend.  This is the pure-jnp
    oracle for the Pallas ``tree_traverse`` kernel.
    """
    depth = int(np.log2(leaf.shape[0]) + 0.5)
    idx = jnp.zeros((), jnp.int32)
    for _ in range(depth):
        f = feature[idx]
        thr = threshold[idx]
        go_right = (x[f] > thr).astype(jnp.int32)
        idx = 2 * idx + 1 + go_right
    return leaf[idx - (leaf.shape[0] - 1)]


# [t,...] trees x [B,F] batch -> [B, t, C]
_traverse_tree_batch = jax.vmap(traverse_one, in_axes=(0, 0, 0, None))      # over trees
_traverse = jax.vmap(_traverse_tree_batch, in_axes=(None, None, None, 0))   # over batch


@partial(jax.jit, static_argnames=())
def forest_proba(forest: TensorForest, x: jax.Array) -> jax.Array:
    """Mean leaf distribution over trees: [B, C].  (sklearn predict_proba.)"""
    per_tree = _traverse(forest.feature, forest.threshold, forest.leaf, x)
    return per_tree.mean(axis=1)


@partial(jax.jit, static_argnames=())
def forest_votes(forest: TensorForest, x: jax.Array) -> jax.Array:
    """Per-tree hard votes -> one-hot counts [B, C] (conventional RF)."""
    per_tree = _traverse(forest.feature, forest.threshold, forest.leaf, x)
    votes = jnp.argmax(per_tree, axis=-1)                      # [B, t]
    return jax.nn.one_hot(votes, forest.n_classes).sum(axis=1)  # [B, C]


def pad_forest(forests: list[TensorForest]) -> TensorForest:
    """Stack single-tree forests (possibly different depths) to common depth."""
    max_depth = max(f.depth for f in forests)
    out = []
    for f in forests:
        while f.depth < max_depth:
            n_int, n_leaf = f.feature.shape[1], f.leaf.shape[1]
            # graft each leaf as a subtree root: new internal layer always goes left
            new_feature = jnp.concatenate(
                [f.feature, jnp.zeros((f.feature.shape[0], n_leaf), jnp.int32)], axis=1)
            new_threshold = jnp.concatenate(
                [f.threshold, jnp.full((f.threshold.shape[0], n_leaf), jnp.inf)], axis=1)
            # duplicate each leaf into (left, right) children; right unused (inf thr)
            new_leaf = jnp.repeat(f.leaf, 2, axis=1)
            f = TensorForest(new_feature, new_threshold, new_leaf)
        out.append(f)
    return TensorForest(
        jnp.concatenate([f.feature for f in out], axis=0),
        jnp.concatenate([f.threshold for f in out], axis=0),
        jnp.concatenate([f.leaf for f in out], axis=0),
    )
