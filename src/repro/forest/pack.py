"""ForestPack — the one dtype-aware packed representation of grove tables.

The paper's ASIC walks trees out of fixed-point SRAM: energy per
classification is dominated by the table bytes read per hop, and the whole
field of groves must fit the PE array's local memory.  This module gives the
reproduction the same lever.  A :class:`ForestPack` is the single canonical
packed form of a grove collection's node tables — dense head-stacked
``[O, G, k, ...]`` feature/threshold/leaf arrays with a *dtype spec*:

==========  ===============================================================
precision   table storage
==========  ===============================================================
``fp32``    float32 thresholds/leaves (bit-identical to the unpacked path)
``bf16``    bfloat16 thresholds/leaves, upcast to fp32 at compare time
``int8``    symmetric per-tree-scaled int8 (the ``optim/compression.py``
            scheme applied per tree) with fp32 scales; dequantized at load
            time inside each kernel — int8 SRAM/VMEM reads, fp32
            compare/accumulate
==========  ===============================================================

Every evaluation backend consumes a pack: the fused Pallas kernel pins the
packed arrays whole in VMEM (int8 fits ~4x the field of fp32), the per-hop
backends gather per-lane grove slices and dequantize in registers, and the
mesh ring shards the packed tables.  Derived layouts — the ring's
strided-reordered tables, the fused head-stacked view — are computed and
cached *inside* the pack, so every consumer of a given (layout, dtype) pair
shares one device copy.

Packs persist: :meth:`save` writes a versioned ``.npz`` artifact (plus an
arbitrary metadata dict for facade state) and :meth:`load` restores it,
which is how ``FogClassifier.save``/``load`` round-trip trained models.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.tree import _traverse

# the table dtype spec every layer shares (FogPolicy.precision's domain)
PRECISIONS = ("fp32", "bf16", "int8")

# bump when the .npz field layout changes; loaders reject newer artifacts
PACK_FORMAT_VERSION = 1

_TABLE_DTYPE = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
# threshold bytes per node entry, used by the energy model's byte accounting
PRECISION_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def _per_tree_scale(x: jax.Array, axes: tuple[int, ...],
                    qmax: int) -> jax.Array:
    """Symmetric per-tree int8 scale: amax over the tree's *finite* entries
    / qmax (``compress_int8``'s grid, one scale per tree instead of per
    tensor).  Non-finite entries are the complete-tree padding sentinels
    (threshold +inf = "always go left") and get their own int8 code."""
    finite = jnp.where(jnp.isfinite(x), jnp.abs(x), 0.0)
    amax = jnp.max(finite, axis=axes, keepdims=True) + 1e-12
    return (amax / qmax).astype(jnp.float32)


def _quantize_leaf(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def _quantize_thr(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Thresholds use the [-126, 126] grid; ±127 encode the ±inf padding
    sentinels so "always go left" nodes survive quantization exactly."""
    q = jnp.clip(jnp.round(x / scale), -126, 126)
    q = jnp.where(x == jnp.inf, 127, q)
    q = jnp.where(x == -jnp.inf, -127, q)
    return q.astype(jnp.int8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ForestPack:
    """Packed grove tables for ``O`` output heads x ``G`` groves x ``k`` trees.

    feature    int32            [O, G, k, 2**d - 1]
    threshold  fp32|bf16|int8   [O, G, k, 2**d - 1]
    leaf       fp32|bf16|int8   [O, G, k, 2**d, C]
    thr_scale  float32          [O, G, k, 1]       per-tree dequant scales
    leaf_scale float32          [O, G, k, 1, 1]    (ones unless ``int8``)
    """

    feature: jax.Array
    threshold: jax.Array
    leaf: jax.Array
    thr_scale: jax.Array
    leaf_scale: jax.Array
    precision: str = "fp32"
    # derived-layout cache: (name, n_shards) -> table tuple.  Not pytree
    # data — rebuilt lazily after any flatten/unflatten round trip.
    _layouts: dict = dataclasses.field(default_factory=dict, init=False,
                                       repr=False, compare=False)

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"pick from {PRECISIONS}")

    # -- pytree plumbing (precision is static metadata) -------------------
    def tree_flatten(self):
        return ((self.feature, self.threshold, self.leaf,
                 self.thr_scale, self.leaf_scale), self.precision)

    @classmethod
    def tree_unflatten(cls, precision, children):
        return cls(*children, precision=precision)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_groves(cls, gc, precision: str = "fp32") -> "ForestPack":
        """Pack a GroveCollection (or tuple of heads) at the given precision.

        ``fp32`` stores the training arrays verbatim, so evaluation through
        the pack is bit-identical to evaluating the groves directly.
        """
        gcs = tuple(gc) if isinstance(gc, (tuple, list)) else (gc,)
        g0 = gcs[0]
        for g in gcs[1:]:
            if (g.feature.shape != g0.feature.shape
                    or g.leaf.shape != g0.leaf.shape):
                raise ValueError(
                    "packed multi-output heads need identical table shapes "
                    f"(one [O, G, k, ...] stack); got leaf {g.leaf.shape} "
                    f"vs {g0.leaf.shape} — pad shallower heads to a common "
                    "depth first (forest.tree.pad_forest grafts leaves "
                    "without changing predictions)")
        feature = jnp.stack([g.feature.astype(jnp.int32) for g in gcs])
        thr = jnp.stack([g.threshold.astype(jnp.float32) for g in gcs])
        leaf = jnp.stack([g.leaf.astype(jnp.float32) for g in gcs])
        return cls._pack(feature, thr, leaf, precision)

    @classmethod
    def _pack(cls, feature, thr_f32, leaf_f32, precision: str) -> "ForestPack":
        if precision not in PRECISIONS:
            raise ValueError(f"unknown precision {precision!r}; "
                             f"pick from {PRECISIONS}")
        O, G, k = feature.shape[:3]
        ones_t = jnp.ones((O, G, k, 1), jnp.float32)
        ones_l = jnp.ones((O, G, k, 1, 1), jnp.float32)
        if precision == "int8":
            ts = _per_tree_scale(thr_f32, axes=(3,), qmax=126)
            ls = _per_tree_scale(leaf_f32, axes=(3, 4), qmax=127)
            return cls(feature, _quantize_thr(thr_f32, ts),
                       _quantize_leaf(leaf_f32, ls), ts, ls, precision)
        dt = _TABLE_DTYPE[precision]
        return cls(feature, thr_f32.astype(dt), leaf_f32.astype(dt),
                   ones_t, ones_l, precision)

    def astype(self, precision: str) -> "ForestPack":
        """Repack at another precision (from the dequantized fp32 values)."""
        if precision == self.precision:
            return self
        feat, thr, leaf = self.dequantize()
        return ForestPack._pack(feat, thr, leaf, precision)

    # -- shape & size accounting ------------------------------------------
    @property
    def n_heads(self) -> int:
        return self.feature.shape[0]

    @property
    def n_groves(self) -> int:
        return self.feature.shape[1]

    @property
    def grove_size(self) -> int:
        return self.feature.shape[2]

    @property
    def n_leaves(self) -> int:
        return self.leaf.shape[3]

    @property
    def n_classes(self) -> int:
        return self.leaf.shape[4]

    @property
    def depth(self) -> int:
        return int(np.log2(self.n_leaves) + 0.5)

    @property
    def table_bytes(self) -> int:
        """Total packed bytes an accelerator must hold resident (feature +
        threshold + leaf + dequant scales) — the fused kernel's VMEM load."""
        return int(self.feature.nbytes + self.threshold.nbytes
                   + self.leaf.nbytes + self.thr_scale.nbytes
                   + self.leaf_scale.nbytes)

    # -- dequantization ----------------------------------------------------
    def dequantize(self):
        """(feature, threshold fp32, leaf fp32) — the exact values every
        backend compares/accumulates (int8 -> q * scale; bf16 -> upcast)."""
        from repro.kernels.ref import dequantize_tables
        thr, leaf = dequantize_tables(self.threshold, self.leaf,
                                      self.thr_scale, self.leaf_scale)
        return self.feature, thr, leaf

    def to_groves(self) -> tuple:
        """Dequantized per-head GroveCollections (fp32 evaluation views)."""
        from repro.core.grove import GroveCollection
        feat, thr, leaf = self.dequantize()
        return tuple(GroveCollection(feat[o], thr[o], leaf[o])
                     for o in range(self.n_heads))

    # -- derived layouts (cached) -----------------------------------------
    def layout(self, name: str, n_shards: int = 1):
        """Table tuple for one evaluation layout, computed once per pack.

        ``"fused"``  head-stacked ``[O, G, ...]`` tables + scales — the
                     canonical storage, served as-is.
        ``"ring"``   head-0 tables strided-reordered for ``n_shards`` ring
                     shards (shard s hosts groves ``{s, s+n, ...}``),
                     scales reordered alongside.
        """
        key = (name, n_shards)
        if key in self._layouts:
            return self._layouts[key]
        if name == "fused":
            tables = (self.feature, self.threshold, self.leaf,
                      self.thr_scale, self.leaf_scale)
        elif name == "ring":
            if self.n_heads != 1:
                raise NotImplementedError("ring layout is single-output")
            from repro.core.fog_ring import _grove_order
            order = _grove_order(self.n_groves, n_shards)
            tables = (self.feature[0][order], self.threshold[0][order],
                      self.leaf[0][order], self.thr_scale[0][order],
                      self.leaf_scale[0][order])
        else:
            raise ValueError(f"unknown layout {name!r}; "
                             "pick 'fused' or 'ring'")
        self._layouts[key] = tables
        return tables

    # -- per-lane gathered evaluation (reference / pallas contributions) ---
    def predict_proba(self, head: int, g_idx: jax.Array,
                      x: jax.Array) -> jax.Array:
        """Grove(g_idx[b]).predict_prob(x[b]) against packed tables.

        Gathers each lane's grove slice (packed loads), dequantizes the
        gathered values to fp32, then runs the bundle walk — the packed
        equivalent of :func:`repro.core.grove.grove_predict_proba`, and
        bit-identical to it for an fp32 pack.
        """
        from repro.kernels.ref import dequantize_tables
        feat = self.feature[head][g_idx]          # [B, k, nodes]
        thr, leaf = dequantize_tables(
            self.threshold[head][g_idx], self.leaf[head][g_idx],
            self.thr_scale[head][g_idx], self.leaf_scale[head][g_idx])

        def one(feat_b, thr_b, leaf_b, x_b):
            per_tree = _traverse(feat_b, thr_b, leaf_b, x_b[None])  # [1,k,C]
            return per_tree[0].mean(axis=0)

        return jax.vmap(one)(feat, thr, leaf, x)

    # -- persistence -------------------------------------------------------
    def save(self, path, extra: dict | None = None) -> Path:
        """Write a versioned ``.npz`` model artifact.

        bf16 tables are stored as raw uint16 bits (npz has no bfloat16);
        ``extra`` is an arbitrary JSON-serializable dict for facade state
        (hyperparameters, class counts, ...), returned by ``load_with_meta``.
        """
        path = Path(path)
        thr, leaf = np.asarray(self.threshold), np.asarray(self.leaf)
        if self.precision == "bf16":
            thr, leaf = thr.view(np.uint16), leaf.view(np.uint16)
        with open(path, "wb") as f:
            np.savez(
                f,
                format_version=np.int64(PACK_FORMAT_VERSION),
                precision=np.str_(self.precision),
                feature=np.asarray(self.feature),
                threshold=thr,
                leaf=leaf,
                thr_scale=np.asarray(self.thr_scale),
                leaf_scale=np.asarray(self.leaf_scale),
                extra_json=np.str_(json.dumps(extra or {})),
            )
        return path

    @classmethod
    def load(cls, path) -> "ForestPack":
        return cls.load_with_meta(path)[0]

    # every field a v1 artifact must carry; validated at load so a
    # truncated/foreign .npz fails with a schema error, not a raw KeyError
    _REQUIRED_FIELDS = ("precision", "feature", "threshold", "leaf",
                        "thr_scale", "leaf_scale", "extra_json")

    @classmethod
    def load_with_meta(cls, path) -> tuple["ForestPack", dict]:
        """(pack, extra-metadata dict) from a ``save`` artifact."""
        with np.load(Path(path), allow_pickle=False) as z:
            if "format_version" not in z:
                raise ValueError(
                    f"{path} is not a ForestPack artifact (missing "
                    "format_version; this build writes/reads format "
                    f"v{PACK_FORMAT_VERSION})")
            version = int(z["format_version"])
            if version > PACK_FORMAT_VERSION:
                raise ValueError(
                    f"{path} is ForestPack format v{version}; this build "
                    f"reads up to v{PACK_FORMAT_VERSION} — upgrade the code "
                    "or re-export the model")
            missing = [k for k in cls._REQUIRED_FIELDS if k not in z]
            if missing:
                raise ValueError(
                    f"{path} is a corrupt/truncated ForestPack v{version} "
                    f"artifact: missing fields {missing} (format "
                    f"v{PACK_FORMAT_VERSION} requires "
                    f"{list(cls._REQUIRED_FIELDS)})")
            precision = str(z["precision"])
            if precision not in PRECISIONS:
                raise ValueError(
                    f"{path}: artifact precision {precision!r} is not a "
                    f"supported table dtype (pick from {PRECISIONS})")
            thr, leaf = z["threshold"], z["leaf"]
            if precision == "bf16":
                thr = thr.view(jnp.bfloat16.dtype)
                leaf = leaf.view(jnp.bfloat16.dtype)
            pack = cls(jnp.asarray(z["feature"]), jnp.asarray(thr),
                       jnp.asarray(leaf), jnp.asarray(z["thr_scale"]),
                       jnp.asarray(z["leaf_scale"]), precision)
            extra = json.loads(str(z["extra_json"]))
        return pack, extra
