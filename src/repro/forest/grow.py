"""Device-resident forest trainer: level-wise histogram tree induction.

The numpy CART in :mod:`repro.forest.train` expands one node at a time; on
a forest it is a Python loop over trees x nodes.  This trainer inverts the
nesting the way accelerator tree inducers do (LightGBM-style level-wise
growth): grow ALL trees simultaneously, one level per step, with every
per-level quantity a dense tensor —

1. **Bin once.**  Features are quantile-binned against the SAME candidate
   grid the host trainer searches (:func:`~repro.forest.train.
   quantile_bin_edges` / :func:`~repro.forest.train.bin_features`), so a
   split decision here is the split ``x > edges[f, j]`` there, bit for bit.
2. **Histogram per level.**  A ``[T, N]`` node-id vector tracks where each
   sample sits in each tree; :func:`repro.kernels.histogram.
   histogram_level` turns (node ids, labels, bootstrap weights, bins) into
   per-(tree, node, feature, bin, class) fp32 counts — the Pallas one-hot
   kernel or the XLA scatter path, per the autotuned crossover.
3. **All splits in one pass.**  A cumsum over the bin axis yields every
   candidate's left/right class counts; gini gain (including the
   Nan/Wang/Saligrama ``feature_cost`` penalty against a per-path
   paid-feature mask) is computed for the whole ``[T, nodes, F, q]``
   candidate block, argmaxed per node with the host trainer's tie order
   (lowest feature id, then lowest threshold).
4. **Partition by gather.**  No data moves: routing is
   ``node = 2*node + (bin > chosen_j)`` per sample, a pair of gathers.

Bootstrap resampling is expressed as per-tree multiplicity weights
(``w[t, i]`` = times sample i was drawn for tree t), so weighted histogram
counts equal the host trainer's duplicated-row counts exactly.  All
randomness (bootstrap draws, ``max_features`` subsets) comes from
``jax.random`` keyed on ``cfg.seed`` — two same-seed runs produce
bit-identical ``TensorForest`` tables.

Conventions match the host trainer exactly: complete depth-``d`` trees in
heap order, non-splitting nodes sealed with ``feature=0, threshold=+inf``
("always go left"), sealed distributions replicated down to every leaf
below them, empty-node fallback ``1/C``.  The emitted ``TensorForest``
feeds ``ForestPack``/``ModelRegistry``/all four eval backends unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.train import (GAIN_EPS, TrainConfig, bin_features,
                                quantile_bin_edges, resolve_max_features)
from repro.forest.tree import TensorForest
from repro.kernels import autotune
from repro.kernels.histogram import histogram_level, onehot_rows


def _gini(counts: jax.Array, total: jax.Array) -> jax.Array:
    """Gini impurity from weighted class counts [..., C] and their sum."""
    t = jnp.maximum(total, 1.0)
    return 1.0 - jnp.sum(counts * counts, axis=-1) / (t * t)


@functools.partial(
    jax.jit,
    static_argnames=("n_trees", "depth", "n_classes", "msl", "k_feat",
                     "bootstrap", "cost_weight", "hc"))
def _grow(bins, edges, y, fcost, key, *, n_trees, depth, n_classes, msl,
          k_feat, bootstrap, cost_weight, hc):
    N, F = bins.shape
    q = edges.shape[1]
    n_bins = q + 1
    T = n_trees
    kb, kf = jax.random.split(key)

    if bootstrap:
        def draw(k):
            idx = jax.random.randint(k, (N,), 0, N)
            return jnp.zeros((N,), jnp.float32).at[idx].add(1.0)
        w = jax.vmap(draw)(jax.random.split(kb, T))
    else:
        w = jnp.ones((T, N), jnp.float32)

    wu = onehot_rows(bins, w, n_bins)            # level-invariant, built once
    node = jnp.zeros((T, N), jnp.int32)          # level-local node per sample
    alive = jnp.ones((T, 1), bool)               # node still growable
    inherit = jnp.full((T, 1, n_classes), 1.0 / n_classes, jnp.float32)
    paid = jnp.zeros((T, 1, F), bool)            # features paid on the path
    feats, thrs = [], []

    for level in range(depth):
        nodes = 1 << level
        hist = histogram_level(
            node, y, w, bins, n_nodes=nodes, n_bins=n_bins,
            n_classes=n_classes, matmul_max_r=hc.matmul_max_r,
            block_n=hc.block_n, block_r=hc.block_r, block_f=hc.block_f,
            wu=wu)
        # per-node class counts: any feature's bins partition the node
        counts = hist[:, :, 0, :, :].sum(axis=2)             # [T, nodes, C]
        total = counts.sum(-1)                               # [T, nodes]
        dist = jnp.where((alive & (total > 0))[..., None],
                         counts / jnp.maximum(total, 1.0)[..., None],
                         inherit)
        pure = (counts > 0).sum(-1) <= 1
        can_split = alive & (total >= 2 * msl) & ~pure

        # candidate j sends bin <= j left; cumsum gives left counts, and
        # right stats follow algebraically (sum-of-squares expansion keeps
        # every [T,nodes,F,q,C]-shaped tensor to the one cumsum + two
        # contractions instead of materializing the right counts too):
        #   n*gini = n - sum_c(count_c^2)/n
        #   sum_c(right_c^2) = sum_c(counts_c^2) - 2*sum_c(counts_c*left_c)
        #                      + sum_c(left_c^2)
        left = jnp.cumsum(hist, axis=3)[:, :, :, :q, :]  # [T,nodes,F,q,C]
        n_l = left.sum(-1)
        n_r = total[:, :, None, None] - n_l
        sq_l = jnp.einsum("tnfqc,tnfqc->tnfq", left, left)
        cross = jnp.einsum("tnfqc,tnc->tnfq", left, counts)
        sq_c = jnp.einsum("tnc,tnc->tn", counts, counts)
        sq_r = sq_c[:, :, None, None] - 2.0 * cross + sq_l
        parent_imp = _gini(counts, total)
        child = (n_l - sq_l / jnp.maximum(n_l, 1.0)
                 + n_r - sq_r / jnp.maximum(n_r, 1.0))
        gain = (parent_imp[:, :, None, None]
                - child / jnp.maximum(total, 1.0)[:, :, None, None])
        if fcost is not None and cost_weight:
            gain = gain - cost_weight * (fcost[None, None, :]
                                         * ~paid)[..., None]
        if k_feat < F:
            u = jax.random.uniform(jax.random.fold_in(kf, level),
                                   (T, nodes, F))
            _, idx = jax.lax.top_k(u, k_feat)
            fmask = (idx[..., None] == jnp.arange(F)).any(axis=-2)
        else:
            fmask = jnp.ones((T, nodes, F), bool)
        valid = (n_l >= msl) & (n_r >= msl) & fmask[..., None]
        gain = jnp.where(valid, gain, -jnp.inf)

        # first-max argmax over [F*q]: lowest feature id, then lowest
        # threshold — the host trainer's tie order
        flat = gain.reshape(T, nodes, F * q)
        bidx = jnp.argmax(flat, axis=-1)
        bgain = jnp.take_along_axis(flat, bidx[..., None], axis=-1)[..., 0]
        split_ok = can_split & (bgain > GAIN_EPS)
        f_best = (bidx // q).astype(jnp.int32)
        j_best = (bidx % q).astype(jnp.int32)
        feat_l = jnp.where(split_ok, f_best, 0)
        thr_l = jnp.where(split_ok, edges[f_best, j_best],
                          jnp.inf).astype(jnp.float32)
        feats.append(feat_l)
        thrs.append(thr_l)

        # route: right iff this sample's node split and its bin clears the
        # chosen edge index (bin > j  <=>  x > edges[f, j])
        sf = jnp.take_along_axis(feat_l, node, axis=1)       # [T, N]
        sj = jnp.take_along_axis(j_best, node, axis=1)
        sok = jnp.take_along_axis(split_ok, node, axis=1)
        xb = bins[jnp.arange(N)[None, :], sf]
        go_right = sok & (xb > sj)
        node = 2 * node + go_right.astype(jnp.int32)

        # children inherit path state; [m] -> [2m, 2m+1] via repeat
        newly = split_ok[..., None] & (jnp.arange(F) == feat_l[..., None])
        paid = jnp.repeat(paid | newly, 2, axis=1)
        alive = jnp.repeat(split_ok, 2, axis=1)
        inherit = jnp.repeat(dist, 2, axis=1)

    n_leaves = 1 << depth

    def leaf_counts(node_t, w_t):
        return jnp.zeros((n_leaves, n_classes),
                         jnp.float32).at[node_t, y].add(w_t)

    lc = jax.vmap(leaf_counts)(node, w)
    ltot = lc.sum(-1)
    leaf = jnp.where((alive & (ltot > 0))[..., None],
                     lc / jnp.maximum(ltot, 1.0)[..., None], inherit)
    feature = jnp.concatenate(feats, axis=1)     # heap order by level concat
    threshold = jnp.concatenate(thrs, axis=1)
    return feature, threshold, leaf


def grow_forest(x: np.ndarray, y: np.ndarray, n_classes: int,
                cfg: TrainConfig) -> TensorForest:
    """Train ``cfg.n_trees`` trees simultaneously on device.

    Same contract as the host path of
    :func:`repro.forest.train.train_random_forest`: complete
    depth-``cfg.max_depth`` trees over the shared quantile candidate grid,
    seed-deterministic (bit-identical tables across same-seed runs).  Tile
    sizes and the histogram path crossover come from
    :func:`repro.kernels.autotune.best_hist_config`.
    """
    if cfg.min_samples_leaf < 1:
        raise ValueError("device trainer requires min_samples_leaf >= 1 "
                         f"(got {cfg.min_samples_leaf}); padded +inf "
                         "candidates rely on empty right children being "
                         "invalid")
    if cfg.max_depth < 1:
        raise ValueError(f"max_depth must be >= 1 (got {cfg.max_depth})")
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.int32)
    n_features = x.shape[1]
    edges = quantile_bin_edges(x, cfg.n_thresholds)
    bins = bin_features(x, edges)
    k_feat = resolve_max_features(cfg.max_features, n_features)
    hc = autotune.best_hist_config(cfg.n_trees, cfg.max_depth, n_features,
                                   edges.shape[1] + 1, n_classes)
    use_cost = cfg.feature_cost is not None and bool(cfg.cost_weight)
    fcost = jnp.asarray(cfg.feature_cost, jnp.float32) if use_cost else None
    feature, threshold, leaf = _grow(
        jnp.asarray(bins, jnp.int32), jnp.asarray(edges),
        jnp.asarray(y), fcost, jax.random.key(cfg.seed),
        n_trees=cfg.n_trees, depth=cfg.max_depth, n_classes=n_classes,
        msl=int(cfg.min_samples_leaf), k_feat=k_feat,
        bootstrap=bool(cfg.bootstrap),
        cost_weight=float(cfg.cost_weight), hc=hc)
    return TensorForest(np.asarray(feature), np.asarray(threshold),
                        np.asarray(leaf))
