"""Random-forest training (CART, gini, bootstrap, feature subsampling).

The paper trains with scikit-learn + the feature-budgeted criterion of
Nan/Wang/Saligrama (ICML'15).  Offline container => we implement CART
ourselves in numpy (training is offline in the paper too; only *evaluation*
runs on the accelerator).  The budgeted criterion is the ``feature_cost``
option: split gain is penalized by the acquisition cost of features not yet
paid for on that root-to-node path, which is the essence of [11].
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.forest.tree import TensorForest, pad_forest


@dataclasses.dataclass
class TrainConfig:
    n_trees: int = 16
    max_depth: int = 8
    min_samples_leaf: int = 2
    n_thresholds: int = 16        # candidate thresholds per feature (quantiles)
    bootstrap: bool = True
    max_features: str | int = "sqrt"
    feature_cost: np.ndarray | None = None  # [F] acquisition cost (budgeted RF)
    cost_weight: float = 0.0                 # lambda in gain - lambda*cost
    seed: int = 0


def _gini(counts: np.ndarray) -> np.ndarray:
    """Gini impurity for count vectors [..., C]."""
    n = counts.sum(axis=-1, keepdims=True)
    n = np.maximum(n, 1)
    p = counts / n
    return 1.0 - (p * p).sum(axis=-1)


def _best_split(x: np.ndarray, y: np.ndarray, n_classes: int,
                feat_ids: np.ndarray, cfg: TrainConfig,
                paid: np.ndarray) -> tuple[int, float, float] | None:
    """Exhaustive split search over candidate quantile thresholds.

    Returns (feature, threshold, gain) or None if no split improves.
    """
    n = len(y)
    onehot = np.eye(n_classes, dtype=np.float64)[y]           # [n, C]
    parent_counts = onehot.sum(axis=0)
    parent_imp = _gini(parent_counts)

    best = None
    best_gain = 1e-12
    for f in feat_ids:
        col = x[:, f]
        qs = np.quantile(col, np.linspace(0.05, 0.95, cfg.n_thresholds))
        qs = np.unique(qs)
        if len(qs) == 0:
            continue
        # [n, q] mask of right-going examples
        right = col[:, None] > qs[None, :]
        right_counts = np.einsum("nq,nc->qc", right.astype(np.float64), onehot)
        left_counts = parent_counts[None, :] - right_counts
        n_r = right_counts.sum(axis=-1)
        n_l = n - n_r
        valid = (n_r >= cfg.min_samples_leaf) & (n_l >= cfg.min_samples_leaf)
        if not valid.any():
            continue
        child_imp = (n_l * _gini(left_counts) + n_r * _gini(right_counts)) / n
        gain = parent_imp - child_imp
        if cfg.feature_cost is not None and not paid[f]:
            gain = gain - cfg.cost_weight * cfg.feature_cost[f]
        gain = np.where(valid, gain, -np.inf)
        q_best = int(np.argmax(gain))
        if gain[q_best] > best_gain:
            best_gain = float(gain[q_best])
            best = (int(f), float(qs[q_best]), best_gain)
    return best


def _train_tree(x: np.ndarray, y: np.ndarray, n_classes: int,
                cfg: TrainConfig, rng: np.random.Generator) -> TensorForest:
    """Train one tree; emit it as a depth-``cfg.max_depth`` complete tree."""
    d = cfg.max_depth
    n_internal = 2**d - 1
    n_leaves = 2**d
    feature = np.zeros((n_internal,), np.int32)
    threshold = np.full((n_internal,), np.inf, np.float32)  # default: go left
    leaf = np.zeros((n_leaves, n_classes), np.float32)

    if cfg.max_features == "sqrt":
        k_feat = max(1, int(np.sqrt(x.shape[1])))
    elif cfg.max_features == "all":
        k_feat = x.shape[1]
    else:
        k_feat = int(cfg.max_features)

    def leaf_dist(idx: np.ndarray) -> np.ndarray:
        counts = np.bincount(y[idx], minlength=n_classes).astype(np.float32)
        s = counts.sum()
        return counts / s if s > 0 else np.full((n_classes,), 1.0 / n_classes, np.float32)

    def fill_leaves(node: int, depth: int, dist: np.ndarray) -> None:
        """Replicate ``dist`` across all leaf slots under ``node``."""
        first = node
        for _ in range(depth, d):
            first = 2 * first + 1
        first -= n_internal
        span = 2 ** (d - depth)
        leaf[first : first + span] = dist

    # iterative DFS: (node_id, depth, sample idx, paid-feature mask)
    stack = [(0, 0, np.arange(len(y)), np.zeros(x.shape[1], bool))]
    while stack:
        node, depth, idx, paid = stack.pop()
        ys = y[idx]
        if depth == d or len(idx) < 2 * cfg.min_samples_leaf or len(np.unique(ys)) == 1:
            dist = leaf_dist(idx)
            if depth == d:
                leaf[node - n_internal] = dist
            else:
                fill_leaves(node, depth, dist)
            continue
        feat_ids = rng.choice(x.shape[1], size=min(k_feat, x.shape[1]), replace=False)
        split = _best_split(x[idx], ys, n_classes, feat_ids, cfg, paid)
        if split is None:
            fill_leaves(node, depth, leaf_dist(idx))
            continue
        f, thr, _ = split
        feature[node] = f
        threshold[node] = thr
        go_right = x[idx, f] > thr
        paid2 = paid.copy()
        paid2[f] = True
        stack.append((2 * node + 1, depth + 1, idx[~go_right], paid2))
        stack.append((2 * node + 2, depth + 1, idx[go_right], paid2))

    return TensorForest(feature[None], threshold[None], leaf[None])


def train_random_forest(x: np.ndarray, y: np.ndarray, n_classes: int,
                        cfg: TrainConfig) -> TensorForest:
    """RandomForestTrain(n, X, y) — Algorithm 1 line 2."""
    rng = np.random.default_rng(cfg.seed)
    trees = []
    for _ in range(cfg.n_trees):
        if cfg.bootstrap:
            idx = rng.integers(0, len(y), size=len(y))
        else:
            idx = np.arange(len(y))
        trees.append(_train_tree(x[idx], y[idx], n_classes, cfg, rng))
    return pad_forest(trees)
