"""Random-forest training (CART, gini, bootstrap, feature subsampling).

The paper trains with scikit-learn + the feature-budgeted criterion of
Nan/Wang/Saligrama (ICML'15).  Offline container => we implement CART
ourselves (training is offline in the paper too; only *evaluation* runs on
the accelerator).  The budgeted criterion is the ``feature_cost`` option:
split gain is penalized by the acquisition cost of features not yet paid
for on that root-to-node path, which is the essence of [11].

Two trainers share one candidate-threshold contract:

``trainer="host"``    the numpy CART here: recursive node expansion, but
                      with the split search vectorized across the whole
                      ``[n, F_sub, q]`` (samples x subsampled features x
                      candidate thresholds) grid per node.
``trainer="device"``  :mod:`repro.forest.grow` — level-wise histogram tree
                      induction growing all trees simultaneously on the
                      accelerator (quantile-binned features, Pallas
                      histogram kernel, one vectorized gain pass per level).

Both search the SAME candidate grid: :func:`quantile_bin_edges` computes
per-feature global quantile thresholds ONCE per fit (deduplicated — a
low-cardinality column's repeated quantiles would otherwise produce
redundant candidate masks — and padded with ``+inf``, which no sample
exceeds, so padding candidates are never valid splits).  Ties in the gain
argmax break toward the lowest feature index, then the lowest threshold,
in both trainers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.forest.tree import TensorForest, pad_forest

TRAINERS = ("host", "device")

# a split must beat the parent impurity by more than this to be taken
GAIN_EPS = 1e-12


@dataclasses.dataclass
class TrainConfig:
    n_trees: int = 16
    max_depth: int = 8
    min_samples_leaf: int = 2
    n_thresholds: int = 16        # candidate thresholds per feature (quantiles)
    bootstrap: bool = True
    max_features: str | int = "sqrt"
    feature_cost: np.ndarray | None = None  # [F] acquisition cost (budgeted RF)
    cost_weight: float = 0.0                 # lambda in gain - lambda*cost
    seed: int = 0
    trainer: str = "host"         # "host" (numpy CART) | "device" (grow.py)


def resolve_max_features(max_features: str | int, n_features: int) -> int:
    """The per-node feature-subsample size k (shared by both trainers)."""
    if max_features == "sqrt":
        return max(1, int(np.sqrt(n_features)))
    if max_features == "all":
        return n_features
    return min(int(max_features), n_features)


def quantile_bin_edges(x: np.ndarray, n_thresholds: int) -> np.ndarray:
    """Per-feature candidate split thresholds, shared by both trainers.

    Returns float32 ``[F, q]``: the ``linspace(0.05, 0.95, q)`` quantiles
    of each column over the FULL training matrix (computed once per fit —
    the device trainer bins against these, and the host trainer searches
    the same grid), deduplicated per feature and right-padded with ``+inf``.
    Dedup happens AFTER the float32 cast so two float64 quantiles that
    collapse at storage precision count as one candidate; ``+inf`` pads are
    inactive by construction (``x > +inf`` is never true, so the right
    child is empty and ``min_samples_leaf >= 1`` invalidates the split).
    """
    x = np.asarray(x, np.float64)
    qs = np.quantile(x, np.linspace(0.05, 0.95, n_thresholds), axis=0)
    qs = qs.T.astype(np.float32)                       # [F, q]
    edges = np.full_like(qs, np.inf)
    for f in range(qs.shape[0]):
        u = np.unique(qs[f])
        u = u[np.isfinite(u)]
        edges[f, : len(u)] = u
    return edges


def bin_features(x: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Bin index per (sample, feature): ``bin = #edges strictly below x``.

    With edges sorted ascending, ``x > edges[f, j]  <=>  bin[x] > j`` — the
    device trainer's histogram cumsums recover every candidate split's
    left/right counts from these uint8 indices alone.
    """
    x = np.asarray(x, np.float32)
    bins = (x[:, :, None] > edges[None, :, :]).sum(axis=-1)
    return bins.astype(np.uint8)


def _gini(counts: np.ndarray) -> np.ndarray:
    """Gini impurity for count vectors [..., C]."""
    n = counts.sum(axis=-1, keepdims=True)
    n = np.maximum(n, 1)
    p = counts / n
    return 1.0 - (p * p).sum(axis=-1)


def _best_split(x: np.ndarray, y: np.ndarray, n_classes: int,
                feat_ids: np.ndarray, cfg: TrainConfig,
                paid: np.ndarray, edges: np.ndarray,
                ) -> tuple[int, float, float] | None:
    """Split search over the shared candidate grid, one vectorized pass.

    The historical per-feature Python loop is hoisted into a single
    ``[n, F_sub, q]`` batched gain computation (right-mask -> einsum counts
    -> gini gain for every (feature, threshold) candidate at once).
    Ties break toward the lowest feature index then lowest threshold
    (``feat_ids`` are sorted first — the subsample's draw order must not
    leak into the pick, or the device trainer could never match it).
    Returns (feature, threshold, gain) or None if no split improves.
    """
    n = len(y)
    onehot = np.eye(n_classes, dtype=np.float64)[y]           # [n, C]
    parent_counts = onehot.sum(axis=0)
    parent_imp = _gini(parent_counts)

    feat_ids = np.sort(np.asarray(feat_ids))
    e = edges[feat_ids]                                       # [Fs, q]
    right = x[:, feat_ids, None] > e[None, :, :]              # [n, Fs, q]
    right_counts = np.einsum("nfq,nc->fqc", right.astype(np.float64), onehot)
    left_counts = parent_counts[None, None, :] - right_counts
    n_r = right_counts.sum(axis=-1)
    n_l = n - n_r
    valid = (n_r >= cfg.min_samples_leaf) & (n_l >= cfg.min_samples_leaf)
    if not valid.any():
        return None
    child_imp = (n_l * _gini(left_counts) + n_r * _gini(right_counts)) / n
    gain = parent_imp - child_imp                             # [Fs, q]
    if cfg.feature_cost is not None and cfg.cost_weight:
        unpaid = ~paid[feat_ids]
        gain = gain - (cfg.cost_weight * cfg.feature_cost[feat_ids]
                       * unpaid)[:, None]
    gain = np.where(valid, gain, -np.inf)
    flat = int(np.argmax(gain))                # first max: lowest f, then q
    if gain.flat[flat] <= GAIN_EPS:
        return None
    f_loc, j = divmod(flat, edges.shape[1])
    return int(feat_ids[f_loc]), float(e[f_loc, j]), float(gain.flat[flat])


def _train_tree(x: np.ndarray, y: np.ndarray, n_classes: int,
                cfg: TrainConfig, rng: np.random.Generator,
                edges: np.ndarray) -> TensorForest:
    """Train one tree; emit it as a depth-``cfg.max_depth`` complete tree."""
    d = cfg.max_depth
    n_internal = 2**d - 1
    n_leaves = 2**d
    feature = np.zeros((n_internal,), np.int32)
    threshold = np.full((n_internal,), np.inf, np.float32)  # default: go left
    leaf = np.zeros((n_leaves, n_classes), np.float32)

    k_feat = resolve_max_features(cfg.max_features, x.shape[1])

    def leaf_dist(idx: np.ndarray) -> np.ndarray:
        counts = np.bincount(y[idx], minlength=n_classes).astype(np.float32)
        s = counts.sum()
        return counts / s if s > 0 else np.full((n_classes,), 1.0 / n_classes, np.float32)

    def fill_leaves(node: int, depth: int, dist: np.ndarray) -> None:
        """Replicate ``dist`` across all leaf slots under ``node``."""
        first = node
        for _ in range(depth, d):
            first = 2 * first + 1
        first -= n_internal
        span = 2 ** (d - depth)
        leaf[first : first + span] = dist

    # iterative DFS: (node_id, depth, sample idx, paid-feature mask)
    stack = [(0, 0, np.arange(len(y)), np.zeros(x.shape[1], bool))]
    while stack:
        node, depth, idx, paid = stack.pop()
        ys = y[idx]
        if depth == d or len(idx) < 2 * cfg.min_samples_leaf or len(np.unique(ys)) == 1:
            dist = leaf_dist(idx)
            if depth == d:
                leaf[node - n_internal] = dist
            else:
                fill_leaves(node, depth, dist)
            continue
        feat_ids = rng.choice(x.shape[1], size=min(k_feat, x.shape[1]), replace=False)
        split = _best_split(x[idx], ys, n_classes, feat_ids, cfg, paid, edges)
        if split is None:
            fill_leaves(node, depth, leaf_dist(idx))
            continue
        f, thr, _ = split
        feature[node] = f
        threshold[node] = thr
        go_right = x[idx, f] > thr
        paid2 = paid.copy()
        paid2[f] = True
        stack.append((2 * node + 1, depth + 1, idx[~go_right], paid2))
        stack.append((2 * node + 2, depth + 1, idx[go_right], paid2))

    return TensorForest(feature[None], threshold[None], leaf[None])


def train_random_forest(x: np.ndarray, y: np.ndarray, n_classes: int,
                        cfg: TrainConfig) -> TensorForest:
    """RandomForestTrain(n, X, y) — Algorithm 1 line 2.

    ``cfg.trainer`` selects the implementation: ``"host"`` is the numpy
    CART below; ``"device"`` dispatches to the level-wise histogram trainer
    (:func:`repro.forest.grow.grow_forest`) that grows every tree
    simultaneously on the accelerator.  Both emit the same complete-tree
    ``TensorForest`` padding/sentinel conventions.
    """
    if cfg.trainer not in TRAINERS:
        raise ValueError(f"unknown trainer {cfg.trainer!r}; "
                         f"pick from {TRAINERS}")
    if cfg.trainer == "device":
        from repro.forest.grow import grow_forest
        return grow_forest(x, y, n_classes, cfg)
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.int32)
    edges = quantile_bin_edges(x, cfg.n_thresholds)
    rng = np.random.default_rng(cfg.seed)
    trees = []
    for _ in range(cfg.n_trees):
        if cfg.bootstrap:
            idx = rng.integers(0, len(y), size=len(y))
        else:
            idx = np.arange(len(y))
        trees.append(_train_tree(x[idx], y[idx], n_classes, cfg, rng, edges))
    return pad_forest(trees)
