"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scaled quantization before the cross-pod all-reduce; the
quantization residual is carried in an error-feedback buffer so compression
bias doesn't accumulate (Seide et al. / EF-SGD).  Used on the ``pod`` axis
only — intra-pod ICI is fast, the pod-to-pod DCN hop is the thin pipe this
is worth 4x on.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressionState:
    error: Any   # pytree of residuals, same structure as grads


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_compression(grads) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads))


def ef_compress_grads(grads, state: CompressionState | None):
    """Quantize grads with error feedback.  Returns (dequantized_grads, state).

    The round trip models what crosses the wire: callers all-reduce the
    *dequantized* tensors (bitwise what the receiving pod reconstructs).
    """
    if state is None:
        state = init_compression(grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat = jax.tree.map(one, grads, state.error)
    out = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return out, CompressionState(error=err)
