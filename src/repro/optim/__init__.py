from repro.optim.optim import (
    OptState, adamw, sgd, clip_by_global_norm, cosine_schedule,
    linear_warmup_cosine, global_norm,
)
from repro.optim.compression import (
    CompressionState, compress_int8, decompress_int8, ef_compress_grads,
)

__all__ = [
    "OptState", "adamw", "sgd", "clip_by_global_norm", "cosine_schedule",
    "linear_warmup_cosine", "global_norm",
    "CompressionState", "compress_int8", "decompress_int8", "ef_compress_grads",
]
