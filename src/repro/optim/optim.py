"""Optimizers: AdamW + momentum SGD, global-norm clipping, LR schedules.

Self-contained (no optax in the container).  States are pytrees mirroring
the parameter tree, so they shard with the parameters under pjit (ZeRO-style
optimizer-state sharding falls out of the same in_shardings rules).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("step", "mu", "nu"), meta_fields=())
@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: Any      # first moment (or momentum buffer for sgd)
    nu: Any      # second moment (None-like zeros for sgd)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def lr(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype=jnp.float32):
    """Returns (init_fn, update_fn).  update: (grads, state, params) -> (new_params, new_state)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def update(grads, state: OptState, params):
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf
        lr_t = lr_fn(stepf)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * delta.astype(p.dtype)).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step=step, mu=new_mu, nu=new_nu)

    return init, update


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.9,
        weight_decay: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr_fn(step.astype(jnp.float32))

        def upd(g, m, p):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g32
            return (p - lr_t * m.astype(p.dtype)).astype(p.dtype), m

        flat = jax.tree.map(upd, grads, state.mu, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step=step, mu=new_mu, nu=state.nu)

    return init, update
