"""Deterministic synthetic token pipeline for LM training.

Seeded per (step, host) so (a) every restart reproduces the same batch
sequence (fault-tolerant resume), (b) each data shard sees distinct tokens.
A zipf-ish unigram mixture with short-range induction patterns gives the
loss curve actual structure to learn (repeated bigrams), unlike uniform
noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.train.fault import deterministic_data_key


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    induction_period: int = 64   # repeat window: makes in-context structure


def batch_at_step(cfg: DataConfig, step: int, *, host: int = 0,
                  n_hosts: int = 1) -> dict[str, np.ndarray]:
    """Batch for ``step``; host h draws rows [h*B/n, (h+1)*B/n)."""
    rng = np.random.default_rng(deterministic_data_key(cfg.seed, step) + host)
    b = cfg.global_batch // n_hosts
    # zipf unigram over the vocab
    ranks = np.arange(1, cfg.vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1), p=probs)
    # induction structure: second half of each window repeats the first
    P = cfg.induction_period
    for start in range(0, cfg.seq_len + 1 - P, P):
        half = P // 2
        toks[:, start + half : start + P] = toks[:, start : start + half]
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}
