from repro.data.synth import Dataset, DatasetSpec, SPECS, make_dataset, all_datasets

__all__ = ["Dataset", "DatasetSpec", "SPECS", "make_dataset", "all_datasets"]
