"""Synthetic twins of the paper's five UCI datasets.

Offline container => seeded Gaussian-mixture generators with the exact
(n_features, n_classes) signature of each UCI dataset and matched difficulty
(class-center spread vs noise tuned so simple linear models underperform
nonlinear ones, as in Table 1).  Generators are deterministic per seed.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_features: int
    n_classes: int
    n_train: int
    n_test: int
    # difficulty: cluster-center separation in units of noise sigma
    separation: float
    # fraction of features that are pure noise (no class signal)
    noise_features: float
    # clusters per class: >1 makes classes multimodal, so linear models
    # (SVM_lr) underperform RF/RBF/CNN as in Table 1
    clusters_per_class: int
    # intrinsic dimensionality of the class manifold: LOW, so the many
    # cluster centers are NOT in convex position and no linear partition
    # separates the interleaved classes (in high dim random clusters are
    # all extreme points of their hull and linear always wins)
    intrinsic_dim: int
    # test-label Bayes noise: caps attainable accuracy below 1.0
    label_noise: float
    # probability mass of each class's primary cluster: controls how much
    # of the class a LINEAR model can capture (paper's SVM_lr lands at
    # 67-86%), while local models also pick up the secondary clusters
    primary_weight: float = 0.72


# (F, C) signatures match UCI; sizes scaled to run everywhere fast.
SPECS = {
    "isolet": DatasetSpec("isolet", 617, 26, 4000, 1000, 5.6, 0.5, 3, 7, 0.03, 0.62),
    "penbased": DatasetSpec("penbased", 16, 10, 4000, 1000, 5.2, 0.0, 3, 6, 0.02, 0.72),
    "mnist": DatasetSpec("mnist", 784, 10, 4000, 1000, 5.4, 0.6, 3, 6, 0.02, 0.70),
    "letter": DatasetSpec("letter", 16, 26, 6000, 1500, 5.4, 0.0, 3, 7, 0.03, 0.66),
    "segmentation": DatasetSpec("segmentation", 19, 7, 2000, 500, 5.0, 0.1, 3, 6, 0.02, 0.60),
}


@dataclasses.dataclass(frozen=True)
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    @property
    def n_features(self) -> int:
        return self.x_train.shape[1]


def make_dataset(name: str, seed: int = 0) -> Dataset:
    spec = SPECS[name]
    # crc32, not hash(): str hashes are salted per process, which would
    # make "the same dataset" differ across runs and CI jobs
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**16))
    F, C = spec.n_features, spec.n_classes
    n_signal = max(2, int(F * (1.0 - spec.noise_features)))

    # multimodal classes: each class is a mixture of m well-separated
    # clusters whose centers are shared-permuted across classes, so no
    # linear projection separates the classes but local rules (trees,
    # RBF) do — reproducing Table 1's linear-vs-nonlinear accuracy gap
    m = spec.clusters_per_class
    D = spec.intrinsic_dim
    # a common pool of cluster centers in LOW-dim intrinsic space...
    pool = rng.normal(0.0, spec.separation, size=(C * m, D))
    # ...assigned to classes by a random permutation (interleaves classes
    # through space -> non-convex, linearly inseparable class regions)
    assignment = rng.permutation(C * m).reshape(C, m)
    # fixed random embedding of the intrinsic manifold into feature space
    embed = rng.normal(0.0, 1.0 / np.sqrt(D), size=(D, n_signal))

    comp_probs = np.full((m,), (1.0 - spec.primary_weight) / max(m - 1, 1))
    comp_probs[0] = spec.primary_weight if m > 1 else 1.0

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, C, size=n)
        comp = rng.choice(m, size=n, p=comp_probs)
        z = pool[assignment[y, comp]] + rng.normal(0.0, 1.0, size=(n, D))
        x_sig = z @ embed + rng.normal(0.0, 0.5, size=(n, n_signal))
        if spec.label_noise > 0:
            flip = rng.random(n) < spec.label_noise
            y = np.where(flip, rng.integers(0, C, size=n), y)
        if n_signal < F:
            x_noise = rng.normal(0.0, 1.0, size=(n, F - n_signal))
            x = np.concatenate([x_sig, x_noise], axis=1)
        else:
            x = x_sig
        # mix the columns so signal isn't axis-aligned-trivial
        return x.astype(np.float32), y.astype(np.int32)

    x_train, y_train = sample(spec.n_train)
    x_test, y_test = sample(spec.n_test)
    # standardize with train stats
    mu, sd = x_train.mean(0), x_train.std(0) + 1e-6
    x_train = (x_train - mu) / sd
    x_test = (x_test - mu) / sd
    return Dataset(name, x_train, y_train, x_test, y_test, C)


def all_datasets(seed: int = 0) -> dict[str, Dataset]:
    return {name: make_dataset(name, seed) for name in SPECS}
