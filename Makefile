PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all bench bench-registry quickstart

# tier-1 gate: fast default suite (slow marks + hypothesis sweeps excluded)
test:
	$(PY) -m pytest -x -q

# everything, including the >minutes integration paths and property sweeps
test-all:
	$(PY) -m pytest -q -m ""

# benchmark runner; the engine section writes BENCH_engine.json
bench:
	$(PY) -m benchmarks.run --quick

bench-full:
	$(PY) -m benchmarks.run

# multi-tenant registry serving bench; writes BENCH_registry.json
bench-registry:
	$(PY) -m benchmarks.registry_bench --smoke

quickstart:
	$(PY) examples/quickstart.py
