PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all bench bench-registry bench-serve bench-serve-profile \
	bench-train quickstart

# tier-1 gate: fast default suite (slow marks + hypothesis sweeps excluded)
test:
	$(PY) -m pytest -x -q

# everything, including the >minutes integration paths and property sweeps
test-all:
	$(PY) -m pytest -q -m ""

# benchmark runner; the engine section writes BENCH_engine.json
bench:
	$(PY) -m benchmarks.run --quick

bench-full:
	$(PY) -m benchmarks.run

# multi-tenant registry serving bench; writes BENCH_registry.json
bench-registry:
	$(PY) -m benchmarks.registry_bench --smoke

# closed-loop serving bench (virtual + wall clock); writes BENCH_serve.json
bench-serve:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		$(PY) -m benchmarks.serve_bench --smoke

# host vs device trainer sweep + train_gate; writes BENCH_train.json
bench-train:
	$(PY) -m benchmarks.train_bench

# per-step host/device breakdown of the packed hot loop.  --no-trace by
# default: jax.profiler.trace costs >100x per step on CPU hosts and would
# swamp the numbers; drop the flag to also write /tmp/serve-trace
bench-serve-profile:
	$(PY) -m benchmarks.serve_profile --devices 4 --steps 200 --no-trace

quickstart:
	$(PY) examples/quickstart.py
