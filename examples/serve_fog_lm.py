"""Serve a small LM with batched requests + FoG early-exit decoding.

    PYTHONPATH=src python examples/serve_fog_lm.py

Demonstrates the continuous-batching scheduler driving decode_step_fog
with MIXED-QOS traffic: every request carries its own FogPolicy (threshold
+ hop budget), the batcher assembles them into per-lane vectors, and one
compiled decode step serves the whole batch.  Per-request grove usage
(hops) is the LM analogue of the paper's energy meter — easy tokens exit
after 1 grove, hard tokens use the full stack, and budget-capped requests
never exceed their energy contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.policy import FogPolicy
from repro.data.lm_data import DataConfig, batch_at_step
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog, grove_boundaries
from repro.serve.scheduler import ContinuousBatcher, Request

cfg = smoke_config("tinyllama-1.1b").scaled(n_layers=4, fog_groups=4)
params = T.init_params(cfg, jax.random.key(0), jnp.float32)
# untrained demo weights -> tiny logit margins; 0.01 shows the per-token
# variation. A trained model exits much earlier (benchmarks/lm_fog_exit.py).
N_SLOTS, MAX_SEQ, THRESH = 4, 160, 0.01

caches = T.cache_init(cfg, N_SLOTS, MAX_SEQ, jnp.float32)


def prefill_fn(slot: int, prompt: np.ndarray) -> int:
    # per-slot prefill: run the prompt row, splice its cache into the batch
    _, c = T.prefill(params, cfg, tokens=jnp.asarray(prompt)[None, :],
                     max_seq=MAX_SEQ)
    def splice(batch_leaf, row_leaf):
        return batch_leaf.at[..., slot : slot + 1, :row_leaf.shape[-2], :] \
            .set(row_leaf[..., 0:1, :, :]) \
            if batch_leaf.ndim >= 3 else batch_leaf
    global caches
    caches = jax.tree.map(
        lambda b, r: _splice_cache(b, r, slot), caches, c)
    return len(prompt)


def _splice_cache(batch_leaf, row_leaf, slot):
    # leaves: [n_blocks, B, S, ...] (stack) or [B, S, ...] (prefix);
    # mamba states [.., B, H, P, N]; conv tails [.., B, K-1, C]
    b_axis = 1 if batch_leaf.ndim == row_leaf.ndim and \
        batch_leaf.shape[0] != row_leaf.shape[0] * 0 + batch_leaf.shape[0] else 0
    # find the axis where batch_leaf has N_SLOTS and row_leaf has 1
    for ax in range(batch_leaf.ndim):
        if batch_leaf.shape[ax] == N_SLOTS and row_leaf.shape[ax] == 1:
            sl = [slice(None)] * batch_leaf.ndim
            sl[ax] = slice(slot, slot + 1)
            # seq axis may be shorter in row_leaf (prefill length)
            for sax in range(batch_leaf.ndim):
                if sax != ax and row_leaf.shape[sax] != batch_leaf.shape[sax]:
                    sl[sax] = slice(0, row_leaf.shape[sax])
            return batch_leaf.at[tuple(sl)].set(row_leaf)
    return batch_leaf


def decode_fn(tokens, lengths, policy):
    global caches
    # the batch shares one position counter in this demo: use max length;
    # policy carries the per-lane thresholds/budgets the batcher assembled
    length = jnp.int32(int(lengths.max()))
    logits, caches, hops = decode_step_fog(params, cfg, tokens, caches,
                                           length, policy)
    return logits, hops


# three QoS tiers sharing ONE continuous batch: premium (hop until really
# confident), standard, and a budget tier capped at 2 groves per token
TIERS = {
    "premium": FogPolicy(threshold=0.05),
    "standard": FogPolicy(threshold=THRESH),
    "budget": FogPolicy(threshold=0.05, hop_budget=2),
}
batcher = ContinuousBatcher(N_SLOTS, decode_fn, prefill_fn, eos_id=-1,
                            default_policy=TIERS["standard"])
rng = np.random.default_rng(0)
dcfg = DataConfig(cfg.vocab_size, 32, 8, seed=7)
tier_of = {}
for rid in range(8):
    prompt = batch_at_step(dcfg, rid)["tokens"][0, :24]
    tier = list(TIERS)[rid % len(TIERS)]
    tier_of[rid] = tier
    batcher.submit(Request(rid=rid, prompt=prompt, max_new_tokens=16,
                           policy=TIERS[tier]))

done = batcher.run(max_steps=200)
n_groups = len(grove_boundaries(cfg))
print(f"served {len(done)} requests, {n_groups} groves, mixed QoS tiers")
for req in sorted(done, key=lambda r: r.rid):
    h = np.asarray(req.hops, np.float64)
    print(f"  req {req.rid} [{tier_of[req.rid]:>8}]: "
          f"{len(req.generated)} tokens, "
          f"mean groves/token {h.mean():.2f}  "
          f"(flops frac vs full stack: {h.mean() / n_groups:.2f})")
