"""End-to-end LM training driver (deliverable b: ~100M model, few hundred
steps) with checkpoints + crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a ~100M-param reduced tinyllama-family config on the host devices; the
identical code path scales to the production mesh via launch/train.py.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_arch
from repro.data.lm_data import DataConfig, batch_at_step
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

# ~100M params: tinyllama family, narrowed
cfg = dataclasses.replace(
    get_arch("tinyllama-1.1b"), name="tinyllama-100m",
    n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab_size=8192)
from repro.configs.base import param_count
print(f"model: {cfg.name} ({param_count(cfg)[0] / 1e6:.0f}M params)")

mesh = make_host_mesh()
with compat.set_mesh(mesh):
    step_fn, *_, init_opt = make_train_step(cfg, mesh, lr=3e-4,
                                            total_steps=args.steps,
                                            donate=False)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    opt_state = init_opt(params)
    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state),
                                                  args.ckpt_dir)
        print(f"resumed from step {start}")

    dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0)
    t0 = time.time()
    for step in range(start, args.steps):
        b = batch_at_step(dcfg, step)
        params, opt_state, m = step_fn(
            params, opt_state,
            {"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])})
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, (params, opt_state), args.ckpt_dir)
    print("training done; checkpoint in", args.ckpt_dir)
