"""The paper's micro-architecture on a device mesh: groves pinned to shards,
the req/ack handshake as a ppermute ring (README §Design mapping), driven
through the unified FogEngine.

Needs multiple devices; forces 8 host devices, so run it directly:

    PYTHONPATH=src python examples/fog_ring_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import FogEngine, FogPolicy, split  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.forest import TrainConfig, train_random_forest  # noqa: E402

ds = make_dataset("penbased")
rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                         TrainConfig(n_trees=16, max_depth=8))
gc = split(rf, 2)                       # 8 groves -> one per device
mesh = jax.make_mesh((8,), ("grove",))
print(f"mesh: {mesh}")

engine = FogEngine(gc, backend="ring", mesh=mesh)
x = jnp.asarray(ds.x_test[:512])
res = engine.eval(x, jax.random.key(0),
                  policy=FogPolicy(threshold=0.3, max_hops=8))
hops = np.asarray(res.hops)
print(f"accuracy          : {(np.asarray(res.label) == ds.y_test[:512]).mean():.3f}")
print(f"mean hops         : {hops.mean():.2f} of 8 groves")
print("ring occupancy    :", " ".join(
    f"hop{j}:{(hops > j).mean():.2f}" for j in range(8)))
print("Each hop is one collective_permute over one ICI link — the ASIC "
      "handshake, TPU-native.")
