"""Quickstart: train a random forest, split it into a Field of Groves,
classify with confidence-gated early exit through the unified FogEngine,
and read the energy meter.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FogEngine, FogPolicy, rf_report, split
from repro.data import make_dataset
from repro.forest import TrainConfig, rf_predict, train_random_forest
from repro.sklearn import FogClassifier

# 1. a dataset (synthetic twin of UCI Pen-based digits: 16 features, 10 classes)
ds = make_dataset("penbased")

# 2. conventional RF: 16 trees, depth 8 (Algorithm 1 line 2)
rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                         TrainConfig(n_trees=16, max_depth=8))
rf_acc = np.mean(np.asarray(rf_predict(rf, jnp.asarray(ds.x_test))) == ds.y_test)
rf_energy = rf_report(1, 16, 8, ds.n_classes).per_example_nj
print(f"conventional RF : acc={rf_acc:.3f}  energy={rf_energy:.2f} nJ/example")

# 3. split into a Field of Groves: 8 groves x 2 trees (Algorithm 1 Split)
gc = split(rf, 2)

# 4. one engine owns Algorithm 2; the hop update is a pluggable backend —
#    "reference" (pure jnp), "pallas" (fused hop-update kernel, one launch
#    per hop), "fused" (the ENTIRE early-exit loop in one VMEM-resident
#    Pallas launch — the paper's PE on a TPU), or "ring" (shard_map mesh;
#    see examples/fog_ring_demo.py).  All backends return identical labels
#    and hop counts.
engine = FogEngine(gc, backend="fused")

# 5. evaluate with Algorithm 2: random start grove, MaxDiff confidence,
#    hop to the next grove while confidence < threshold.  Every runtime
#    knob travels in a FogPolicy — the one contract shared by the engine,
#    the serving path, and the sklearn facade.
for thresh in [0.1, 0.3, 0.6, 1.1]:
    res = engine.eval(jnp.asarray(ds.x_test), jax.random.key(0),
                      policy=FogPolicy(threshold=thresh))
    acc = np.mean(np.asarray(res.label) == ds.y_test)
    hops = np.asarray(res.hops)
    e = res.energy_report()   # the EvalReport prices its own evaluation
    tag = " (== RF, every grove votes)" if thresh > 1 else ""
    print(f"FoG thresh={thresh:<4} acc={acc:.3f}  mean_hops={hops.mean():.2f}  "
          f"energy={e.per_example_nj:.2f} nJ/example{tag}")

# 6. per-lane policies: one batch, two QoS tiers — the first half classifies
#    cheaply, the second half buys full confidence
B = len(ds.y_test)
tiers = jnp.where(jnp.arange(B) < B // 2, 0.1, 0.6)
res = engine.eval(jnp.asarray(ds.x_test), jax.random.key(0),
                  policy=FogPolicy(threshold=tiers))
hops = np.asarray(res.hops)
print(f"mixed QoS batch  : mean_hops lo-tier={hops[:B//2].mean():.2f} "
      f"hi-tier={hops[B//2:].mean():.2f}")

# 7. or skip the plumbing entirely: the sklearn-style facade owns
#    train -> split -> engine, and meters energy as it classifies
clf = FogClassifier(n_trees=16, grove_size=2, max_depth=8).fit(
    ds.x_train, ds.y_train)
print(f"FogClassifier    : acc={clf.score(ds.x_test, ds.y_test):.3f}  "
      f"profile={clf.profile()['energy_nj_per_classification']:.2f} "
      f"nJ/classification at "
      f"{clf.profile()['mean_hops']:.2f} mean hops")

# 8. quantize + persist: int8 packed tables (the ASIC's fixed-point SRAM —
#    ~4x smaller, int8 reads, fp32 compares) and a versioned .npz artifact
#    that round-trips through save/load without retraining
clf.quantize("int8").reset_profile()
acc8 = clf.score(ds.x_test, ds.y_test)
nj8 = clf.profile()["energy_nj_per_classification"]
pack8 = clf.engine_.tables.pack("int8")
pack32 = clf.engine_.tables.pack("fp32")
print(f"int8 quantized   : acc={acc8:.3f}  profile={nj8:.2f} nJ  "
      f"tables {pack32.table_bytes // 1024} KiB -> "
      f"{pack8.table_bytes // 1024} KiB")
clf.save("/tmp/fog_quickstart.npz")
reloaded = FogClassifier.load("/tmp/fog_quickstart.npz")
same = np.array_equal(reloaded.predict(ds.x_test), clf.predict(ds.x_test))
print(f"save -> load     : precision={reloaded.precision}  "
      f"identical labels: {same}")

# 9. the energy budget as a control plane: calibrate the Pareto frontier
#    over (threshold x precision), pin the best policy under 2 nJ, and read
#    measured-vs-budget from the profile (Fig. 5's operating-point
#    selection as one call; the frontier persists through save/load, and
#    the profile accounting restarts at the pin)
clf.set_energy_budget(2.0, ds.x_test[:512], ds.y_test[:512])
acc_b = clf.score(ds.x_test, ds.y_test)
prof = clf.profile()
print(f"2 nJ budget      : acc={acc_b:.3f}  "
      f"measured={prof['energy_nj_per_classification']:.2f} nJ  "
      f"within_budget={prof['within_budget']}  "
      f"(pinned thr={clf.policy.threshold}, "
      f"precision={clf.policy.precision})")

print("\nThe run-time knobs: lower threshold -> fewer groves per input -> "
      "less energy, graceful accuracy decay (paper Fig. 5); int8 packs -> "
      "fewer SRAM bytes per hop and ~4x more field per VMEM byte.")
