"""Closed-loop load harness for the data-parallel serving plane.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.serve_bench --smoke

Drives the REAL serving stack — ForestReplicaServer replicas behind a
DeviceDispatcher behind a ContinuousBatcher, every dispatch a real fused-
kernel evaluation with real per-request hop/energy telemetry — under a
Poisson open-arrival workload with mixed QoS tiers, warmup + measurement
windows, and admission control, and emits ``BENCH_serve.json`` with one row
per (n_devices, precision, governor) config: throughput (req/s), p50/p99
latency, mean nJ/request, shed rate.

Concurrency accounting (the "virtual clock").  CI and this container run on
a single CPU core, so N virtual XLA host devices execute their dispatches
sequentially in wall time — wall-clock alone cannot show data-parallel
speedup anywhere except on real multi-core/multi-chip hardware.  Following
the profiling-and-modeling methodology the ISSUE cites (arXiv 1902.11119),
the harness therefore runs everything for real but *accounts* device
concurrency: a calibration phase measures each precision's per-dispatch
service time ``s`` sequentially, and each step's virtual duration is

    vstep = max(wall_step - sum_over_dispatches(s), 0) + max_over_devices(busy_d)

i.e. the measured non-overlappable time (Python scheduling, policy
assembly, harvest — everything that is NOT device compute) plus the
longest single device's compute, which is what a concurrent fleet would
wait for.  On one device ``max_d busy_d == sum s`` and the virtual clock
EQUALS wall time — single-device rows are the built-in sanity check (see
the ``wall_rps`` column).  Both clocks are reported; the gate reads the
virtual one.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# QoS mix: fraction of arrivals per tier.  "gold" buys accuracy with a
# HIGHER exit threshold — in FoG a higher MaxDiff gate means more groves
# vote (same compiled program, per-lane knob); "bulk" trades accuracy for
# energy with a lower threshold AND int8 tables (its own precision group);
# "contract" (governor rows only, carved out of "std") carries a hard
# per-request energy_budget_nj.
TIERS = (("std", 0.70), ("gold", 0.20), ("bulk", 0.10))
CONTRACT_FRAC = 0.20
BASE_THRESH = 0.7     # std tier / calibration
GOLD_THRESH = 1.0     # premium: nearly every grove votes
BULK_THRESH = 0.4     # bulk: exit early, and on int8 tables

SMOKE_GRID = [
    dict(n_devices=1, precision="fp32", governor=False),
    dict(n_devices=4, precision="fp32", governor=False),
    dict(n_devices=1, precision="int8", governor=False),
    dict(n_devices=4, precision="int8", governor=False),
    dict(n_devices=4, precision="fp32", governor=True),
]
FULL_GRID = [
    dict(n_devices=d, precision=p, governor=g)
    for p in ("fp32", "bf16", "int8")
    for d in (1, 4)
    for g in (False, True)
]


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


class _Plane:
    """One (n_devices,)-keyed serving plane, shared across the grid rows so
    each (span, precision) program compiles exactly once."""

    def __init__(self, gc, ds, n_devices, n_slots, precisions, backend,
                 seed=0):
        import numpy as np
        from repro.launch.mesh import serve_devices
        from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer

        self.ds = ds
        self.n_slots = n_slots
        self.server = ForestReplicaServer(
            gc, ds.x_test.shape[1], backend=backend, precisions=precisions,
            seed=seed)
        self.dispatcher = DeviceDispatcher(self.server.factory,
                                           serve_devices(n_devices))
        self.dispatcher.bind(n_slots)
        # real feature rows in every span buffer before calibration, so the
        # calibrated service times see real early-exit behavior
        for slot in range(n_slots):
            self.server.prefill(slot, ds.x_test[slot % len(ds.x_test)])
        self._warm_full_path(precisions, np)
        self.svc: dict[str, float] = {}
        self._calibrate(precisions, np, threshold=BASE_THRESH)

    def _warm_full_path(self, precisions, np):
        """Drain one throwaway batcher burst through the REAL step path
        (policy assembly, dispatch, harvest, completion bookkeeping) so the
        first timed capacity probe pays zero first-step costs."""
        from repro.core.policy import FogPolicy
        from repro.serve.scheduler import ContinuousBatcher, Request
        b = ContinuousBatcher(self.n_slots, None, self.server.prefill,
                              eos_id=-1,
                              default_policy=FogPolicy(threshold=BASE_THRESH),
                              dispatcher=self.dispatcher)
        alt = [FogPolicy(threshold=BULK_THRESH, precision=p)
               for p in precisions[1:]]
        for rid in range(2 * self.n_slots):
            pol = alt[rid % len(alt)] if alt and rid % 3 == 0 else None
            b.submit(Request(rid=rid,
                             prompt=self.ds.x_test[rid % len(self.ds.x_test)],
                             max_new_tokens=1, policy=pol))
        while b.active or b.queue:
            b.step()

    def _calibrate(self, precisions, np, threshold):
        """Sequential per-dispatch service time per precision: warm every
        device's program (compiles), then best-of-5 a single-device
        dispatch+harvest."""
        from repro.core.policy import FogPolicy
        tokens = np.zeros((self.n_slots,), np.int32)
        lengths = np.ones((self.n_slots,), np.int32)
        span = self.dispatcher.span
        all_lanes = list(range(0, self.n_slots, span))
        for prec in precisions:
            pol = FogPolicy(threshold=threshold, precision=prec)
            for _ in range(2):   # compile + warm every replica
                self.dispatcher.dispatch(tokens, lengths, pol, all_lanes)
                self.dispatcher.harvest(self.n_slots)
            best = float("inf")
            for _ in range(5):   # then time ONE device's span, sequentially
                t0 = time.perf_counter()
                self.dispatcher.dispatch(tokens, lengths, pol, [0])
                self.dispatcher.harvest(self.n_slots)
                best = min(best, time.perf_counter() - t0)
            self.svc[prec] = best


def _make_governor(plane, base_policy, budget_nj):
    from repro.serve.governor import EnergyGovernor, default_ladder
    model = plane.server.energy_model("fp32")
    ladder = default_ladder(base_policy, model, budget_nj)
    return EnergyGovernor(ladder, budget_nj, model=model, window=64,
                          patience=2)


def _run_row(plane, cfg, n_requests, warmup_frac, seed, arrival_factor):
    """One grid row: capacity probe, then the Poisson closed loop."""
    import numpy as np
    from repro.core.policy import FogPolicy
    from repro.serve.scheduler import ContinuousBatcher, Request

    ds = plane.ds
    n_slots = plane.n_slots
    row_prec = cfg["precision"]
    base = FogPolicy(threshold=BASE_THRESH, precision=row_prec)
    rng = np.random.default_rng(seed)

    def svc_of(pending):
        return plane.svc.get(pending.precision or row_prec,
                             plane.svc[row_prec])

    def new_batcher(governor=None, max_queue=None):
        return ContinuousBatcher(
            n_slots, None, plane.server.prefill, eos_id=-1,
            default_policy=base, governor=governor,
            dispatcher=plane.dispatcher, max_queue=max_queue,
            shed_policy="reject")

    def vclock_step(b):
        t0 = time.perf_counter()
        b.step()
        wall = time.perf_counter() - t0
        busy: dict[int, float] = {}
        total = 0.0
        for p in b.last_dispatches:
            s = svc_of(p)
            busy[p.device] = busy.get(p.device, 0.0) + s
            total += s
        vstep = max(wall - total, 0.0) + (max(busy.values()) if busy
                                          else wall)
        return vstep, wall

    # -- capacity probe: saturated burst, no arrivals process ------------
    cap_n = 4 * n_slots
    b = new_batcher()
    for rid in range(cap_n):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                         max_new_tokens=1))
    vtot = wtot = 0.0
    while len(b.completed) < cap_n:
        v, w = vclock_step(b)
        vtot += v
        wtot += w
    capacity_rps = cap_n / vtot
    arrival_rps = arrival_factor * capacity_rps

    # -- the measured closed loop ----------------------------------------
    governor = None
    energy_model = None
    budget_nj = None
    if cfg["governor"]:
        # price the capacity burst to size the SLO: slightly under the
        # measured mean forces the governor to actually govern
        model0 = plane.server.energy_model(row_prec)
        burst_hops = np.asarray([r.hops[0] for r in b.completed])
        mean_nj = float(np.asarray(model0.lane_pj(burst_hops)).mean()) * 1e-3
        budget_nj = 0.9 * mean_nj
        governor = _make_governor(plane, base, budget_nj)
        energy_model = governor.model  # fp32 base; re-priced per precision

    b = new_batcher(governor=governor, max_queue=n_slots)
    inter = rng.exponential(1.0 / arrival_rps, size=n_requests)
    arrivals = np.cumsum(inter)
    tiers = rng.choice([t for t, _ in TIERS], size=n_requests,
                       p=[f for _, f in TIERS])
    contract_mask = (cfg["governor"]
                     & (tiers == "std")
                     & (rng.random(n_requests) < CONTRACT_FRAC
                        / TIERS[0][1]))
    contract_budgets = {}

    def make_request(rid):
        tier = tiers[rid]
        kw = {}
        if contract_mask[rid]:
            nj = float(rng.choice([1.3, 2.0])) * budget_nj
            contract_budgets[rid] = nj
            kw["energy_budget_nj"] = nj
        elif tier == "gold":
            kw["policy"] = FogPolicy(threshold=GOLD_THRESH)
        elif tier == "bulk":
            kw["policy"] = FogPolicy(threshold=BULK_THRESH,
                                     precision="int8")
        return Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                       max_new_tokens=1, **kw)

    vnow = 0.0
    wall_total = 0.0
    next_rid = 0
    arrival_vtime = {}
    done_vtime = {}
    n_done_seen = 0
    warmup_n = int(warmup_frac * n_requests)
    v_measure_start = None
    w_measure_start = None
    shed_rids = set()
    guard = 0
    while len(b.completed) + len(b.shed_requests) < n_requests:
        guard += 1
        if guard > 500_000:
            raise RuntimeError("serve_bench closed loop did not drain")
        while next_rid < n_requests and arrivals[next_rid] <= vnow:
            rid = next_rid
            if rid == warmup_n:
                v_measure_start, w_measure_start = vnow, wall_total
            arrival_vtime[rid] = vnow
            if not b.submit(make_request(rid)):
                shed_rids.add(rid)
            next_rid += 1
        if b.active == 0 and not b.queue:
            if next_rid < n_requests:      # idle: jump to the next arrival
                vnow = max(vnow, float(arrivals[next_rid]))
                continue
            break
        v, w = vclock_step(b)
        vnow += v
        wall_total += w
        for r in b.completed[n_done_seen:]:
            done_vtime[r.rid] = vnow
        n_done_seen = len(b.completed)

    # -- metrics over the measurement window -----------------------------
    measured = [r for r in b.completed if r.rid >= warmup_n]
    lat_ms = [(done_vtime[r.rid] - arrival_vtime[r.rid]) * 1e3
              for r in measured]
    v_window = vnow - (v_measure_start if v_measure_start is not None
                       else 0.0)
    w_window = wall_total - (w_measure_start if w_measure_start is not None
                             else 0.0)
    offered_m = sum(1 for rid in range(warmup_n, n_requests))
    shed_m = sum(1 for rid in shed_rids if rid >= warmup_n)

    def price(req):
        prec = (req.policy.precision if req.policy is not None
                and req.policy.precision is not None else row_prec)
        model = (governor.model_for(prec) if governor is not None
                 else plane.server.energy_model(prec))
        return float(np.asarray(model.lane_pj(
            np.asarray(req.hops))).sum()) * 1e-3

    nj = [price(r) for r in measured]
    contracts_offered = [r for r in b.completed if r.rid in contract_budgets]
    contracts_held = [r for r in contracts_offered
                      if price(r) <= contract_budgets[r.rid] + 1e-9]

    row = dict(
        n_devices=cfg["n_devices"], precision=row_prec,
        governor=bool(cfg["governor"]), n_slots=n_slots,
        n_requests=n_requests, warmup_n=warmup_n,
        capacity_rps=round(capacity_rps, 1),
        arrival_rps=round(arrival_rps, 1),
        throughput_rps=round(len(measured) / max(v_window, 1e-9), 1),
        wall_rps=round(len(measured) / max(w_window, 1e-9), 1),
        p50_ms=round(_percentile(lat_ms, 50), 3),
        p99_ms=round(_percentile(lat_ms, 99), 3),
        mean_nj_per_req=round(float(np.mean(nj)) if nj else 0.0, 4),
        mean_hops=round(float(np.mean([r.hops[0] for r in measured]))
                        if measured else 0.0, 3),
        completed=len(measured), offered=offered_m, shed=shed_m,
        shed_rate=round(shed_m / max(1, offered_m), 4),
        svc_us={p: round(s * 1e6, 1) for p, s in plane.svc.items()},
        contracts=dict(offered=len(contracts_offered),
                       held=len(contracts_held)),
    )
    if governor is not None:
        row["governor_budget_nj"] = round(budget_nj, 4)
        row["governor_rung_final"] = governor.rung
        row["governor_transitions"] = len(governor.transitions)
        row["device_nj"] = {str(d): round(v, 4)
                            for d, v in sorted(governor.device_nj.items())}
    return row


def bench(smoke: bool, seed: int = 0) -> dict:
    import numpy as np  # noqa: F401 (ensures numpy before jax init)
    from benchmarks.common import forest_for
    from repro.core.grove import split
    from repro.data import make_dataset

    grid = SMOKE_GRID if smoke else FULL_GRID
    n_requests = 6144 if smoke else 12288
    # slots per step sized so per-dispatch device COMPUTE dominates the
    # fixed per-dispatch runtime cost (~0.3ms) even at span = n_slots/4:
    # the fused kernel's wall time is flat below ~256 lanes (XLA-CPU op
    # overhead), so smaller spans under-report the parallel fraction.  At
    # 1024 slots both the single-device (span 1024) and 4-device (span
    # 256) programs run in the ~4 us/lane scaling regime with the same
    # block_b
    n_slots = 1024
    precisions = (("fp32", "int8") if smoke
                  else ("fp32", "bf16", "int8"))

    ds = make_dataset("penbased")
    gc = split(forest_for("penbased"), 2)

    planes: dict[int, _Plane] = {}
    rows = []
    for cfg in grid:
        d = cfg["n_devices"]
        if d not in planes:
            planes[d] = _Plane(gc, ds, d, n_slots, precisions,
                               backend="fused", seed=seed)
        t0 = time.time()
        row = _run_row(planes[d], cfg, n_requests, warmup_frac=0.2,
                       seed=seed, arrival_factor=1.3)
        row["row_seconds"] = round(time.time() - t0, 1)
        print(f"[serve_bench] {row['n_devices']}dev {row['precision']} "
              f"gov={row['governor']}: {row['throughput_rps']} req/s "
              f"(wall {row['wall_rps']}), p50 {row['p50_ms']}ms "
              f"p99 {row['p99_ms']}ms, {row['mean_nj_per_req']} nJ/req, "
              f"shed {100 * row['shed_rate']:.1f}%", flush=True)
        rows.append(row)

    import jax
    return dict(
        dataset="penbased", topology="8x2", backend="fused",
        smoke=smoke, seed=seed,
        host_devices=len(jax.devices()),
        methodology=(
            "real dispatches on virtual XLA host devices; device "
            "concurrency accounted in virtual time: vstep = "
            "max(wall - sum(svc), 0) + max_device(busy); svc calibrated "
            "sequentially per precision; single-device rows have "
            "virtual == wall by construction"),
        rows=rows,
    )


# --------------------------------------------------------------------------
# gate
# --------------------------------------------------------------------------

def serve_gate(data: dict, min_speedup: float = 1.5) -> list[str]:
    """CI gate over BENCH_serve.json: multi-device virtual throughput must
    beat single-device by ``min_speedup`` per matched precision (governor
    off), every completed per-request energy contract must have held, and
    the overloaded closed loop must actually have shed."""
    fails = []
    rows = data.get("rows", [])
    if not rows:
        return ["no rows in BENCH_serve.json"]
    by = {(r["n_devices"], r["precision"], r["governor"]): r for r in rows}
    for r in rows:
        if r["governor"] or r["n_devices"] < 4:
            continue
        single = by.get((1, r["precision"], False))
        if single is None:
            continue
        ratio = r["throughput_rps"] / max(single["throughput_rps"], 1e-9)
        if ratio < min_speedup:
            fails.append(
                f"{r['precision']}: {r['n_devices']}-device throughput "
                f"{r['throughput_rps']} req/s is only {ratio:.2f}x the "
                f"single-device {single['throughput_rps']} req/s "
                f"(need >= {min_speedup}x)")
    for r in rows:
        c = r.get("contracts", {})
        if c.get("offered", 0) and c["held"] != c["offered"]:
            fails.append(
                f"{r['n_devices']}dev {r['precision']} gov={r['governor']}: "
                f"only {c['held']}/{c['offered']} energy contracts held")
        if r["governor"] and not c.get("offered", 0):
            fails.append(
                f"{r['n_devices']}dev {r['precision']}: governor row "
                "completed no contract requests (nothing verified)")
    if not any(r["shed"] > 0 for r in rows):
        fails.append("no row shed any request: the closed loop never "
                     "overloaded admission control (arrival_factor bug?)")
    return fails


# --------------------------------------------------------------------------
# CLI + benchmarks.run integration
# --------------------------------------------------------------------------

def run(smoke: bool = True):
    """benchmarks.run section hook: subprocess so the forced host-device
    count cannot collide with the parent's already-initialized jax."""
    import subprocess
    repo = Path(__file__).resolve().parent.parent
    env = {
        "PYTHONPATH": str(repo / "src"),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
    }
    cmd = [sys.executable, "-m", "benchmarks.serve_bench"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                          text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"serve_bench failed:\n{proc.stdout}\n{proc.stderr}")
    yield from (ln for ln in proc.stdout.splitlines() if ln.strip())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + short windows (the CI tier-1 run)")
    ap.add_argument("--gate-only", action="store_true",
                    help="re-run the serve gate over an existing "
                         "BENCH_serve.json without re-benchmarking")
    ap.add_argument("--out", default=str(OUT_PATH))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.gate_only:
        data = json.loads(Path(args.out).read_text())
        fails = serve_gate(data)
        if fails:
            print("[serve_gate] FAIL:\n  " + "\n  ".join(fails))
            sys.exit(1)
        print("[serve_gate] ok")
        return

    # the forced host-device count must land before jax initializes; when
    # the caller (CI) already set XLA_FLAGS we leave it alone
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_"
                                     "count=4").strip()
    data = bench(smoke=args.smoke, seed=args.seed)
    Path(args.out).write_text(json.dumps(data, indent=1))
    print(f"[serve_bench] wrote {args.out} ({len(data['rows'])} rows)")
    fails = serve_gate(data)
    if fails:
        print("[serve_gate] FAIL:\n  " + "\n  ".join(fails))
        sys.exit(1)
    print("[serve_gate] ok")


if __name__ == "__main__":
    main()
