"""Closed-loop load harness for the data-parallel serving plane.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.serve_bench --smoke

Drives the REAL serving stack — ForestReplicaServer replicas behind a
DeviceDispatcher behind a ContinuousBatcher, every dispatch a real fused-
kernel evaluation with real per-request hop/energy telemetry — under a
Poisson open-arrival workload with mixed QoS tiers, warmup + measurement
windows, and admission control, and emits ``BENCH_serve.json`` with one row
per (n_devices, precision, governor) config: throughput (req/s), p50/p99
latency, mean nJ/request, shed rate.

The serving hot loop under test is the device-resident packed path: slot
feature rows and per-lane policy vectors live on the devices and are
updated by staged splices, each dispatch is ONE jitted program per device
returning packed ``(next, hops, energy)`` (argmax + pricing in-jit, no
logits download), and the batcher runs ``pipeline=True`` — step t's
dispatch is harvested at the start of step t+1 so host bookkeeping for
t+1 overlaps device compute of t.  Telemetry is buffered and replayed
every ``telemetry_every`` steps (exact under ``flush()``).

Concurrency accounting (the "virtual clock").  CI and this container run on
a single CPU core, so N virtual XLA host devices execute their dispatches
sequentially in wall time — wall-clock alone cannot show data-parallel
speedup anywhere except on real multi-core/multi-chip hardware.  Following
the profiling-and-modeling methodology the ISSUE cites (arXiv 1902.11119),
the harness therefore runs everything for real but *accounts* device
concurrency: a calibration phase measures each precision's per-dispatch
service time ``s`` sequentially, and each step's virtual duration is

    vstep = max(wall_step - sum_over_dispatches(s), 0) + max_over_devices(busy_d)

i.e. the measured non-overlappable time (Python scheduling, policy
assembly, harvest — everything that is NOT device compute) plus the
longest single device's compute, which is what a concurrent fleet would
wait for.  On one device ``max_d busy_d == sum s`` and the virtual clock
EQUALS wall time — single-device rows are the built-in sanity check (see
the ``wall_rps`` column).  Both clocks are reported; the virtual-speedup
gate reads the virtual one.

Wall-clock scaling gate.  Wall time additionally carries its own gate: the
``wall_baseline`` row serves the SAME per-device batch (``span`` lanes) on
one device that each of the 4-dev row's devices serves, so comparing their
``wall_rps`` asks "does adding devices at fixed per-device batch keep the
host out of the way?"  On this 1-core container device compute is
timeshared, so the honest expectation is ratio ~1.0x (the target on real
multi-core hardware is >= 1.5x); the gate enforces the >= 1.0x floor —
the pre-refactor host-bound loop scored 0.89x.  Ambient container load
swings single-shot wall measurements by up to 2x, so every row repeats
its measured window ``WALL_REPS`` times and reports the best (noise is
one-sided: interference only ever slows a run down).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# QoS mix: fraction of arrivals per tier.  "gold" buys accuracy with a
# HIGHER exit threshold — in FoG a higher MaxDiff gate means more groves
# vote (same compiled program, per-lane knob); "bulk" trades accuracy for
# energy with a lower threshold AND int8 tables (its own precision group);
# "contract" (governor rows only, carved out of "std") carries a hard
# per-request energy_budget_nj.
TIERS = (("std", 0.70), ("gold", 0.20), ("bulk", 0.10))
CONTRACT_FRAC = 0.20
BASE_THRESH = 0.7     # std tier / calibration
GOLD_THRESH = 1.0     # premium: nearly every grove votes
BULK_THRESH = 0.4     # bulk: exit early, and on int8 tables

SPAN = 256        # wall-baseline per-device batch (lanes per device)
TEL_EVERY = 8     # deferred-telemetry flush cadence (steps)
WALL_REPS = 3     # measured-window repeats; wall_rps = best of

SMOKE_GRID = [
    dict(n_devices=1, precision="fp32", governor=False),
    dict(n_devices=4, precision="fp32", governor=False),
    dict(n_devices=1, precision="int8", governor=False),
    dict(n_devices=4, precision="int8", governor=False),
    dict(n_devices=4, precision="fp32", governor=True),
]
FULL_GRID = [
    dict(n_devices=d, precision=p, governor=g)
    for p in ("fp32", "bf16", "int8")
    for d in (1, 4)
    for g in (False, True)
]
# span-matched single-device row for the wall-clock scaling gate: serves
# the same 256-lane per-device batch the 4-dev rows serve per device
WALL_BASELINE = dict(n_devices=1, precision="fp32", governor=False,
                     wall_baseline=True)


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


class _Plane:
    """One (n_devices, n_slots)-keyed serving plane, shared across the grid
    rows so each (span, precision) program compiles exactly once.  Built on
    the packed (device-resident) replica protocol; per-precision service
    times are calibrated LAZILY — a row pays only for the precisions its
    traffic mix can actually dispatch (``ensure_svc``)."""

    def __init__(self, gc, ds, n_devices, n_slots, precisions, backend,
                 seed=0):
        from repro.launch.mesh import serve_devices
        from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer

        self.ds = ds
        self.n_slots = n_slots
        self.precisions = tuple(precisions)
        self.server = ForestReplicaServer(
            gc, ds.x_test.shape[1], backend=backend, precisions=precisions,
            seed=seed)
        self.dispatcher = DeviceDispatcher(self.server.packed_factory,
                                           serve_devices(n_devices))
        self.dispatcher.bind(n_slots)
        self._warm_full_path(precisions)
        self.svc: dict[str, float] = {}

    def _warm_full_path(self, precisions):
        """Drain one throwaway batcher burst through the REAL pipelined
        step path (admit splices, per-precision dispatch, harvest, deferred
        telemetry flush) so the first timed capacity probe pays zero
        first-step costs — every replica's program compiles here."""
        from repro.core.policy import FogPolicy
        from repro.serve.scheduler import ContinuousBatcher, Request
        b = ContinuousBatcher(self.n_slots, None, self.server.prefill,
                              eos_id=-1,
                              default_policy=FogPolicy(threshold=BASE_THRESH,
                                                       precision=precisions[0]),
                              dispatcher=self.dispatcher,
                              pipeline=True, telemetry_every=TEL_EVERY)
        alt = [FogPolicy(threshold=BULK_THRESH, precision=p)
               for p in precisions[1:]]
        for rid in range(2 * self.n_slots):
            pol = alt[rid % len(alt)] if alt and rid % 3 == 0 else None
            b.submit(Request(rid=rid,
                             prompt=self.ds.x_test[rid % len(self.ds.x_test)],
                             max_new_tokens=1, policy=pol))
        while b.active or b.queue:
            b.step()
        b.flush()
        self._warm_splice_sizes(precisions[0])

    def _warm_splice_sizes(self, prec):
        """Compile every staged-splice program the real loop can hit.
        Admit/retire splices pad their lane index to the next power of
        two, and the saturated warm burst above only ever refills FULL
        spans — so the size-1, 2, 4, ... programs would otherwise compile
        lazily inside the measured window (tens of ms each, per device
        buffer shape)."""
        import numpy as np
        from repro.core.policy import NO_BUDGET
        span = self.dispatcher.span
        n_dev = self.dispatcher.n_devices
        rows = np.resize(self.ds.x_test.astype(np.float32),
                         (span, self.ds.x_test.shape[1]))
        all_lanes = np.arange(self.n_slots, dtype=np.int64)
        size = 1
        while size <= span:
            lanes = np.concatenate([d * span + np.arange(size)
                                    for d in range(n_dev)]).astype(np.int64)
            k = len(lanes)
            self.dispatcher.admit_lanes(
                lanes, np.resize(rows[:size], (k, rows.shape[1])),
                np.full((k,), BASE_THRESH, np.float32),
                np.full((k,), NO_BUDGET, np.int32))
            self.dispatcher.dispatch_packed(all_lanes, BASE_THRESH,
                                            NO_BUDGET, precision=prec)
            self.dispatcher.harvest_packed(self.n_slots)
            size *= 2
        # retire staging reuses the same per-size policy-splice programs
        self.dispatcher.retire_lanes(all_lanes)
        self.dispatcher.dispatch_packed(all_lanes, BASE_THRESH, NO_BUDGET,
                                        precision=prec)
        self.dispatcher.harvest_packed(self.n_slots)

    def ensure_svc(self, prec: str) -> None:
        """Calibrate one precision's sequential per-dispatch service time
        on demand: admit real feature rows onto device 0's span, warm the
        (already compiled) program, best-of-5 a single dispatch+harvest,
        then retire the lanes.  Splice application happens on the warmup
        dispatches, so the timed number is pure steady-state device
        compute — exactly what the virtual clock must not double-count."""
        if prec in self.svc:
            return
        import numpy as np
        from repro.core.policy import NO_BUDGET
        span = self.dispatcher.span
        lanes = np.arange(span, dtype=np.int64)
        rows = np.resize(self.ds.x_test.astype(np.float32),
                         (span, self.ds.x_test.shape[1]))
        self.dispatcher.admit_lanes(
            lanes, rows, np.full((span,), BASE_THRESH, np.float32),
            np.full((span,), NO_BUDGET, np.int32))
        for _ in range(2):
            self.dispatcher.dispatch_packed(lanes, BASE_THRESH, NO_BUDGET,
                                            precision=prec)
            self.dispatcher.harvest_packed(self.n_slots)
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            self.dispatcher.dispatch_packed(lanes, BASE_THRESH, NO_BUDGET,
                                            precision=prec)
            self.dispatcher.harvest_packed(self.n_slots)
            best = min(best, time.perf_counter() - t0)
        self.svc[prec] = best
        self.dispatcher.retire_lanes(lanes)


def _make_governor(plane, base_policy, budget_nj):
    from repro.serve.governor import EnergyGovernor, default_ladder
    model = plane.server.energy_model("fp32")
    ladder = default_ladder(base_policy, model, budget_nj)
    return EnergyGovernor(ladder, budget_nj, model=model, window=64,
                          patience=2)


def _run_row(plane, cfg, n_requests, warmup_frac, seed, arrival_factor):
    """One grid row: capacity probe, then the Poisson closed loop
    (repeated WALL_REPS times; metrics from the best-virtual repeat,
    wall_rps from the best wall repeat — ambient load only ever slows a
    repeat down)."""
    import numpy as np
    from repro.core.policy import FogPolicy
    from repro.serve.scheduler import ContinuousBatcher, Request

    ds = plane.ds
    n_slots = plane.n_slots
    row_prec = cfg["precision"]
    base = FogPolicy(threshold=BASE_THRESH, precision=row_prec)
    rng = np.random.default_rng(seed)

    # every precision this row's traffic mix can dispatch: its own base
    # precision, plus int8 (the bulk tier always rides along, and the
    # governor ladder's lower rungs drop to int8)
    needed = sorted({row_prec, "int8"})
    for p in needed:
        plane.ensure_svc(p)

    def svc_of(pending):
        return plane.svc.get(pending.precision or row_prec,
                             plane.svc[row_prec])

    def new_batcher(governor=None, max_queue=None):
        return ContinuousBatcher(
            n_slots, None, plane.server.prefill, eos_id=-1,
            default_policy=base, governor=governor,
            dispatcher=plane.dispatcher, max_queue=max_queue,
            shed_policy="reject", pipeline=True,
            telemetry_every=TEL_EVERY)

    def vclock_step(b):
        t0 = time.perf_counter()
        b.step()
        wall = time.perf_counter() - t0
        busy: dict[int, float] = {}
        total = 0.0
        # pipelined loop: last_dispatches is the set HARVESTED this step
        # (issued one step earlier) — every dispatch is credited exactly
        # once over the run, just one step late.  The very first step has
        # harvested nothing, so its device term is 0 (NOT wall — that
        # would double-count the host time already in the first term).
        for p in b.last_dispatches:
            s = svc_of(p)
            busy[p.device] = busy.get(p.device, 0.0) + s
            total += s
        vstep = max(wall - total, 0.0) + (max(busy.values()) if busy
                                          else 0.0)
        return vstep, wall

    # -- capacity probe: saturated burst, no arrivals process ------------
    cap_n = 4 * n_slots
    b = new_batcher()
    for rid in range(cap_n):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                         max_new_tokens=1))
    vtot = wtot = 0.0
    while len(b.completed) < cap_n:
        v, w = vclock_step(b)
        vtot += v
        wtot += w
    b.flush()
    capacity_rps = cap_n / vtot
    arrival_rps = arrival_factor * capacity_rps

    # -- closed-loop workload (shared across repeats) --------------------
    budget_nj = None
    if cfg["governor"]:
        # price the capacity burst to size the SLO: slightly under the
        # measured mean forces the governor to actually govern
        model0 = plane.server.energy_model(row_prec)
        burst_hops = np.asarray([r.hops[0] for r in b.completed])
        mean_nj = float(np.asarray(model0.lane_pj(burst_hops)).mean()) * 1e-3
        budget_nj = 0.9 * mean_nj
    inter = rng.exponential(1.0 / arrival_rps, size=n_requests)
    arrivals = np.cumsum(inter)
    tiers = rng.choice([t for t, _ in TIERS], size=n_requests,
                       p=[f for _, f in TIERS])
    contract_mask = (cfg["governor"]
                     & (tiers == "std")
                     & (rng.random(n_requests) < CONTRACT_FRAC
                        / TIERS[0][1]))
    contract_factor = rng.choice([1.3, 2.0], size=n_requests)
    warmup_n = int(warmup_frac * n_requests)

    def run_loop(governor):
        contract_budgets = {}

        def make_request(rid):
            tier = tiers[rid]
            kw = {}
            if contract_mask[rid]:
                nj = float(contract_factor[rid]) * budget_nj
                contract_budgets[rid] = nj
                kw["energy_budget_nj"] = nj
            elif tier == "gold":
                kw["policy"] = FogPolicy(threshold=GOLD_THRESH)
            elif tier == "bulk":
                kw["policy"] = FogPolicy(threshold=BULK_THRESH,
                                         precision="int8")
            return Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                           max_new_tokens=1, **kw)

        b = new_batcher(governor=governor, max_queue=n_slots)
        vnow = 0.0
        wall_total = 0.0
        next_rid = 0
        arrival_vtime = np.full((n_requests,), np.nan)
        done_vtime = np.full((n_requests,), np.nan)
        n_done_seen = 0
        v_measure_start = None
        w_measure_start = None
        shed_rids = set()
        guard = 0
        while len(b.completed) + len(b.shed_requests) < n_requests:
            guard += 1
            if guard > 500_000:
                raise RuntimeError("serve_bench closed loop did not drain")
            while next_rid < n_requests and arrivals[next_rid] <= vnow:
                rid = next_rid
                if rid == warmup_n:
                    v_measure_start, w_measure_start = vnow, wall_total
                arrival_vtime[rid] = vnow
                if not b.submit(make_request(rid)):
                    shed_rids.add(rid)
                next_rid += 1
            if b.active == 0 and not b.queue:
                if next_rid < n_requests:  # idle: jump to the next arrival
                    vnow = max(vnow, float(arrivals[next_rid]))
                    continue
                break
            v, w = vclock_step(b)
            vnow += v
            wall_total += w
            for r in b.completed[n_done_seen:]:
                done_vtime[r.rid] = vnow
            n_done_seen = len(b.completed)
        b.flush()
        return dict(
            b=b, governor=governor, vnow=vnow, wall_total=wall_total,
            arrival_vtime=arrival_vtime, done_vtime=done_vtime,
            shed_rids=shed_rids, contract_budgets=contract_budgets,
            v_measure_start=v_measure_start,
            w_measure_start=w_measure_start)

    reps = []
    for _ in range(WALL_REPS):
        governor = (_make_governor(plane, base, budget_nj)
                    if cfg["governor"] else None)
        reps.append(run_loop(governor))

    def w_window(rep):
        return rep["wall_total"] - (rep["w_measure_start"] or 0.0)

    def wall_rps_of(rep):
        done = sum(1 for r in rep["b"].completed if r.rid >= warmup_n)
        return done / max(w_window(rep), 1e-9)

    def v_rps_of(rep):
        done = sum(1 for r in rep["b"].completed if r.rid >= warmup_n)
        window = rep["vnow"] - (rep["v_measure_start"] or 0.0)
        return done / max(window, 1e-9)

    wall_runs = [wall_rps_of(rep) for rep in reps]
    v_runs = [v_rps_of(rep) for rep in reps]
    # metrics come from the best-virtual rep (and wall_rps is best-of-reps
    # below): the runner timeshares all virtual devices on one core and
    # ambient load swings any single window ~2x, so a fixed rep would gate
    # on scheduler noise, not on the serving plane
    r0 = reps[int(np.argmax(v_runs))]
    b, governor = r0["b"], r0["governor"]
    contract_budgets = r0["contract_budgets"]

    # -- metrics over the best rep's measurement window ------------------
    measured = [r for r in b.completed if r.rid >= warmup_n]
    lat_ms = [(r0["done_vtime"][r.rid] - r0["arrival_vtime"][r.rid]) * 1e3
              for r in measured]
    v_window = r0["vnow"] - (r0["v_measure_start"] or 0.0)
    offered_m = n_requests - warmup_n
    shed_m = sum(1 for rid in r0["shed_rids"] if rid >= warmup_n)

    def price(req):
        prec = (req.policy.precision if req.policy is not None
                and req.policy.precision is not None else row_prec)
        model = (governor.model_for(prec) if governor is not None
                 else plane.server.energy_model(prec))
        return float(np.asarray(model.lane_pj(
            np.asarray(req.hops))).sum()) * 1e-3

    nj = [price(r) for r in measured]
    contracts_offered = [r for r in b.completed if r.rid in contract_budgets]
    contracts_held = [r for r in contracts_offered
                      if price(r) <= contract_budgets[r.rid] + 1e-9]

    span = plane.dispatcher.span
    wall_rps = max(wall_runs)
    steps = max(b.n_steps, 1)
    row = dict(
        n_devices=cfg["n_devices"], precision=row_prec,
        governor=bool(cfg["governor"]), n_slots=n_slots, span=span,
        pipeline=True, telemetry_every=TEL_EVERY,
        n_requests=n_requests, warmup_n=warmup_n,
        capacity_rps=round(capacity_rps, 1),
        arrival_rps=round(arrival_rps, 1),
        throughput_rps=round(max(v_runs), 1),
        throughput_rps_runs=[round(x, 1) for x in v_runs],
        wall_rps=round(wall_rps, 1),
        wall_rps_runs=[round(x, 1) for x in wall_runs],
        wall_over_capacity=round(wall_rps / max(capacity_rps, 1e-9), 3),
        p50_ms=round(_percentile(lat_ms, 50), 3),
        p99_ms=round(_percentile(lat_ms, 99), 3),
        mean_nj_per_req=round(float(np.mean(nj)) if nj else 0.0, 4),
        mean_hops=round(float(np.mean([r.hops[0] for r in measured]))
                        if measured else 0.0, 3),
        completed=len(measured), offered=offered_m, shed=shed_m,
        shed_rate=round(shed_m / max(1, offered_m), 4),
        svc_us={p: round(plane.svc[p] * 1e6, 1) for p in needed},
        svc_measured=needed,
        host_phase_us_per_step={k: round(v / 1e3 / steps, 1)
                                for k, v in b.phase_ns.items()},
        contracts=dict(offered=len(contracts_offered),
                       held=len(contracts_held)),
    )
    if cfg.get("wall_baseline"):
        row["wall_baseline"] = True
    if governor is not None:
        row["governor_budget_nj"] = round(budget_nj, 4)
        row["governor_rung_final"] = governor.rung
        row["governor_transitions"] = len(governor.transitions)
        row["device_nj"] = {str(d): round(v, 4)
                            for d, v in sorted(governor.device_nj.items())}
    return row


def bench(smoke: bool, seed: int = 0) -> dict:
    import numpy as np  # noqa: F401 (ensures numpy before jax init)
    from benchmarks.common import forest_for
    from repro.core.grove import split
    from repro.data import make_dataset

    grid = list(SMOKE_GRID if smoke else FULL_GRID) + [dict(WALL_BASELINE)]
    n_requests = 6144 if smoke else 12288
    # fixed-slot rows: 1024 slots per step so per-dispatch device COMPUTE
    # dominates the fixed per-dispatch runtime cost even at span =
    # n_slots/4 — these carry the virtual-speedup gate.  The wall_baseline
    # row instead serves SPAN slots on one device (span-matched with the
    # 4-dev rows' per-device batch) and carries the wall-clock floor gate.
    n_slots_fixed = 1024
    precisions = (("fp32", "int8") if smoke
                  else ("fp32", "bf16", "int8"))

    ds = make_dataset("penbased")
    gc = split(forest_for("penbased"), 2)

    planes: dict[tuple, _Plane] = {}
    rows = []
    for cfg in grid:
        d = cfg["n_devices"]
        n_slots = (SPAN * d if cfg.get("wall_baseline") else n_slots_fixed)
        if (d, n_slots) not in planes:
            planes[d, n_slots] = _Plane(gc, ds, d, n_slots, precisions,
                                        backend="fused", seed=seed)
        t0 = time.time()
        row = _run_row(planes[d, n_slots], cfg, n_requests, warmup_frac=0.2,
                       seed=seed, arrival_factor=1.3)
        row["row_seconds"] = round(time.time() - t0, 1)
        tag = " [wall-baseline]" if cfg.get("wall_baseline") else ""
        print(f"[serve_bench] {row['n_devices']}dev {row['precision']} "
              f"gov={row['governor']}{tag}: {row['throughput_rps']} req/s "
              f"(wall {row['wall_rps']}), p50 {row['p50_ms']}ms "
              f"p99 {row['p99_ms']}ms, {row['mean_nj_per_req']} nJ/req, "
              f"shed {100 * row['shed_rate']:.1f}%", flush=True)
        rows.append(row)

    import jax
    return dict(
        dataset="penbased", topology="8x2", backend="fused",
        smoke=smoke, seed=seed,
        host_devices=len(jax.devices()),
        methodology=(
            "packed device-resident dispatch (argmax + energy pricing "
            "in-jit), pipelined batcher (harvest t-1 overlaps dispatch t), "
            "deferred telemetry flushed every "
            f"{TEL_EVERY} steps; device concurrency accounted in virtual "
            "time: vstep = max(wall - sum(svc), 0) + max_device(busy); "
            "svc calibrated lazily per served precision; single-device "
            "rows have virtual == wall by construction; wall_rps is the "
            f"best of {WALL_REPS} measured-window repeats (ambient load "
            "is one-sided noise); the wall_baseline row is span-matched "
            "to the 4-dev rows for the wall floor gate"),
        rows=rows,
    )


# --------------------------------------------------------------------------
# gate
# --------------------------------------------------------------------------

def serve_gate(data: dict, min_speedup: float = 1.5,
               wall_floor: float = 1.0,
               wall_target: float = 1.5) -> list[str]:
    """CI gate over BENCH_serve.json: multi-device virtual throughput must
    beat single-device by ``min_speedup`` per matched precision (governor
    off), 4-dev wall-clock throughput must not fall below the span-matched
    single-device baseline (``wall_floor``; ``wall_target`` is the real-
    hardware goal and is reported, not enforced, on this 1-core runner),
    every completed per-request energy contract must have held, and the
    overloaded closed loop must actually have shed."""
    fails = []
    rows = data.get("rows", [])
    if not rows:
        return ["no rows in BENCH_serve.json"]
    by = {(r["n_devices"], r["precision"], r["governor"],
           r.get("n_slots")): r for r in rows}
    for r in rows:
        if r["governor"] or r["n_devices"] < 4 or r.get("wall_baseline"):
            continue
        single = by.get((1, r["precision"], False, r.get("n_slots")))
        if single is None:
            continue
        ratio = r["throughput_rps"] / max(single["throughput_rps"], 1e-9)
        if ratio < min_speedup:
            fails.append(
                f"{r['precision']}: {r['n_devices']}-device throughput "
                f"{r['throughput_rps']} req/s is only {ratio:.2f}x the "
                f"single-device {single['throughput_rps']} req/s "
                f"(need >= {min_speedup}x)")
    # wall-clock floor: 4-dev wall_rps vs the span-matched 1-dev baseline
    baselines = [r for r in rows if r.get("wall_baseline")]
    if not baselines:
        fails.append("no wall_baseline row: the wall-clock scaling floor "
                     "was never measured")
    for base in baselines:
        four = next(
            (r for r in rows
             if r["n_devices"] == 4 and not r["governor"]
             and not r.get("wall_baseline")
             and r["precision"] == base["precision"]
             and r.get("span") == base.get("span")), None)
        if four is None:
            fails.append(
                f"wall_baseline {base['precision']} (span "
                f"{base.get('span')}) has no span-matched 4-device row")
            continue
        ratio = four["wall_rps"] / max(base["wall_rps"], 1e-9)
        if ratio < wall_floor:
            fails.append(
                f"{four['precision']}: 4-device wall throughput "
                f"{four['wall_rps']} req/s is {ratio:.2f}x the span-"
                f"matched 1-device {base['wall_rps']} req/s — below the "
                f"{wall_floor}x floor (multi-core target {wall_target}x)")
    for r in rows:
        c = r.get("contracts", {})
        if c.get("offered", 0) and c["held"] != c["offered"]:
            fails.append(
                f"{r['n_devices']}dev {r['precision']} gov={r['governor']}: "
                f"only {c['held']}/{c['offered']} energy contracts held")
        if r["governor"] and not c.get("offered", 0):
            fails.append(
                f"{r['n_devices']}dev {r['precision']}: governor row "
                "completed no contract requests (nothing verified)")
    if not any(r["shed"] > 0 for r in rows):
        fails.append("no row shed any request: the closed loop never "
                     "overloaded admission control (arrival_factor bug?)")
    return fails


def wall_summary(data: dict) -> list[str]:
    """Human-readable wall-scaling lines for the bench/gate output."""
    out = []
    rows = data.get("rows", [])
    for base in (r for r in rows if r.get("wall_baseline")):
        four = next(
            (r for r in rows
             if r["n_devices"] == 4 and not r["governor"]
             and not r.get("wall_baseline")
             and r["precision"] == base["precision"]
             and r.get("span") == base.get("span")), None)
        if four is None:
            continue
        ratio = four["wall_rps"] / max(base["wall_rps"], 1e-9)
        out.append(
            f"wall scaling ({base['precision']}, span {base['span']}): "
            f"4-dev {four['wall_rps']} / 1-dev {base['wall_rps']} req/s "
            f"= {ratio:.2f}x (floor 1.0x, multi-core target 1.5x)")
    return out


# --------------------------------------------------------------------------
# CLI + benchmarks.run integration
# --------------------------------------------------------------------------

def run(smoke: bool = True):
    """benchmarks.run section hook: subprocess so the forced host-device
    count cannot collide with the parent's already-initialized jax."""
    import subprocess
    repo = Path(__file__).resolve().parent.parent
    env = {
        "PYTHONPATH": str(repo / "src"),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
    }
    cmd = [sys.executable, "-m", "benchmarks.serve_bench"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                          text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"serve_bench failed:\n{proc.stdout}\n{proc.stderr}")
    yield from (ln for ln in proc.stdout.splitlines() if ln.strip())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + short windows (the CI tier-1 run)")
    ap.add_argument("--gate-only", action="store_true",
                    help="re-run the serve gate over an existing "
                         "BENCH_serve.json without re-benchmarking")
    ap.add_argument("--out", default=str(OUT_PATH))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.gate_only:
        data = json.loads(Path(args.out).read_text())
        for ln in wall_summary(data):
            print(f"[serve_gate] {ln}")
        fails = serve_gate(data)
        if fails:
            print("[serve_gate] FAIL:\n  " + "\n  ".join(fails))
            sys.exit(1)
        print("[serve_gate] ok")
        return

    # the forced host-device count must land before jax initializes; when
    # the caller (CI) already set XLA_FLAGS we leave it alone
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_"
                                     "count=4").strip()
    data = bench(smoke=args.smoke, seed=args.seed)
    Path(args.out).write_text(json.dumps(data, indent=1))
    print(f"[serve_bench] wrote {args.out} ({len(data['rows'])} rows)")
    for ln in wall_summary(data):
        print(f"[serve_bench] {ln}")
    fails = serve_gate(data)
    if fails:
        print("[serve_gate] FAIL:\n  " + "\n  ".join(fails))
        sys.exit(1)
    print("[serve_gate] ok")


if __name__ == "__main__":
    main()
