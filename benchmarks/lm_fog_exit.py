"""Beyond-paper: FoG layer-grove early exit on an LM (decode FLOPs/token).

Trains a reduced tinyllama-family model briefly on structured synthetic
data, then decodes with FoG exit at several thresholds, reporting mean
groves used and the modeled FLOPs/token saving — the LM analogue of the
paper's threshold/energy trade-off (Fig 5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.lm_data import DataConfig, batch_at_step
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog, grove_boundaries
from repro.optim import adamw


def run(arch: str = "tinyllama-1.1b", train_steps: int = 250) -> list[str]:
    cfg = smoke_config(arch)
    cfg = cfg.scaled(n_layers=4, fog_groups=4)   # 4 groves of 1 block
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    dcfg = DataConfig(cfg.vocab_size, 128, 8, seed=3)
    init, update = adamw(lr=5e-3)
    state = init(params)

    @jax.jit
    def step(params, state, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, tokens=tokens, labels=labels))(params)
        params, state = update(grads, state, params)
        return params, state, loss

    for i in range(train_steps):
        b = batch_at_step(dcfg, i)
        params, state, loss = step(params, state,
                                   jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))

    # decode with FoG exit; decode positions land in the second half of an
    # induction window (repeats of the first half) so a confident model can
    # exit early on them — the LM analogue of "easy inputs"
    B, S, new = 8, 96, 32
    b = batch_at_step(dcfg, 999)
    prompt = jnp.asarray(b["tokens"][:B, :S])
    rows = ["arch,thresh,mean_groves,exit_rate_g1,flops_frac,ppl_ratio"]
    n_groups = len(grove_boundaries(cfg))

    _, cache_full = T.prefill(params, cfg, tokens=prompt, max_seq=S + new)
    # full decode logits for quality reference
    full_logits = []
    cache = cache_full
    toks = prompt[:, -1]
    for t in range(new):
        lg, cache = T.decode_step(params, cfg, toks, cache, jnp.int32(S + t))
        full_logits.append(lg)
        toks = jnp.argmax(lg, -1).astype(jnp.int32)

    for thresh in [0.05, 0.1, 0.3, 0.6, 1.1]:
        cache = jax.tree.map(jnp.copy, cache_full)
        toks = prompt[:, -1]
        hops_all, agree = [], []
        for t in range(new):
            lg, cache, hops = decode_step_fog(params, cfg, toks, cache,
                                              jnp.int32(S + t), thresh)
            hops_all.append(np.asarray(hops))
            agree.append(np.mean(np.asarray(jnp.argmax(lg, -1)) ==
                                 np.asarray(jnp.argmax(full_logits[t], -1))))
            toks = jnp.argmax(lg, -1).astype(jnp.int32)
        hops_all = np.concatenate(hops_all)
        mean_g = hops_all.mean()
        rows.append(f"{cfg.name},{thresh},{mean_g:.2f},"
                    f"{(hops_all == 1).mean():.2f},{mean_g / n_groups:.2f},"
                    f"{np.mean(agree):.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
