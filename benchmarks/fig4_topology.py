"""Figure 4: accuracy + EDP as a function of FoG topology (groves x size)."""
from __future__ import annotations

from benchmarks.common import DATASETS, dataset, forest_for
from repro.core import FogPolicy, select_min_edp, topology_sweep


def run(datasets=("isolet", "penbased")) -> list[str]:
    rows = ["dataset,topology,threshold,accuracy,energy_nj,mean_hops,edp"]
    for name in datasets:
        ds = dataset(name)
        rf = forest_for(name)
        pts = topology_sweep(rf, ds.x_test, ds.y_test,
                             policy=FogPolicy(threshold=0.3))
        for p in pts:
            rows.append(f"{name},{p.n_groves}x{p.grove_size},{p.threshold},"
                        f"{p.accuracy:.4f},{p.energy_nj:.3f},{p.delay:.2f},"
                        f"{p.edp:.4f}")
        pick = select_min_edp(pts)
        rows.append(f"{name},SELECTED:{pick.n_groves}x{pick.grove_size},"
                    f"{pick.threshold},{pick.accuracy:.4f},{pick.energy_nj:.3f},"
                    f"{pick.delay:.2f},{pick.edp:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
