"""Per-step host/device profile of the packed serving hot loop.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.serve_profile --steps 200

Runs a saturated ContinuousBatcher burst on the device-resident packed
path (optionally pipelined) under ``jax.profiler.trace`` and prints a
per-step breakdown table:

- the batcher's own ``phase_ns`` accumulators (harvest / bookkeep /
  telemetry / refill / dispatch) — host time by phase, per step;
- the blocking device service time per dispatch, measured separately so
  host-vs-device attribution does not rely on wall subtraction (on a
  single-core runner device compute timeshares into whichever host phase
  runs concurrently, so phase walls alone overstate the host);
- aggregate throughput for the profiled window.

The XLA trace itself lands in ``--trace-dir`` (default
``/tmp/serve-trace``), viewable with TensorBoard's profile plugin or
Perfetto; pass ``--no-trace`` to skip it (the table never needs it).

This is ``make bench-serve-profile``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_devices(n: int) -> None:
    flag = f"--xla_force_host_platform_device_count={n}"
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " " + flag).strip()


def profile(n_devices: int, span: int, steps: int, telemetry_every: int,
            pipeline: bool, trace_dir: str | None, seed: int = 0) -> dict:
    import numpy as np
    import jax
    from benchmarks.common import forest_for
    from repro.core.grove import split
    from repro.core.policy import NO_BUDGET, FogPolicy
    from repro.data import make_dataset
    from repro.launch.mesh import serve_devices
    from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer
    from repro.serve.scheduler import ContinuousBatcher, Request

    ds = make_dataset("penbased")
    gc = split(forest_for("penbased"), 2)
    n_slots = span * n_devices

    server = ForestReplicaServer(gc, ds.x_test.shape[1], backend="fused",
                                 precisions=("fp32",), seed=seed)
    dispatcher = DeviceDispatcher(server.packed_factory,
                                  serve_devices(n_devices))
    dispatcher.bind(n_slots)

    def batcher():
        return ContinuousBatcher(
            n_slots, None, server.prefill, eos_id=-1,
            default_policy=FogPolicy(threshold=0.7, precision="fp32"),
            dispatcher=dispatcher, pipeline=pipeline,
            telemetry_every=telemetry_every)

    def saturate(b, n):
        for rid in range(n):
            b.submit(Request(rid=rid,
                             prompt=ds.x_test[rid % len(ds.x_test)],
                             max_new_tokens=1))

    # warm: compile the program, fault in every path once
    b = batcher()
    saturate(b, 2 * n_slots)
    while b.active or b.queue:
        b.step()
    b.flush()

    # blocking device service time, measured on its own (not by phase-wall
    # subtraction): one full-span dispatch + harvest per device
    lanes = np.arange(span, dtype=np.int64)
    rows = np.resize(ds.x_test.astype(np.float32),
                     (span, ds.x_test.shape[1]))
    dispatcher.admit_lanes(lanes, rows,
                           np.full((span,), 0.7, np.float32),
                           np.full((span,), NO_BUDGET, np.int32))
    for _ in range(2):
        dispatcher.dispatch_packed(lanes, 0.7, NO_BUDGET, precision="fp32")
        dispatcher.harvest_packed(n_slots)
    svc = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        dispatcher.dispatch_packed(lanes, 0.7, NO_BUDGET, precision="fp32")
        dispatcher.harvest_packed(n_slots)
        svc = min(svc, time.perf_counter() - t0)
    dispatcher.retire_lanes(lanes)

    # the profiled window: a fresh saturated batcher, `steps` real steps
    b = batcher()
    n_requests = (steps + 2) * n_slots
    saturate(b, n_requests)
    ctx = (jax.profiler.trace(trace_dir) if trace_dir is not None
           else _null_ctx())
    t0 = time.perf_counter()
    with ctx:
        for _ in range(steps):
            b.step()
    wall = time.perf_counter() - t0
    b.flush()

    done = len(b.completed)
    per_step = {k: v / 1e3 / max(b.n_steps, 1)
                for k, v in b.phase_ns.items()}
    return dict(
        n_devices=n_devices, span=span, n_slots=n_slots, steps=b.n_steps,
        pipeline=pipeline, telemetry_every=telemetry_every,
        wall_s=wall, completed=done, rps=done / wall,
        svc_us=svc * 1e6, phase_us_per_step=per_step,
        trace_dir=trace_dir)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def report(res: dict) -> None:
    phases = res["phase_us_per_step"]
    host_total = sum(phases.values())
    step_us = res["wall_s"] * 1e6 / max(res["steps"], 1)
    print(f"[serve_profile] {res['n_devices']} device(s), span "
          f"{res['span']} ({res['n_slots']} slots), {res['steps']} steps, "
          f"pipeline={res['pipeline']}, "
          f"telemetry_every={res['telemetry_every']}")
    print(f"[serve_profile] {res['rps']:.0f} req/s wall "
          f"({step_us:.0f} us/step); device svc "
          f"{res['svc_us']:.0f} us/dispatch (blocking, measured solo)")
    print(f"{'phase':<12} {'us/step':>9} {'% of step':>10}")
    for k in ("harvest", "refill", "dispatch", "bookkeep", "telemetry"):
        v = phases.get(k, 0.0)
        print(f"{k:<12} {v:>9.1f} {100 * v / max(step_us, 1e-9):>9.1f}%")
    print(f"{'(host sum)':<12} {host_total:>9.1f} "
          f"{100 * host_total / max(step_us, 1e-9):>9.1f}%")
    print("note: on a 1-core runner device compute timeshares into the "
          "host phases, so the phase walls overstate pure host time; the "
          "solo svc line is the device floor")
    if res["trace_dir"]:
        print(f"[serve_profile] XLA trace written to {res['trace_dir']} "
              "(TensorBoard profile plugin / Perfetto)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--span", type=int, default=256,
                    help="lanes per device (n_slots = span * devices)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--telemetry-every", type=int, default=8)
    ap.add_argument("--sync", action="store_true",
                    help="synchronous step (default is pipelined)")
    ap.add_argument("--trace-dir", default="/tmp/serve-trace")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip jax.profiler.trace (breakdown table only)")
    args = ap.parse_args()

    _force_devices(args.devices)
    res = profile(args.devices, args.span, args.steps,
                  args.telemetry_every, pipeline=not args.sync,
                  trace_dir=None if args.no_trace else args.trace_dir)
    report(res)


if __name__ == "__main__":
    main()
