"""Table 1 (bottom): modeled energy (nJ) per classification, 7 x 5.

Validates the paper's headline ratios at comparable accuracy:
FoG_opt vs RF ~1.5x, vs SVM_RBF ~24x, vs MLP ~2.5x, vs CNN ~34.7x lower;
vs SVM_LR ~6.5-10x HIGHER.
"""
from __future__ import annotations

import numpy as np

import benchmarks.common as common
from benchmarks.common import evaluate_all
from benchmarks.table1_accuracy import COLUMNS


def run() -> list[str]:
    rows = ["dataset," + ",".join(COLUMNS)]
    ratios = {c: [] for c in COLUMNS}
    for name in common.DATASETS:
        res = evaluate_all(name)
        rows.append(name + "," + ",".join(
            f"{res[c].energy_nj:.2f}" for c in COLUMNS))
        for c in COLUMNS:
            if res["fog_opt"].energy_nj > 0:
                ratios[c].append(res[c].energy_nj / res["fog_opt"].energy_nj)
    rows.append("geomean_ratio_vs_fog_opt," + ",".join(
        f"{np.exp(np.mean(np.log(np.maximum(ratios[c], 1e-9)))):.2f}"
        for c in COLUMNS))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
