"""FogEngine backend matrix benchmark -> CSV rows + BENCH_engine.json.

Times one full Algorithm-2 evaluation per backend on a fixed trained
forest/batch and records wall time, mean hops, and accuracy, so every
future PR has a perf trajectory for the unified hot path.  Backends:

  reference        pure-jnp scan (the oracle)
  reference-lazy   early-exit while_loop
  pallas           fused hop-update kernel (interpreted on CPU, Mosaic on TPU)
  pallas-chunked   same, batch evaluated in chunk_b slices (VMEM-bounded)
  fused            ENTIRE Algorithm-2 loop in ONE Pallas launch (all grove
                   tables VMEM-pinned, early-exit while_loop in-kernel)
  fused-chunked    same, one launch per chunk_b slice

The record's ``kernel_launches`` field is the analytic per-eval Pallas
dispatch count: the per-hop pallas backend pays one ``grove_aggregate``
launch per hop (``max_hops`` worst case, with the [B, C] state making an
HBM round trip each time); the fused backend pays exactly ONE launch (one
per chunk when chunked) — the paper's keep-the-walk-on-chip story.

The ring backend is timed separately in fog_ring_bench (needs forced
multi-device XLA in a subprocess).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_engine.json"


def _time_engine(engine, x, key, policy, reps=3):
    res = engine.eval(x, key, policy=policy)   # compile + warm
    res.proba.block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = engine.eval(x, key, policy=policy)
        res.proba.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, res


def run(out_path: Path | str | None = OUT_PATH) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import FogEngine, FogPolicy, split
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=1))
    gc = split(rf, 2)
    x = jnp.asarray(ds.x_test)
    key = jax.random.key(0)
    thresh = 0.3
    policy = FogPolicy(threshold=thresh, max_hops=gc.n_groves)

    engines = {
        "reference": FogEngine(gc),
        "reference-lazy": FogEngine(gc, lazy=True),
        "pallas": FogEngine(gc, backend="pallas"),
        "pallas-chunked": FogEngine(gc, backend="pallas", chunk_b=256),
        "fused": FogEngine(gc, backend="fused"),
        "fused-chunked": FogEngine(gc, backend="fused", chunk_b=256),
    }
    B = int(x.shape[0])
    n_chunks = -(-B // 256)
    # analytic Pallas dispatches per evaluation (worst case, lazy aside)
    launches = {
        "reference": 0, "reference-lazy": 0,
        "pallas": gc.n_groves, "pallas-chunked": gc.n_groves * n_chunks,
        "fused": 1, "fused-chunked": n_chunks,
    }
    rows, record = [], {"bench": "engine_backends", "B": B,
                        "n_groves": gc.n_groves, "thresh": thresh,
                        "backend_us": {}, "mean_hops": {}, "acc": {},
                        "kernel_launches": launches}
    base_hops = None
    for name, eng in engines.items():
        dt, res = _time_engine(eng, x, key, policy)
        hops = np.asarray(res.hops)
        acc = float((np.asarray(res.label) == ds.y_test).mean())
        if base_hops is None:
            base_hops = hops
        else:
            # all backends must preserve the hop-count energy accounting
            assert (hops == base_hops).all(), f"{name} diverged on hops"
        record["backend_us"][name] = round(dt * 1e6)
        record["mean_hops"][name] = float(hops.mean())
        record["acc"][name] = acc
        rows.append(f"CSV,engine,backend={name},us={dt * 1e6:.0f},"
                    f"acc={acc:.4f},mean_hops={hops.mean():.2f},"
                    f"launches={launches[name]}")
    if out_path is not None:
        Path(out_path).write_text(json.dumps(record, indent=2) + "\n")
        rows.append(f"CSV,engine,wrote={out_path}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
