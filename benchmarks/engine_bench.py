"""FogEngine backend matrix benchmark -> CSV rows + BENCH_engine.json.

Times one full Algorithm-2 evaluation per backend on a fixed trained
forest/batch and records wall time, mean hops, and accuracy, so every
future PR has a perf trajectory for the unified hot path.  Backends:

  reference        pure-jnp scan (the oracle)
  reference-lazy   early-exit while_loop
  pallas           fused hop-update kernel (interpreted on CPU, Mosaic on TPU)
  pallas-chunked   same, batch evaluated in chunk_b slices (VMEM-bounded)
  fused            ENTIRE Algorithm-2 loop in ONE Pallas launch (all grove
                   tables VMEM-pinned, early-exit while_loop in-kernel) —
                   pinned at the historical hand-picked block_b=256 with
                   compaction off: the autotuner's baseline to beat
  fused-tuned      fused at the MEASURED autotune winner (block_b x live-
                   lane compaction swept per (precision, field size) —
                   kernels/autotune.py); the roofline_gate asserts this is
                   no slower than the hand-picked row
  fused-auto       same, chunk_b="auto": chunks ONLY when the packed tables
                   + batch footprint exceed the VMEM budget (this forest
                   fits, so it must match plain fused — the fix for the
                   fused-chunked 29.4ms-vs-8.2ms regression)
  fused-bf16 /     fused over bf16 / int8 ForestPacks (packed VMEM
  fused-int8       residency; int8 pins ~4x the field per byte)
  reference-int8   the int8 dequantize oracle

Every row also gets a ``roofline`` entry — modeled bytes-moved / FLOPs /
bound / achieved-vs-roofline % from the dtype-aware analytic
:class:`repro.launch.roofline.RooflineModel` (drawn against the TPU v5e
spec; interpret-mode achieved % is honestly tiny) — and the measured
autotune winner is recorded under ``autotune``.

The record's ``kernel_launches`` field is the analytic per-eval Pallas
dispatch count; ``table_bytes`` is each precision's packed ForestPack
footprint (the fused kernel's VMEM load and the paper's SRAM capacity);
``energy_pj`` is each row's modeled pJ/example from the EvalReport's own
EnergyModel (the README backend matrix's pJ column).  Rows sharing a
precision must agree bit-for-bit on hops (the energy contract); int8 rows
additionally face the quantization gate — ``quant_gate`` fails the run if
int8 accuracy drops more than 1% below fp32, and CI invokes it against the
emitted JSON.

The record also carries a ``frontier`` dump: the Pareto frontier the
planning layer builds over (threshold x precision) on this forest
(``core/frontier.py``), which CI's ``energy_gate`` re-checks for
monotonicity — no frontier point may have both lower accuracy and higher
energy than a neighbor.

The ring backend is timed separately in fog_ring_bench (needs forced
multi-device XLA in a subprocess).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_engine.json"

QUANT_GATE_MAX_DROP = 0.01      # int8 may cost at most 1% accuracy vs fp32

# measured-vs-hand-picked tolerance: the tuned config must not lose more
# than this to the legacy block_b=256 default (timing noise headroom on
# shared CI runners; the tuner picked the faster config when it measured)
ROOFLINE_GATE_SLACK = 1.10


def _time_engine(engine, x, key, policy, reps=3):
    res = engine.eval(x, key, policy=policy)   # compile + warm
    res.proba.block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = engine.eval(x, key, policy=policy)
        res.proba.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, res


def energy_gate(record: dict | None = None,
                path: Path | str = OUT_PATH) -> None:
    """Fail (raise) unless the dumped frontier is monotone: sorted by
    energy ascending, accuracy must strictly increase (Frontier's Pareto
    invariant — a violation means the builder regressed)."""
    from repro.core.frontier import Frontier
    if record is None:
        record = json.loads(Path(path).read_text())
    frontier = Frontier.from_dict(record["frontier"])
    frontier.check_monotone()
    print(f"CSV,engine,energy_gate=pass,points={len(frontier)},"
          f"span_nj={frontier.points[0].energy_nj:.3f}"
          f"-{frontier.points[-1].energy_nj:.3f}")


def quant_gate(record: dict | None = None,
               path: Path | str = OUT_PATH) -> None:
    """Fail (raise) if int8 accuracy trails fp32 by more than the gate."""
    if record is None:
        record = json.loads(Path(path).read_text())
    acc = record["acc"]
    fp32, int8 = acc["fused"], acc["fused-int8"]
    if int8 < fp32 - QUANT_GATE_MAX_DROP:
        raise SystemExit(
            f"quantization gate FAILED: int8 accuracy {int8:.4f} is more "
            f"than {QUANT_GATE_MAX_DROP:.0%} below fp32 {fp32:.4f}")
    print(f"CSV,engine,quant_gate=pass,acc_fp32={fp32:.4f},"
          f"acc_int8={int8:.4f}")


def roofline_gate(record: dict | None = None,
                  path: Path | str = OUT_PATH) -> None:
    """Fail (raise) unless (a) every timed backend row carries a roofline
    entry with bytes-moved / bound / achieved %, and (b) the measured
    autotune winner is no slower than the hand-picked block_b default
    (within timing-noise slack)."""
    if record is None:
        record = json.loads(Path(path).read_text())
    roof = record.get("roofline")
    if not roof:
        raise SystemExit("roofline gate FAILED: no roofline section")
    for name in record["backend_us"]:
        entry = roof.get(name)
        if not entry:
            raise SystemExit(f"roofline gate FAILED: no roofline entry "
                             f"for backend row {name!r}")
        for field in ("bytes_moved", "bound", "achieved_pct"):
            if field not in entry:
                raise SystemExit(f"roofline gate FAILED: roofline[{name!r}]"
                                 f" lacks {field!r}")
    tuned = record["backend_us"].get("fused-tuned")
    hand = record["backend_us"].get("fused")
    if tuned is None or hand is None:
        raise SystemExit("roofline gate FAILED: need both fused and "
                         "fused-tuned rows")
    if tuned > hand * ROOFLINE_GATE_SLACK:
        raise SystemExit(
            f"roofline gate FAILED: autotuned fused ({tuned} us) is slower "
            f"than the hand-picked default ({hand} us) beyond "
            f"{ROOFLINE_GATE_SLACK:.2f}x slack")
    cfg = record.get("autotune", {})
    print(f"CSV,engine,roofline_gate=pass,tuned_us={tuned},hand_us={hand},"
          f"block_b={cfg.get('block_b')},compact={cfg.get('compact')}")


def run(out_path: Path | str | None = OUT_PATH) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import FogEngine, FogPolicy, ForestPack, split
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=1))
    gc = split(rf, 2)
    x = jnp.asarray(ds.x_test)
    key = jax.random.key(0)
    thresh = 0.3
    policy = FogPolicy(threshold=thresh, max_hops=gc.n_groves)

    # measured autotune pass first: sweep block_b x compaction on inputs
    # representative of this benchmark, so the fused-tuned row (block_b
    # unset -> best_config cache hit) serves the measured winner
    from repro.core.policy import NO_BUDGET
    from repro.kernels import autotune
    B = int(x.shape[0])
    pack_fp32 = ForestPack.from_groves(gc, "fp32")
    tuned = autotune.tune(
        pack_fp32, x,
        jax.random.randint(jax.random.key(1), (B,), 0, gc.n_groves),
        jnp.full((B,), thresh, jnp.float32),
        jnp.full((B,), NO_BUDGET, jnp.int32),
        max_hops=gc.n_groves,
        blocks=[512, 256, 128, 64])

    engines = {
        "reference": FogEngine(gc),
        "reference-lazy": FogEngine(gc, lazy=True),
        "pallas": FogEngine(gc, backend="pallas"),
        "pallas-chunked": FogEngine(gc, backend="pallas", chunk_b=256),
        # the historical hand-picked config: the tuner's baseline to beat
        "fused": FogEngine(gc, backend="fused", block_b=256, compact=False),
        "fused-tuned": FogEngine(gc, backend="fused"),  # autotuned knobs
        "fused-auto": FogEngine(gc, backend="fused", chunk_b="auto"),
        "fused-bf16": FogEngine(gc, backend="fused", precision="bf16"),
        "fused-int8": FogEngine(gc, backend="fused", precision="int8"),
        "reference-int8": FogEngine(gc, precision="int8"),
    }
    precisions = {name: eng.precision for name, eng in engines.items()}
    n_chunks = -(-B // 256)
    # analytic Pallas dispatches per evaluation (worst case, lazy aside);
    # fused-auto must NOT chunk this VMEM-resident forest: 1 launch
    launches = {
        "reference": 0, "reference-lazy": 0,
        "pallas": gc.n_groves, "pallas-chunked": gc.n_groves * n_chunks,
        "fused": 1, "fused-tuned": 1, "fused-auto": 1,
        "fused-bf16": 1, "fused-int8": 1, "reference-int8": 0,
    }
    table_bytes = {p: ForestPack.from_groves(gc, p).table_bytes
                   for p in ("fp32", "bf16", "int8")}
    rows, record = [], {"bench": "engine_backends", "B": B,
                        "n_groves": gc.n_groves, "thresh": thresh,
                        "backend_us": {}, "mean_hops": {}, "acc": {},
                        "energy_pj": {},
                        "kernel_launches": launches,
                        "table_bytes": table_bytes,
                        "autotune": tuned.to_dict(), "roofline": {}}
    # which rows walk every lane every iteration (fixed-trip scan) vs exit
    # early, and which run the fused compaction — the roofline model's
    # iters / compute terms
    scan_rows = {"reference", "pallas", "pallas-chunked", "reference-int8"}
    compact_rows = {"fused-tuned": tuned.compact, "fused-auto": True,
                    "fused-bf16": True, "fused-int8": True}
    from repro.launch.roofline import RooflineModel
    base_hops = {}
    for name, eng in engines.items():
        dt, res = _time_engine(eng, x, key, policy)
        hops = np.asarray(res.hops)
        acc = float((np.asarray(res.label) == ds.y_test).mean())
        prec = precisions[name]
        if prec not in base_hops:
            base_hops[prec] = hops
        else:
            # backends must preserve the hop-count energy accounting
            # within each precision (int8 walks legitimately differ)
            assert (hops == base_hops[prec]).all(), \
                f"{name} diverged on hops"
        energy_pj = res.energy_report().per_example_pj
        roof = RooflineModel(eng.tables.pack(prec), x.shape[1]).estimate(
            name,
            B,
            iters=gc.n_groves if name in scan_rows else int(hops.max()),
            hops_total=float(hops.sum()),
            compact=compact_rows.get(name, False))
        record["backend_us"][name] = round(dt * 1e6)
        record["mean_hops"][name] = float(hops.mean())
        record["acc"][name] = acc
        record["energy_pj"][name] = energy_pj
        record["roofline"][name] = roof.to_dict(measured_s=dt)
        rows.append(f"CSV,engine,backend={name},us={dt * 1e6:.0f},"
                    f"acc={acc:.4f},mean_hops={hops.mean():.2f},"
                    f"energy_pj={energy_pj:.1f},"
                    f"launches={launches[name]},"
                    f"table_bytes={table_bytes[prec]},"
                    f"roof_bound={roof.bound},"
                    f"roof_mb={roof.bytes_moved / 1e6:.2f}")
    # the auto-chunk regression fix: auto must not chunk a resident pack
    assert engines["fused-auto"]._resolve_chunk(
        "fused", engines["fused-auto"].tables.pack("fp32"), B, 256, "auto",
        int(x.shape[1])) is None, "fused-auto chunked a VMEM-resident pack"
    # the planning layer's Pareto frontier over (threshold x precision) on
    # this forest — persisted so CI's energy_gate can assert monotonicity
    # and the README pJ column has a calibrated source
    from repro.core.frontier import build_frontier
    frontier = build_frontier(
        engines["reference"], np.asarray(ds.x_test), ds.y_test)
    record["frontier"] = frontier.to_dict()
    rows.extend(f"CSV,engine,frontier,{p}" for p in frontier)
    if out_path is not None:
        Path(out_path).write_text(json.dumps(record, indent=2) + "\n")
        rows.append(f"CSV,engine,wrote={out_path}")
    quant_gate(record)
    energy_gate(record)
    roofline_gate(record)
    return rows


if __name__ == "__main__":
    import sys
    if "--gate-only" in sys.argv:
        quant_gate()
    elif "--energy-gate-only" in sys.argv:
        energy_gate()
    elif "--roofline-gate-only" in sys.argv:
        roofline_gate()
    else:
        print("\n".join(run()))
