"""Shared benchmark harness: trains the classifier zoo once per dataset."""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import train_cnn, train_mlp, train_svm_lr, train_svm_rbf
from repro.core import (
    FogEngine, FogPolicy, find_opt_threshold, rf_report, split,
    threshold_sweep,
)
from repro.data import Dataset, make_dataset
from repro.forest import TensorForest, TrainConfig, rf_predict, train_random_forest

DATASETS = ["isolet", "penbased", "mnist", "letter", "segmentation"]
N_TREES = 16
# deeper trees for the wide/many-class datasets (the paper's budgeted
# training picks per-dataset structure; these are our EDP-trained depths)
DEPTHS = {"isolet": 12, "mnist": 12, "letter": 11, "penbased": 9,
          "segmentation": 8}


def depth_for(name: str) -> int:
    return DEPTHS.get(name, 8)


@functools.lru_cache(maxsize=None)
def dataset(name: str) -> Dataset:
    return make_dataset(name)


@functools.lru_cache(maxsize=None)
def forest_for(name: str) -> TensorForest:
    ds = dataset(name)
    return train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                               TrainConfig(n_trees=N_TREES,
                                           max_depth=depth_for(name),
                                           seed=0))


@dataclasses.dataclass
class ClassifierResult:
    name: str
    accuracy: float
    energy_nj: float


@functools.lru_cache(maxsize=None)
def evaluate_all(name: str) -> dict[str, ClassifierResult]:
    """Accuracy + modeled energy for all 7 classifiers on one dataset."""
    ds = dataset(name)
    out: dict[str, ClassifierResult] = {}
    for key, fn in [("svm_lr", train_svm_lr), ("svm_rbf", train_svm_rbf),
                    ("mlp", train_mlp), ("cnn", train_cnn)]:
        m = fn(ds)
        out[key] = ClassifierResult(key, m.accuracy, m.energy_nj)

    rf = forest_for(name)
    x_test = jnp.asarray(ds.x_test)
    rf_acc = float(np.mean(np.asarray(rf_predict(rf, x_test)) == ds.y_test))
    e_rf = rf_report(len(ds.y_test), rf.n_trees, depth_for(name), ds.n_classes)
    out["rf"] = ClassifierResult("rf", rf_acc, e_rf.per_example_nj)

    gc = split(rf, 2)   # 8x2 topology (the paper's min-EDP pick)
    # FoG_max: threshold above 1 -> every grove votes; energy comes from
    # the EvalReport's own model (one accounting path, one set of per-op
    # constants — core/energy.py's)
    res = FogEngine(gc).eval(x_test, jax.random.key(0),
                             policy=FogPolicy(threshold=1.1))
    acc = float(np.mean(np.asarray(res.label) == ds.y_test))
    e = res.energy_report()
    out["fog_max"] = ClassifierResult("fog_max", acc, e.per_example_nj)

    # FoG_opt: accuracy-optimal threshold from the sweep
    pts = threshold_sweep(rf, 2, ds.x_test, ds.y_test)
    opt = find_opt_threshold(pts)
    out["fog_opt"] = ClassifierResult("fog_opt", opt.accuracy, opt.energy_nj)
    return out


def timed(fn, *args, repeat: int = 3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    return r, (time.perf_counter() - t0) / repeat * 1e6
