"""Roofline report over BENCH_engine.json: achieved-vs-bound per backend.

    PYTHONPATH=src python -m benchmarks.roofline_report [BENCH_engine.json]

Reads the ``roofline`` section :mod:`benchmarks.engine_bench` attaches to
every backend row (modeled bytes-moved / FLOPs / bound / achieved % from
:class:`repro.launch.roofline.RooflineModel`) and prints the markdown
table the README's backend matrix is refreshed from.  The achieved %
column is drawn against the TPU v5e spec: on the interpret-mode CPU
container it is honestly tiny — the number to read there is the *relative*
bytes-moved ranking (fused moves ~iters× fewer table bytes than per-hop).

The legacy mode — deriving three-term rooflines from LM dry-run JSONL
records — moved with the HLO cost model to :mod:`repro.launch.hlo_cost`;
``derive``/``rows_from``/``table`` below keep that path importable behind
a ``DeprecationWarning`` (now with guarded divisions).
"""
from __future__ import annotations

import json
import sys
import warnings


def bottleneck_note(row: dict) -> str:
    """One actionable lever per bound, FoG flavored."""
    bound = row.get("bound") or row.get("dominant")
    if bound == "memory":
        return "cut table re-reads: fused pin / int8 pack / compaction"
    if bound == "collective":
        return "reshard or overlap: fewer rotation hops across ICI"
    return "raise VPU utilization: bigger block_b / denser live lanes"


def engine_rows(path: str) -> list[dict]:
    """Backend rows of BENCH_engine.json that carry a roofline entry."""
    with open(path) as f:
        bench = json.load(f)
    latency = bench.get("backend_us", {})
    return [{"name": name, "latency_us": latency.get(name), **roof}
            for name, roof in bench.get("roofline", {}).items()]


def engine_table(rows: list[dict]) -> list[str]:
    hdr = ("| backend | latency | bytes moved | flops | bound | "
           "roofline ideal | achieved | next lever |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r.get("latency_us") or 0.0)):
        lat = r.get("latency_us")
        lat_cell = f"{lat / 1e3:.2f} ms" if lat else "—"
        out.append(
            f"| {r['name']} | {lat_cell} | "
            f"{r['bytes_moved'] / 1e6:.2f} MB | {r['flops']:.3g} | "
            f"{r['bound']} | {r['ideal_s'] * 1e6:.1f} us | "
            f"{r.get('achieved_pct', 0.0):.3f}% | {bottleneck_note(r)} |")
    return out


def main() -> None:
    paths = sys.argv[1:] or ["BENCH_engine.json"]
    for path in paths:
        print(f"\n## {path}")
        rows = engine_rows(path)
        if not rows:
            print("(no roofline sections; run benchmarks.engine_bench first)")
            continue
        print("\n".join(engine_table(rows)))


# --------------------------------------------------------------------------
# deprecated: LM dry-run JSONL mode (no FoG path produces these records)
# --------------------------------------------------------------------------

def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"benchmarks.roofline_report.{name} consumes LM dry-run JSONL "
        "records, which no FoG path produces; the engine roofline lives in "
        "BENCH_engine.json (engine_rows/engine_table)",
        DeprecationWarning, stacklevel=3)


def derive(rec: dict) -> dict:
    """DEPRECATED three-term derivation for one dry-run JSONL record."""
    _warn_legacy("derive")
    from repro.launch.hlo_cost import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS
    compute = rec["hlo_flops"] / PEAK_FLOPS
    memory = rec["hlo_bytes"] / HBM_BW
    coll = rec["collective_bytes"] / (ICI_LINKS * ICI_BW)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    step = max(terms.values())
    chips = rec.get("chips") or 1
    ideal = rec["model_flops"] / (chips * PEAK_FLOPS)
    useful = ((rec["model_flops"] / chips) / rec["hlo_flops"]
              if rec["hlo_flops"] else 0.0)
    return {**rec, "compute_s": compute, "memory_s": memory,
            "collective_s": coll, "dominant": dom,
            "useful_flops_ratio": useful,
            "roofline_fraction": ideal / step if step else 0.0}


def rows_from(path: str) -> list[dict]:
    """DEPRECATED reader for dry-run JSONL files."""
    _warn_legacy("rows_from")
    out = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("skipped") or rec.get("error"):
                continue
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                out.append(derive(rec))
    return out


def table(rows: list[dict]) -> list[str]:
    """DEPRECATED dry-run table renderer."""
    _warn_legacy("table")
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MODEL_FLOPS | useful | roofline_frac | next lever |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.3g} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {bottleneck_note(r)} |")
    return out


if __name__ == "__main__":
    main()
