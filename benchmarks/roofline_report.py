"""§Roofline report: derive the three-term table from dry-run JSONL records.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        results_dryrun_single.jsonl [results_dryrun_multi.jsonl]

Terms (v5e, per chip): compute = HLO_FLOPs/197e12; memory = HLO_bytes/819e9;
collective = collective_bytes/(4*50e9).  HLO quantities are per-device
(post-SPMD).  MODEL_FLOPS = 6*N_active*D (train) / 2*N_active (decode).
"""
from __future__ import annotations

import json
import sys

from repro.launch.roofline import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS


def derive(rec: dict) -> dict:
    compute = rec["hlo_flops"] / PEAK_FLOPS
    memory = rec["hlo_bytes"] / HBM_BW
    coll = rec["collective_bytes"] / (ICI_LINKS * ICI_BW)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    step = max(terms.values())
    ideal = rec["model_flops"] / (rec["chips"] * PEAK_FLOPS)
    useful = (rec["model_flops"] / rec["chips"]) / rec["hlo_flops"] \
        if rec["hlo_flops"] else 0.0
    return {**rec, "compute_s": compute, "memory_s": memory,
            "collective_s": coll, "dominant": dom,
            "useful_flops_ratio": useful,
            "roofline_fraction": ideal / step if step else 0.0}


def bottleneck_note(rec: dict) -> str:
    d = rec["dominant"]
    if d == "memory":
        return "cut HBM traffic: fused attention tiles / bf16 / fewer saves"
    if d == "collective":
        return "reshard or overlap: fewer all-gathers per layer"
    return "raise MXU utilization: bigger matmul tiles / drop masked work"


def rows_from(path: str) -> list[dict]:
    return [derive(json.loads(l)) for l in open(path)
            if not json.loads(l).get("skipped") and not json.loads(l).get("error")]


def table(rows: list[dict]) -> list[str]:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MODEL_FLOPS | useful | roofline_frac | next lever |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.3g} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {bottleneck_note(r)} |")
    return out


def main() -> None:
    for path in sys.argv[1:]:
        print(f"\n## {path}")
        print("\n".join(table(rows_from(path))))


if __name__ == "__main__":
    main()
