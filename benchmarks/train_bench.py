"""Trainer benchmark: host CART vs device histogram induction -> CSV rows
+ BENCH_train.json.

Times both trainers over a forest-size sweep on the budgeted-RF bench
config — penbased, depth 8, ``max_features="all"`` with a
``feature_cost``/``cost_weight`` penalty.  That config is the paper's
training story (the Nan/Wang/Saligrama budgeted criterion scores EVERY
feature's acquisition cost at every node, so there is no subsample to hide
the host trainer's per-candidate work behind), and it is where retraining
cost actually bites the streaming tier.

Record schema (``BENCH_train.json``):

  sweep[]        one entry per n_trees: host_s / device_s wall time (device
                 timed warm — compile is a once-per-shape cost a retraining
                 loop never pays again; compile time is recorded
                 separately), speedup, test accuracy per trainer,
                 tree_samples_per_s (N * n_trees / wall)
  gate           the gate-config (largest sweep entry) measurements plus
                 the determinism and round-trip checks
  autotune       the measured histogram TuneResult for the gate signature

``train_gate`` (CI tier-1) fails the run unless, on the gate config:
  - the device trainer is >= 5x faster than the (vectorized) host trainer
  - device test accuracy is within 0.5% absolute of the host trainer
  - two same-seed device runs produce bit-identical TensorForest tables
  - the device-trained forest round-trips ForestPack.save/load and
    ModelRegistry.publish, and all four engine backends (reference,
    pallas, fused, ring) serve it with bit-identical labels and hops
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_train.json"

GATE_MIN_SPEEDUP = 5.0
GATE_MAX_ACC_GAP = 0.005        # absolute test-accuracy parity budget

DATASET = "penbased"
DEPTH = 8
SWEEP = (8, 16, 32)             # n_trees; last entry is the gate config
SEED = 0
COST_WEIGHT = 0.01


def train_gate(record: dict | None = None,
               path: Path | str = OUT_PATH) -> None:
    """Fail (raise) unless the gate-config measurements hold: >=5x device
    speedup, <=0.5% absolute accuracy gap, bit-reproducible device runs,
    and an intact ForestPack/ModelRegistry/4-backend round trip."""
    if record is None:
        record = json.loads(Path(path).read_text())
    g = record["gate"]
    if g["speedup"] < GATE_MIN_SPEEDUP:
        raise SystemExit(
            f"train gate FAILED: device speedup {g['speedup']:.2f}x is "
            f"below {GATE_MIN_SPEEDUP:.0f}x (host {g['host_s']:.2f}s vs "
            f"device {g['device_s']:.2f}s)")
    # round before comparing: accuracies are ratios of small integers, and
    # a gap of exactly 0.5% must not fail on fp representation error
    gap = round(abs(g["acc_host"] - g["acc_device"]), 9)
    if gap > GATE_MAX_ACC_GAP:
        raise SystemExit(
            f"train gate FAILED: accuracy gap {gap * 100:.2f}% exceeds "
            f"{GATE_MAX_ACC_GAP * 100:.1f}% (host {g['acc_host']:.4f} vs "
            f"device {g['acc_device']:.4f})")
    if not g["bit_reproducible"]:
        raise SystemExit("train gate FAILED: two same-seed device runs "
                         "produced different TensorForest tables")
    if not g["roundtrip_identical"]:
        raise SystemExit("train gate FAILED: the device-trained forest did "
                         "not serve bit-identically across backends after "
                         "the ForestPack/ModelRegistry round trip")
    print(f"CSV,train,train_gate=pass,speedup={g['speedup']:.2f}x,"
          f"acc_gap={gap * 100:.2f}%,backends={g['backends_checked']}")


def _forest_equal(a, b) -> bool:
    import numpy as np
    return (np.array_equal(a.feature, b.feature)
            and np.array_equal(a.threshold, b.threshold)
            and np.array_equal(a.leaf, b.leaf))


def _roundtrip(forest, ds, n_classes: int) -> dict:
    """ForestPack save/load + ModelRegistry publish + 4-backend serve on
    the device-trained forest; returns the gate evidence."""
    import jax
    import numpy as np
    from repro.core import FogEngine, FogPolicy, split
    from repro.forest.pack import ForestPack
    from repro.registry import ModelRegistry

    gc = split(forest, 2)
    pack = ForestPack.from_groves(gc)
    policy = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    key = jax.random.key(SEED)
    x = ds.x_test

    mesh = jax.make_mesh((1,), ("grove",))
    engines = {
        "reference": FogEngine(gc, policy=policy),
        "pallas": FogEngine(gc, backend="pallas", policy=policy),
        "fused": FogEngine(gc, backend="fused", policy=policy),
        "ring": FogEngine(gc, backend="ring", mesh=mesh, policy=policy),
    }
    labels, hops = {}, {}
    for name, eng in engines.items():
        res = eng.eval(x, key)
        labels[name] = np.asarray(res.label)
        hops[name] = np.asarray(res.hops)
    base = labels["reference"]
    identical = all(
        np.array_equal(labels[n], base)
        and np.array_equal(hops[n], hops["reference"]) for n in engines)

    with tempfile.TemporaryDirectory() as tmp:
        art = pack.save(Path(tmp) / "trained.npz")
        pack2, _ = ForestPack.load_with_meta(art)
        res2 = FogEngine(pack2, policy=policy).eval(x, key)
        identical &= np.array_equal(np.asarray(res2.label), base)
        identical &= np.array_equal(np.asarray(res2.hops),
                                    hops["reference"])
        reg = ModelRegistry(Path(tmp) / "registry")
        version = reg.publish("train-bench", pack)
        pack3, _ = reg.load("train-bench")
        res3 = FogEngine(pack3, policy=policy).eval(x, key)
        identical &= np.array_equal(np.asarray(res3.label), base)

    acc = float((base == ds.y_test).mean())
    return {"roundtrip_identical": bool(identical),
            "backends_checked": sorted(engines),
            "published_version": int(version),
            "serve_acc": acc}


def run(out_path: Path | str | None = OUT_PATH,
        smoke: bool = False) -> list[str]:
    import numpy as np
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest
    from repro.forest.rf import rf_predict
    from repro.kernels import autotune

    ds = make_dataset(DATASET)
    n, n_features = ds.x_train.shape
    fcost = np.linspace(1.0, 2.0, n_features).astype(np.float32)
    depth = 5 if smoke else DEPTH
    sweep = (4,) if smoke else SWEEP
    n_thresholds = 16

    def cfg(trainer: str, n_trees: int) -> TrainConfig:
        return TrainConfig(n_trees=n_trees, max_depth=depth,
                           n_thresholds=n_thresholds, max_features="all",
                           feature_cost=fcost, cost_weight=COST_WEIGHT,
                           seed=SEED, trainer=trainer)

    # measured histogram autotune for the gate signature, so grow_forest's
    # best_hist_config lookup serves the measured winner (mirrors the
    # engine bench tuning the fused kernel before timing it)
    tuned = autotune.tune_histogram(
        sweep[-1], depth, n_features, n_thresholds + 1, ds.n_classes,
        n_samples=n, repeats=1 if smoke else 3)

    def accuracy(forest) -> float:
        pred = np.asarray(rf_predict(forest, ds.x_test))
        return float((pred == ds.y_test).mean())

    rows, sweep_rec = [], []
    gate: dict = {}
    for n_trees in sweep:
        t0 = time.perf_counter()
        f_host = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                                     cfg("host", n_trees))
        host_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        f_warm = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                                     cfg("device", n_trees))
        compile_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        f_dev = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                                    cfg("device", n_trees))
        device_s = time.perf_counter() - t0

        acc_h, acc_d = accuracy(f_host), accuracy(f_dev)
        entry = {
            "n_trees": n_trees, "host_s": host_s, "device_s": device_s,
            "device_compile_s": compile_s, "speedup": host_s / device_s,
            "acc_host": acc_h, "acc_device": acc_d,
            "tree_samples_per_s": {
                "host": n * n_trees / host_s,
                "device": n * n_trees / device_s,
            },
        }
        sweep_rec.append(entry)
        rows.append(
            f"CSV,train,n_trees={n_trees},host_s={host_s:.2f},"
            f"device_s={device_s:.2f},speedup={entry['speedup']:.2f}x,"
            f"acc_host={acc_h:.4f},acc_device={acc_d:.4f}")

        if n_trees == sweep[-1]:
            gate = dict(entry)
            # warmup and timed runs share the seed: bit-equal tables IS
            # the two-same-seed-runs determinism contract
            gate["bit_reproducible"] = _forest_equal(f_warm, f_dev)
            gate.update(_roundtrip(f_dev, ds, ds.n_classes))

    record = {
        "bench": "trainers", "dataset": DATASET, "n_train": int(n),
        "n_features": int(n_features), "depth": depth,
        "n_thresholds": n_thresholds, "max_features": "all",
        "cost_weight": COST_WEIGHT, "seed": SEED, "smoke": smoke,
        "sweep": sweep_rec, "gate": gate, "autotune": tuned.to_dict(),
    }
    if out_path is not None:
        Path(out_path).write_text(json.dumps(record, indent=2) + "\n")
        rows.append(f"CSV,train,wrote={out_path}")
    if not smoke:
        train_gate(record)
        rows.append(
            f"CSV,train,gate,speedup={gate['speedup']:.2f}x,"
            f"reproducible={gate['bit_reproducible']},"
            f"roundtrip={gate['roundtrip_identical']}")
    return rows


if __name__ == "__main__":
    import sys
    if "--gate-only" in sys.argv:
        train_gate()
    else:
        print("\n".join(run(smoke="--smoke" in sys.argv)))
