"""Table 1 (top): accuracy of 7 classifiers x 5 datasets."""
from __future__ import annotations

import benchmarks.common as common
from benchmarks.common import evaluate_all

COLUMNS = ["svm_lr", "svm_rbf", "mlp", "cnn", "rf", "fog_max", "fog_opt"]


def run() -> list[str]:
    rows = ["dataset," + ",".join(COLUMNS)]
    for name in common.DATASETS:
        res = evaluate_all(name)
        rows.append(name + "," + ",".join(
            f"{res[c].accuracy * 100:.1f}" for c in COLUMNS))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
