"""§Perf hillclimb driver: re-lower the three target cells and print terms.

    PYTHONPATH=src python -m benchmarks.perf_iter [--cells a,b,c] [--fog]

Target cells (chosen per EXPERIMENTS.md §Perf):
  minicpm3-4b/train_4k    worst roofline fraction (score-traffic-dominated)
  jamba-1.5-large-398b/train_4k   most collective-bound
  tinyllama-1.1b/decode_32k       paper-technique representative (FoG decode)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

DEFAULT_CELLS = [
    ("minicpm3-4b", "train_4k"),
    ("jamba-1.5-large-398b", "train_4k"),
    ("tinyllama-1.1b", "decode_32k"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=None,
                    help="comma list arch/shape[,arch/shape...]")
    ap.add_argument("--fog", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--out", default="results_perf_iters.jsonl")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun_cell
    cells = DEFAULT_CELLS
    if args.cells:
        cells = [tuple(c.split("/")) for c in args.cells.split(",")]

    for arch, shape in cells:
        rec = dryrun_cell(arch, shape, fog=args.fog and shape.startswith("decode"),
                          accum_steps=args.accum if shape.startswith("train") else 1)
        rec["tag"] = args.tag
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(f"{args.tag} {arch}/{shape}: compute {rec['compute_s']:.3f}s "
              f"memory {rec['memory_s']:.3f}s collective {rec['collective_s']:.3f}s "
              f"useful {rec['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
