"""Figure 5: run-time tunability — accuracy/EDP vs threshold, 8x2 vs 4x4."""
from __future__ import annotations

import numpy as np

import benchmarks.common as common
from benchmarks.common import dataset, forest_for
from repro.core import threshold_sweep


def run(datasets=None) -> list[str]:
    datasets = datasets or common.DATASETS
    rows = ["dataset,topology,threshold,accuracy,energy_nj,edp"]
    for name in datasets:
        ds = dataset(name)
        rf = forest_for(name)
        for grove_size, label in [(2, "8x2"), (4, "4x4")]:
            for p in threshold_sweep(rf, grove_size, ds.x_test, ds.y_test,
                                     np.asarray([0.02, 0.05, 0.1, 0.2, 0.3,
                                                 0.5, 0.7, 0.9, 1.0])):
                rows.append(f"{name},{label},{p.threshold:.2f},"
                            f"{p.accuracy:.4f},{p.energy_nj:.4f},{p.edp:.5f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
