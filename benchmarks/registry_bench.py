"""Multi-tenant registry serving bench: N tenants, one process, one cache.

    PYTHONPATH=src python -m benchmarks.registry_bench --smoke

Drives the REAL multi-tenant stack — a ModelRegistry of versioned forest
artifacts, a VMEM-budgeted PackCache, bucket-aware ForestReplicaServer
replicas behind a DeviceDispatcher behind a ContinuousBatcher, and a
TenantLedger of per-tenant EnergyGovernors — under Zipf-skewed open-loop
tenant traffic with mixed QoS tiers and mixed precisions, and emits
``BENCH_registry.json``:

* **cache** — hit rate, evictions and peak bytes against the VMEM budget:
  the budget holds the steady-state working set but NOT every (tenant,
  version, precision) bucket the run touches, so the mid-run version
  churn must evict (traffic-weighted) while the measured window stays
  >= 90% hits;
* **swap** — a live ``publish`` hot-swap of the hottest tenant mid-run:
  every request in flight at the swap completes on its pinned version
  (zero loss), and completion p99 latency in the post-swap window must
  not spike vs the pre-swap window;
* **canary** — ``publish(..., canary=f)`` traffic split on another tenant:
  the observed split matches ``f``, per-version ServeStats telemetry
  accumulates on both sides, and ``judge_canary`` prices the delta;
* **tenants** — per-tenant energy isolation: beta is ledgered under a
  budget its fp32 rungs cannot meet, so its governor must walk down to
  an int8 rung and settle there, while alpha's and gamma's governors
  (generous budgets) never move — one tenant's squeeze must not leak.

Single serve device: the data-parallel speedup story is serve_bench's;
this bench isolates the multi-tenant control plane, so virtual time ==
wall time by construction.  Control-plane work (``publish`` writing the
artifact) is NOT charged to the serving clock — a real deployment
publishes from outside the serving process; the cost the serving path
does pay (the new version's cache miss + device placement on first
dispatch) is charged.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_registry.json"

# Zipf-skewed tenant shares (~1/rank^1.45, normalized): one hot tenant, a
# warm one, a cold one — the cache's traffic-weighted eviction must keep
# the hot buckets resident while stale versions are dropped
TENANTS = (("alpha", 0.62), ("beta", 0.24), ("gamma", 0.14))
# QoS tier mix: gold buys accuracy (higher MaxDiff gate), bulk buys energy
# (explicit int8 tables + early exit), std rides its tenant's governor rung
TIER_MIX = (("std", 0.60), ("gold", 0.20), ("bulk", 0.20))
BASE_THRESH = 0.6
GOLD_THRESH = 0.9
BULK_THRESH = 0.4
# ledger budgets as factors of each tenant's CALIBRATED mixed-traffic
# rung-0 cost: alpha/gamma get headroom (their rungs must NOT move), beta
# is squeezed well under what any fp32 rung can deliver, so its governor
# must walk down to an int8 rung to comply
BUDGET_FACTOR = {"alpha": 1.6, "beta": 0.55, "gamma": 1.7}
SWAP_FRAC = 0.45       # hot-swap the hot tenant at 45% of the run
CANARY_FRAC_AT = 0.70  # start the canary split at 70%
CANARY_FRACTION = 0.25
WARMUP_FRAC = 0.15
WINDOW_FRAC = 0.15     # pre/post swap p99 windows (fraction of requests)


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


def bench(smoke: bool, seed: int = 0, workdir: str | None = None) -> dict:
    import tempfile

    import numpy as np

    from benchmarks.common import forest_for
    from repro.core.grove import split
    from repro.core.policy import FogPolicy
    from repro.data import make_dataset
    from repro.forest.pack import ForestPack
    from repro.registry import ModelRegistry, PackCache
    from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer
    from repro.serve.governor import (EnergyGovernor, TenantLedger,
                                      default_ladder)
    from repro.serve.scheduler import ContinuousBatcher, Request

    import jax

    n_requests = 1500 if smoke else 6000
    n_slots = 64
    rng = np.random.default_rng(seed)
    ds = make_dataset("penbased")
    n_features = ds.x_test.shape[1]
    gc = split(forest_for("penbased"), 2)
    pack = ForestPack.from_groves(gc, "fp32")

    workdir = workdir or tempfile.mkdtemp(prefix="registry_bench_")
    registry = ModelRegistry(workdir)
    extra = {"n_features_in": n_features}
    for t, _ in TENANTS:
        registry.publish(t, pack, extra=extra)

    # VMEM budget: sized to hold the steady-state working set — every
    # tenant's fp32 + int8 buckets (bulk-tier lanes carry explicit int8
    # contracts, so rare tenants' int8 buckets ARE part of the hot set;
    # with 64 lanes a step, even a 3%-share bucket is dispatched most
    # steps) — but NOT the extra buckets the hot-swap and canary versions
    # bring, so the mid-run churn must evict the stale version's tables
    fp32_b, int8_b = pack.table_bytes, pack.astype("int8").table_bytes
    budget_bytes = 4 * fp32_b + 3 * int8_b
    cache = PackCache(registry, budget_bytes=budget_bytes)
    server = ForestReplicaServer(None, n_features, backend="fused",
                                 registry=registry, cache=cache, seed=seed)
    dispatcher = DeviceDispatcher(server.factory, jax.devices()[:1])

    tenant_names = [t for t, _ in TENANTS]
    tenant_share = np.asarray([s for _, s in TENANTS])
    tenant_share = tenant_share / tenant_share.sum()
    tier_names = [t for t, _ in TIER_MIX]
    tier_share = np.asarray([s for _, s in TIER_MIX])
    tier_share = tier_share / tier_share.sum()

    base = FogPolicy(threshold=BASE_THRESH)
    tenants_of = rng.choice(len(tenant_names), size=n_requests,
                            p=tenant_share)
    tiers_of = rng.choice(tier_names, size=n_requests, p=tier_share)
    beta_bulk = rng.random(n_requests)

    def make_request(rid):
        t = tenant_names[int(tenants_of[rid % len(tenants_of)])]
        if t == "beta":
            # the squeezed tenant's traffic is governed lanes: std (the
            # rung's knobs — the ledger's lever) plus some explicit-int8
            # bulk.  Gold lanes pin their own threshold, which the ladder
            # cannot touch, and would put beta's floor above any budget.
            tier = "bulk" if beta_bulk[rid % len(beta_bulk)] < 0.1 else "std"
        else:
            tier = str(tiers_of[rid % len(tiers_of)])
        pol = None
        if tier == "gold":
            pol = FogPolicy(threshold=GOLD_THRESH)
        elif tier == "bulk":
            pol = FogPolicy(threshold=BULK_THRESH, precision="int8")
        # max_new_tokens=2: every request spans two decode steps, so a
        # hot-swap always catches requests mid-flight — the zero-downtime
        # pinning claim is only tested if something IS in flight
        return Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                       model=t, tier=tier, max_new_tokens=2, policy=pol)

    # -- calibration: wave 1 compiles every precision's program and fills
    # the cache; wave 2 (warm) measures serving capacity.  The whole burst
    # also measures each tenant's rung-0 mixed-traffic cost, which sizes
    # the ledger budgets. -------------------------------------------------
    cal = ContinuousBatcher(n_slots, None, server.prefill, eos_id=-1,
                            default_policy=base, dispatcher=dispatcher,
                            registry=registry)
    for rid in range(2 * n_slots):
        cal.submit(make_request(rid))
    cal.run()
    cal_n = 4 * n_slots
    for rid in range(2 * n_slots, 2 * n_slots + cal_n):
        cal.submit(make_request(rid))
    t0 = time.perf_counter()
    cal.run()
    capacity_rps = cal_n / (time.perf_counter() - t0)

    budgets = {}
    ledger = TenantLedger()
    for t in tenant_names:
        m32 = server.energy_model(tenant=t)
        m8 = server.energy_model("int8", tenant=t)
        pj = np.concatenate([
            np.asarray(m32.lane_pj(np.asarray(
                [r.hops[0] for r in cal.completed
                 if r.model == t and r.tier != "bulk"]))),
            np.asarray(m8.lane_pj(np.asarray(
                [r.hops[0] for r in cal.completed
                 if r.model == t and r.tier == "bulk"]))),
        ])
        c_mix = float(pj.mean()) * 1e-3
        budgets[t] = BUDGET_FACTOR[t] * c_mix
        # cooldown longer than the run: a rung measured over budget stays
        # off-limits, so a squeezed tenant SETTLES on its compliant rung
        # instead of periodically re-probing (and flapping through) the
        # rungs that already breached
        ledger.add(t, EnergyGovernor(
            default_ladder(base, m32, budgets[t]), budgets[t],
            model=m32, window=128, patience=2, cooldown=10**9))
    for t in tenant_names:          # calibration traffic is not billed
        registry.stats_for(t, 1).reset()
    cache.stats.reset()

    # -- the measured open loop -------------------------------------------
    b = ContinuousBatcher(n_slots, None, server.prefill, eos_id=-1,
                          default_policy=base, governor=ledger,
                          dispatcher=dispatcher, registry=registry)
    arrival_rps = 0.85 * capacity_rps
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rps,
                                         size=n_requests))
    warmup_n = int(WARMUP_FRAC * n_requests)
    swap_rid = int(SWAP_FRAC * n_requests)
    canary_rid = int(CANARY_FRAC_AT * n_requests)
    window_n = int(WINDOW_FRAC * n_requests)

    vnow = 0.0
    next_rid = 0
    done_vtime: dict[int, float] = {}
    n_done_seen = 0
    v_measure_start = 0.0
    swap_info: dict = {}
    canary_info: dict = {}
    swapped = canaried = False
    alpha_v2 = beta_canary_v = None
    guard = 0
    while len(b.completed) < n_requests:
        guard += 1
        if guard > 500_000:
            raise RuntimeError("registry_bench loop did not drain")
        if not swapped and next_rid >= swap_rid:
            # control plane: retrain-and-publish of the hot tenant.  The
            # artifact write happens off the serving clock; the serving
            # path pays only the new buckets' cache misses.
            inflight = [s.request.rid for s in b.slots
                        if s.request is not None
                        and s.request.model == "alpha"]
            alpha_v2 = registry.publish("alpha", pack, extra=extra)
            swap_info = {"at_rid": swap_rid, "inflight_rids": inflight}
            swapped = True
        if not canaried and next_rid >= canary_rid:
            # the canary artifact is published at int8 — the denser dtype
            # is the candidate the energy judge should prefer.  Reset the
            # live side's telemetry at the split so the judge compares the
            # SAME traffic window on both sides — live's history includes
            # beta's expensive pre-step-down era, which is not evidence
            # about the candidate.
            registry.stats_for(
                "beta", registry.live_version("beta")).reset()
            beta_canary_v = registry.publish(
                "beta", pack.astype("int8"), extra=extra,
                canary=CANARY_FRACTION)
            canary_info = {"tenant": "beta", "version": beta_canary_v,
                           "fraction": CANARY_FRACTION,
                           "at_rid": canary_rid}
            canaried = True
        while next_rid < n_requests and arrivals[next_rid] <= vnow:
            if next_rid == warmup_n:
                v_measure_start = vnow
                cache.stats.reset()
                b.stats.reset()
            b.submit(make_request(next_rid))
            next_rid += 1
        if b.active == 0 and not b.queue:
            if next_rid < n_requests:
                vnow = max(vnow, float(arrivals[next_rid]))
                continue
            break
        t0 = time.perf_counter()
        b.step()
        vnow += time.perf_counter() - t0
        for r in b.completed[n_done_seen:]:
            done_vtime[r.rid] = vnow
        n_done_seen = len(b.completed)

    # -- metrics ----------------------------------------------------------
    completed = {r.rid: r for r in b.completed}
    measured = [r for r in b.completed if r.rid >= warmup_n]
    correct = sum(1 for r in b.completed
                  if r.generated
                  and r.generated[0] == int(ds.y_test[r.rid % len(ds.y_test)]))
    valid = sum(1 for r in b.completed
                if r.generated and r.hops and r.hops[0] >= 1
                and 0 <= r.generated[0] < pack.n_classes)

    def lat_ms(rids):
        return [(done_vtime[rid] - float(arrivals[rid])) * 1e3
                for rid in rids if rid in done_vtime]

    pre = lat_ms(range(max(warmup_n, swap_rid - window_n), swap_rid))
    post = lat_ms(range(swap_rid, swap_rid + window_n))
    inflight_rids = swap_info.get("inflight_rids", [])
    swap_row = {
        "tenant": "alpha", "at_rid": swap_info.get("at_rid"),
        "v_to": alpha_v2,
        "inflight": len(inflight_rids),
        "inflight_completed": sum(1 for rid in inflight_rids
                                  if rid in completed
                                  and completed[rid].done),
        "inflight_on_old_version": sum(
            1 for rid in inflight_rids
            if rid in completed and completed[rid].version == 1),
        "p50_pre_ms": round(_percentile(pre, 50), 3),
        "p99_pre_ms": round(_percentile(pre, 99), 3),
        "p50_post_ms": round(_percentile(post, 50), 3),
        "p99_post_ms": round(_percentile(post, 99), 3),
        "alpha_versions_served": sorted(
            {r.version for r in b.completed if r.model == "alpha"}),
    }

    beta_post = [r for r in b.completed
                 if r.model == "beta" and r.rid >= canary_rid]
    beta_on_canary = [r for r in beta_post if r.version == beta_canary_v]
    judge = registry.judge_canary("beta")
    if judge["canary"]["n_events"] and judge["delta_nj"] <= 0:
        registry.promote("beta")
        promoted = True
    else:
        registry.abort_canary("beta")
        promoted = False
    canary_row = {
        **canary_info,
        "observed_fraction": round(
            len(beta_on_canary) / max(1, len(beta_post)), 4),
        "n_routed": len(beta_on_canary), "n_beta_post": len(beta_post),
        "judge": judge,
        "promoted": promoted,
        "live_after": registry.live_version("beta"),
    }

    tenants_row = {}
    for i, t in enumerate(tenant_names):
        gov = ledger.governor_for(t)
        t_done = [r for r in measured if r.model == t]
        tenants_row[t] = {
            "share": round(float(tenant_share[i]), 4),
            "budget_nj": round(budgets[t], 4),
            "rolling_nj": (None if gov.rolling_nj is None
                           else round(gov.rolling_nj, 4)),
            "rung_final": gov.rung,
            "rung_precision": gov.current.precision,
            "transitions": len(gov.transitions),
            "n_done": len(t_done),
            "mean_hops": round(float(np.mean(
                [r.hops[0] for r in t_done])) if t_done else 0.0, 3),
        }

    v_window = vnow - v_measure_start
    return {
        "dataset": "penbased", "topology": "8x2", "backend": "fused",
        "smoke": smoke, "seed": seed, "n_slots": n_slots,
        "n_requests": n_requests, "warmup_n": warmup_n,
        "capacity_rps": round(capacity_rps, 1),
        "arrival_rps": round(arrival_rps, 1),
        "throughput_rps": round(len(measured) / max(v_window, 1e-9), 1),
        "offered": n_requests, "completed": len(b.completed),
        "shed": len(b.shed_requests),
        "valid": valid,
        "accuracy": round(correct / max(1, len(b.completed)), 4),
        "tiers": b.stats.tier_summary(),
        "tenants": tenants_row,
        "cache": {
            "budget_bytes": budget_bytes,
            "bytes_used": cache.bytes_used,
            "peak_bytes": cache.peak_bytes,
            "hits": cache.stats.hits, "misses": cache.stats.misses,
            "evictions": cache.stats.evictions,
            "hit_rate": round(cache.stats.hit_rate, 4),
            "resident": [list(map(str, k)) for k in cache.keys()],
        },
        "swap": swap_row,
        "canary": canary_row,
    }


# --------------------------------------------------------------------------
# gate
# --------------------------------------------------------------------------

def registry_gate(data: dict) -> list[str]:
    """CI gate over BENCH_registry.json — the acceptance criteria: zero
    request loss across a live hot-swap with no p99 spike, the cache under
    its VMEM budget (with real eviction churn) at >= 90% hits, and
    per-tenant energy isolation (each tenant's steady-state nJ under its
    own budget; the squeezed tenant steps down to int8 alone)."""
    fails = []
    if data.get("completed") != data.get("offered") or data.get("shed"):
        fails.append(
            f"request loss: offered {data.get('offered')} vs completed "
            f"{data.get('completed')} (shed {data.get('shed')})")
    if data.get("valid") != data.get("completed"):
        fails.append(f"only {data.get('valid')}/{data.get('completed')} "
                     "completions were valid (hops>=1, in-range label)")
    if data.get("accuracy", 0.0) < 0.8:
        fails.append(f"end-to-end accuracy {data.get('accuracy')} < 0.8 — "
                     "some bucket served the wrong tables")

    sw = data.get("swap", {})
    if sw.get("inflight_completed") != sw.get("inflight"):
        fails.append(
            f"hot-swap dropped in-flight requests: "
            f"{sw.get('inflight_completed')}/{sw.get('inflight')} completed")
    if sw.get("inflight_on_old_version") != sw.get("inflight"):
        fails.append(
            "hot-swap migrated in-flight requests off their pinned "
            f"version: {sw.get('inflight_on_old_version')}/"
            f"{sw.get('inflight')} stayed on v1")
    p99_pre, p99_post = sw.get("p99_pre_ms", 0.0), sw.get("p99_post_ms", 0.0)
    if p99_post > max(1.5 * p99_pre, p99_pre + 5.0):
        fails.append(
            f"hot-swap p99 spike: {p99_post}ms post vs {p99_pre}ms pre "
            "(allowed 1.5x or +5ms)")
    if len(sw.get("alpha_versions_served", [])) < 2:
        fails.append("hot-swap never served the new version "
                     f"(versions {sw.get('alpha_versions_served')})")

    c = data.get("cache", {})
    if c.get("peak_bytes", 0) > c.get("budget_bytes", 0):
        fails.append(f"cache exceeded its VMEM budget: peak "
                     f"{c.get('peak_bytes')} > {c.get('budget_bytes')} B")
    if c.get("evictions", 0) < 1:
        fails.append("cache never evicted: the run's bucket set did not "
                     "exceed the budget (nothing was measured)")
    if c.get("hit_rate", 0.0) < 0.90:
        fails.append(f"cache hit rate {c.get('hit_rate')} < 0.90 under "
                     "Zipf tenant traffic")

    tenants = data.get("tenants", {})
    for t, row in tenants.items():
        if (row.get("rolling_nj") is not None
                and row["rolling_nj"] > row["budget_nj"]):
            fails.append(
                f"tenant {t}: steady-state {row['rolling_nj']} nJ over "
                f"its own budget {row['budget_nj']} nJ")
    if tenants.get("beta", {}).get("rung_precision") != "int8":
        fails.append("beta's squeezed governor never stepped down to an "
                     "int8 rung (per-tenant governance is inert)")
    for t in ("alpha", "gamma"):
        if tenants.get(t, {}).get("transitions", 1) != 0:
            fails.append(
                f"tenant {t}'s governor moved "
                f"({tenants.get(t, {}).get('transitions')} transitions) — "
                "beta's squeeze leaked across the ledger")

    cn = data.get("canary", {})
    target = cn.get("fraction", 0.0)
    if abs(cn.get("observed_fraction", 0.0) - target) > 0.12:
        fails.append(
            f"canary split off target: observed "
            f"{cn.get('observed_fraction')} vs fraction {target}")
    judge = cn.get("judge", {})
    if not (judge.get("live", {}).get("n_events", 0)
            and judge.get("canary", {}).get("n_events", 0)):
        fails.append("canary judging has no per-version telemetry on "
                     "one side of the split")
    return fails


# --------------------------------------------------------------------------
# CLI + benchmarks.run integration
# --------------------------------------------------------------------------

def run(smoke: bool = True):
    """benchmarks.run section hook: subprocess for a clean jax (and so a
    crashed bench cannot poison the parent's device state)."""
    import subprocess
    repo = Path(__file__).resolve().parent.parent
    env = {
        "PYTHONPATH": str(repo / "src"),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
    }
    cmd = [sys.executable, "-m", "benchmarks.registry_bench"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                          text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"registry_bench failed:\n{proc.stdout}\n{proc.stderr}")
    yield from (ln for ln in proc.stdout.splitlines() if ln.strip())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run (the CI tier-1 configuration)")
    ap.add_argument("--gate-only", action="store_true",
                    help="re-run the gate over an existing "
                         "BENCH_registry.json without re-benchmarking")
    ap.add_argument("--out", default=str(OUT_PATH))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default=None,
                    help="registry directory (default: a fresh tempdir)")
    args = ap.parse_args()

    if args.gate_only:
        data = json.loads(Path(args.out).read_text())
        fails = registry_gate(data)
        if fails:
            print("[registry_gate] FAIL:\n  " + "\n  ".join(fails))
            sys.exit(1)
        print("[registry_gate] ok")
        return

    data = bench(smoke=args.smoke, seed=args.seed, workdir=args.workdir)
    Path(args.out).write_text(json.dumps(data, indent=1))
    c, sw = data["cache"], data["swap"]
    print(f"[registry_bench] {len(data['tenants'])} tenants, "
          f"{data['completed']}/{data['offered']} done, "
          f"acc {data['accuracy']}, {data['throughput_rps']} req/s")
    print(f"[registry_bench] cache hit {c['hit_rate']}, "
          f"{c['evictions']} evictions, peak {c['peak_bytes']}/"
          f"{c['budget_bytes']} B")
    print(f"[registry_bench] swap p99 {sw['p99_pre_ms']}ms -> "
          f"{sw['p99_post_ms']}ms, inflight {sw['inflight_completed']}/"
          f"{sw['inflight']} done, on v1 {sw['inflight_on_old_version']}")
    for t, row in data["tenants"].items():
        print(f"[registry_bench] {t}: budget {row['budget_nj']} nJ, "
              f"rolling {row['rolling_nj']} nJ, rung {row['rung_final']} "
              f"({row['rung_precision'] or 'fp32'}), "
              f"{row['transitions']} transitions")
    print(f"[registry_bench] canary observed "
          f"{data['canary']['observed_fraction']} vs "
          f"{data['canary']['fraction']}, promoted "
          f"{data['canary']['promoted']}")
    print(f"[registry_bench] wrote {args.out}")
    fails = registry_gate(data)
    if fails:
        print("[registry_gate] FAIL:\n  " + "\n  ".join(fails))
        sys.exit(1)
    print("[registry_gate] ok")


if __name__ == "__main__":
    main()
