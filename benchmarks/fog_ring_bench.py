"""FoG ring on a device mesh (§3.2.2 scaled): per-hop wall time + traffic.

Runs the shard_map + ppermute ring evaluator on 8 forced host devices and
reports lane occupancy decay (how fast confident lanes die -> the load
self-balancing the paper's queue priority scheme provides).  Run as a
subprocess to get its own XLA device count.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import split, fog_eval
    from repro.core.fog_ring import fog_ring_eval
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=1))
    gc = split(rf, 2)
    mesh = jax.make_mesh((8,), ("grove",))
    x = jnp.asarray(ds.x_test[:1024] if len(ds.x_test) >= 1024 else ds.x_test)

    for thresh in [0.1, 0.3, 0.5]:
        t0 = time.perf_counter()
        proba, hops = fog_ring_eval(gc, x, jax.random.key(0), thresh, 8, mesh)
        proba.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e6
        hops = np.asarray(hops)
        label = np.argmax(np.asarray(proba), -1)
        acc = (label == ds.y_test[: len(label)]).mean()
        occ = [float((hops > j).mean()) for j in range(8)]
        print(f"CSV,fog_ring,thresh={thresh},us={dt:.0f},acc={acc:.4f},"
              f"mean_hops={hops.mean():.2f},occupancy=" +
              "|".join(f"{o:.2f}" for o in occ))
""")


def run() -> list[str]:
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [f"fog_ring_bench FAILED: {proc.stderr[-500:]}"]
    return [l for l in proc.stdout.splitlines() if l.startswith("CSV")]


if __name__ == "__main__":
    print("\n".join(run()))
