"""FoG ring on a device mesh (§3.2.2 scaled): per-hop wall time + traffic.

Runs the FogEngine ring backend (shard_map + ppermute) on 8 forced host
devices and reports lane occupancy decay (how fast confident lanes die ->
the load self-balancing the paper's queue priority scheme provides), for
both the classic 1-grove-per-shard ring and the generalized
multiple-groves-per-shard placement.  Run as a subprocess to get its own
XLA device count.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import FogEngine, FogPolicy, split
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=1))
    gc = split(rf, 2)
    x = jnp.asarray(ds.x_test[:1024] if len(ds.x_test) >= 1024 else ds.x_test)

    for n_shards in [8, 4]:
        mesh = jax.make_mesh((n_shards,), ("grove",))
        engine = FogEngine(gc, backend="ring", mesh=mesh)
        for thresh in [0.1, 0.3, 0.5]:
            t0 = time.perf_counter()
            res = engine.eval(x, jax.random.key(0),
                              policy=FogPolicy(threshold=thresh, max_hops=8))
            res.proba.block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            hops = np.asarray(res.hops)
            acc = (np.asarray(res.label) == ds.y_test[: len(hops)]).mean()
            occ = [float((hops > j).mean()) for j in range(8)]
            print(f"CSV,fog_ring,shards={n_shards},thresh={thresh},"
                  f"us={dt:.0f},acc={acc:.4f},"
                  f"mean_hops={hops.mean():.2f},occupancy=" +
                  "|".join(f"{o:.2f}" for o in occ))
""")


def run() -> list[str]:
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # forced-host-device scripts must not probe a real TPU: the
             # libtpu worker handshake hangs ~8 min before falling back
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [f"fog_ring_bench FAILED: {proc.stderr[-500:]}"]
    return [l for l in proc.stdout.splitlines() if l.startswith("CSV")]


if __name__ == "__main__":
    print("\n".join(run()))
