"""Ablation (beyond paper): is ADAPTIVITY what earns FoG's energy win?

Compare, at matched mean energy, FoG's confidence-gated allocation against
the static alternative (every input uses the same k trees — "truncated
RF").  For each threshold we compute FoG's mean groves-used g*, then
evaluate a static forest of round(g* x grove_size) trees.  If adaptive >
static at equal accuracy/energy, the paper's mechanism — not merely using
fewer trees — is the source of the saving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import benchmarks.common as common
from benchmarks.common import dataset, forest_for
from repro.core import FogEngine, FogPolicy, fog_energy, split
from repro.forest import forest_votes


def run(datasets=("penbased", "letter")) -> list[str]:
    rows = ["dataset,thresh,fog_acc,fog_mean_trees,static_trees,static_acc,adaptive_gain"]
    for name in datasets:
        ds = dataset(name)
        rf = forest_for(name)
        gc = split(rf, 2)
        engine = FogEngine(gc)
        x = jnp.asarray(ds.x_test)
        for thresh in [0.1, 0.3, 0.5, 0.7]:
            res = engine.eval(x, jax.random.key(0),
                              policy=FogPolicy(threshold=thresh))
            fog_acc = float(np.mean(np.asarray(res.label) == ds.y_test))
            mean_trees = float(np.asarray(res.hops).mean()) * gc.grove_size
            k = max(2, round(mean_trees / gc.grove_size) * gc.grove_size)
            static = rf.slice_trees(0, min(k, rf.n_trees))
            votes = forest_votes(static, x)
            st_acc = float(np.mean(np.asarray(jnp.argmax(votes, -1)) == ds.y_test))
            rows.append(f"{name},{thresh},{fog_acc:.4f},{mean_trees:.1f},"
                        f"{min(k, rf.n_trees)},{st_acc:.4f},"
                        f"{fog_acc - st_acc:+.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
