"""Benchmark runner: one section per paper table/figure (+ beyond-paper).

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints CSV blocks; the roofline table is produced by the dry-run
(launch/dryrun.py) since it needs 512 forced host devices.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of datasets for a fast pass")
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()

    from benchmarks import (
        ablation_adaptive, engine_bench, fig4_topology, fig5_threshold,
        fog_ring_bench, lm_fog_exit, registry_bench, serve_bench,
        table1_accuracy, table1_energy, train_bench,
    )
    import benchmarks.common as common

    if args.quick:
        common.DATASETS = ["penbased", "segmentation"]

    sections = {
        "engine": engine_bench.run,
        "table1_accuracy": table1_accuracy.run,
        "table1_energy": table1_energy.run,
        "fig4_topology": fig4_topology.run,
        "fig5_threshold": lambda: fig5_threshold.run(common.DATASETS),
        "fog_ring": fog_ring_bench.run,
        "ablation_adaptive": ablation_adaptive.run,
        "lm_fog_exit": lm_fog_exit.run,
        # subprocess: forces 4 virtual host devices, which must land
        # before jax initializes (this parent already initialized it)
        "serve": lambda: serve_bench.run(smoke=args.quick),
        # subprocess for the same reason; multi-tenant registry serving
        "registry": lambda: registry_bench.run(smoke=args.quick),
        # host vs device trainer; full mode runs the train_gate
        "train": lambda: train_bench.run(smoke=args.quick),
    }
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for name, fn in sections.items():
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            for row in fn():
                print(row)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, str(e)))
        print(f"----- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"\nFAILED sections: {failures}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark sections passed")


if __name__ == "__main__":
    main()
