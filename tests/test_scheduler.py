"""Continuous-batching scheduler (serve/scheduler.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NO_BUDGET, FogPolicy
from repro.serve.scheduler import ContinuousBatcher, Request


def _mock_decode(n_slots, vocab=16, eos=1):
    """Deterministic mock: token t -> (t+1) % vocab; hops = 1 + slot%3."""
    def decode_fn(tokens, lengths):
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        hops = 1 + np.arange(n_slots) % 3
        return jnp.asarray(logits), jnp.asarray(hops)
    return decode_fn


def test_all_requests_complete():
    n = 4
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    for rid in range(10):
        batcher.submit(Request(rid=rid, prompt=np.asarray([2, 3]),
                               max_new_tokens=5))
    done = batcher.run()
    assert len(done) == 10
    assert all(len(r.generated) == 5 for r in done)
    # deterministic generation: 3 -> 4 -> 5 ...
    assert done[0].generated[:3] == [4, 5, 6]


def test_eos_terminates_early():
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n, eos=1),
                                lambda slot, prompt: len(prompt), eos_id=4)
    batcher.submit(Request(rid=0, prompt=np.asarray([3]), max_new_tokens=50))
    done = batcher.run()
    assert done[0].generated == [4]          # 3 -> 4 == eos


def test_slots_refilled_continuously():
    """More requests than slots: every request still finishes, and the
    batcher never runs more than n_slots concurrently."""
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    for rid in range(7):
        batcher.submit(Request(rid=rid, prompt=np.asarray([0]),
                               max_new_tokens=3))
    steps = 0
    while batcher.queue or batcher.active:
        assert batcher.active <= n
        batcher.step()
        steps += 1
        assert steps < 100
    assert len(batcher.completed) == 7


def test_hops_metering_accumulates():
    n = 3
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=4))
    done = batcher.run()
    assert len(done[0].hops) == 4
    assert all(h >= 1 for h in done[0].hops)


def test_serve_stats_accumulate_and_reset():
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=3))
    batcher.run()
    assert batcher.stats.n_events == 3
    assert not batcher.stats.has_energy      # no governor: hops only
    batcher.stats.reset()
    assert batcher.stats.n_events == 0 and batcher.stats.total_hops == 0
    assert batcher.stats.mean_hops == 0.0


def _mock_policy_decode(n_slots, vocab=16):
    """Policy-aware mock: hops = each lane's threshold * 10 (so tests can
    read back exactly which per-lane vector the batcher assembled)."""
    seen = []

    def decode_fn(tokens, lengths, policy):
        assert isinstance(policy, FogPolicy)
        seen.append((np.asarray(policy.lane_thresholds(n_slots)),
                     np.asarray(policy.lane_budgets(n_slots))))
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        hops = np.round(seen[-1][0] * 10).astype(np.int32)
        return jnp.asarray(logits), jnp.asarray(hops)

    return decode_fn, seen


def test_mixed_qos_per_request_policies():
    """Two QoS tiers in ONE continuous batch: the batcher must assemble the
    slots' scalar policies into per-lane vectors every step, and each
    request's hop accounting must reflect ITS OWN threshold."""
    n = 2
    decode_fn, seen = _mock_policy_decode(n)
    batcher = ContinuousBatcher(
        n, decode_fn, lambda slot, prompt: len(prompt), eos_id=-1,
        default_policy=FogPolicy(threshold=0.3))
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=3,
                           policy=FogPolicy(threshold=0.1)))
    batcher.submit(Request(rid=1, prompt=np.asarray([0]), max_new_tokens=3,
                           policy=FogPolicy(threshold=0.9, hop_budget=2)))
    done = batcher.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].hops == [1, 1, 1]            # thresh 0.1 -> mock hops 1
    assert by_rid[1].hops == [9, 9, 9]            # thresh 0.9 -> mock hops 9
    thr0, bud0 = seen[0]
    np.testing.assert_allclose(thr0, [0.1, 0.9])
    np.testing.assert_array_equal(bud0, [NO_BUDGET, 2])


def test_empty_slots_get_default_policy():
    n = 3
    decode_fn, seen = _mock_policy_decode(n)
    batcher = ContinuousBatcher(
        n, decode_fn, lambda slot, prompt: len(prompt), eos_id=-1,
        default_policy=FogPolicy(threshold=0.5))
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=1))
    batcher.run()
    thr, _ = seen[0]
    np.testing.assert_allclose(thr, [0.5, 0.5, 0.5])  # req slot + 2 empty


def test_per_lane_request_policy_rejected():
    n = 2
    decode_fn, _ = _mock_policy_decode(n)
    batcher = ContinuousBatcher(n, decode_fn,
                                lambda slot, prompt: len(prompt))
    with pytest.raises(ValueError):
        batcher.submit(Request(
            rid=0, prompt=np.asarray([0]),
            policy=FogPolicy(threshold=jnp.asarray([0.1, 0.2]))))


def test_static_knobs_on_request_policy_rejected():
    """max_hops/backend/... select the compiled program — they cannot vary
    per request and must be rejected loudly, not silently dropped."""
    n = 2
    decode_fn, _ = _mock_policy_decode(n)
    batcher = ContinuousBatcher(n, decode_fn,
                                lambda slot, prompt: len(prompt))
    with pytest.raises(ValueError, match="static knobs"):
        batcher.submit(Request(rid=0, prompt=np.asarray([0]),
                               policy=FogPolicy(threshold=0.1, max_hops=2)))
    with pytest.raises(ValueError, match="static knobs"):
        batcher.submit(Request(rid=1, prompt=np.asarray([0]),
                               policy=FogPolicy(backend="pallas")))


def _mock_precision_decode(n_slots, vocab=16):
    """Precision-aware mock: records each dispatch's precision and encodes
    it into hops (fp32 -> 32, int8 -> 8, default/None -> 1), so tests can
    see exactly which program served which slot."""
    calls = []
    code = {None: 1, "fp32": 32, "bf16": 16, "int8": 8}

    def decode_fn(tokens, lengths, policy):
        calls.append(policy.precision)
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        hops = np.full((n_slots,), code[policy.precision], np.int32)
        return jnp.asarray(logits), jnp.asarray(hops)

    return decode_fn, calls


def test_per_request_precision_not_rejected():
    """precision is the one static knob a request may set — the batcher
    handles it by bucketed dispatch instead of rejecting it."""
    n = 2
    decode_fn, _ = _mock_precision_decode(n)
    batcher = ContinuousBatcher(n, decode_fn,
                                lambda slot, prompt: len(prompt))
    batcher.submit(Request(rid=0, prompt=np.asarray([0]),
                           policy=FogPolicy(precision="int8")))   # no raise


def test_mixed_precision_buckets_dispatch_per_group():
    """Two precisions in one continuous batch: one dispatch per distinct
    precision per step, and every request's outputs come from ITS OWN
    precision's program."""
    n = 3
    decode_fn, calls = _mock_precision_decode(n)
    batcher = ContinuousBatcher(
        n, decode_fn, lambda slot, prompt: len(prompt), eos_id=-1,
        default_policy=FogPolicy(threshold=0.3))
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=2,
                           policy=FogPolicy(precision="int8")))
    batcher.submit(Request(rid=1, prompt=np.asarray([0]), max_new_tokens=2,
                           policy=FogPolicy(precision="fp32")))
    batcher.submit(Request(rid=2, prompt=np.asarray([0]), max_new_tokens=2))
    done = batcher.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].hops == [8, 8]         # served by the int8 program
    assert by_rid[1].hops == [32, 32]       # served by the fp32 program
    assert by_rid[2].hops == [1, 1]         # default program
    # 3 groups active for 2 steps -> 6 dispatches, all precisions present
    assert len(calls) == 6
    assert set(calls) == {None, "fp32", "int8"}


def test_homogeneous_precision_costs_one_dispatch():
    """All requests on one precision (or none): exactly one decode dispatch
    per step — bucketing must not tax the common case."""
    n = 2
    decode_fn, calls = _mock_precision_decode(n)
    batcher = ContinuousBatcher(n, decode_fn,
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=3,
                           policy=FogPolicy(precision="int8")))
    batcher.run()
    assert calls == ["int8", "int8", "int8"]


def test_per_lane_default_policy_rejected_at_construction():
    n = 2
    decode_fn, _ = _mock_policy_decode(n)
    with pytest.raises(ValueError):
        ContinuousBatcher(n, decode_fn, lambda slot, prompt: len(prompt),
                          default_policy=FogPolicy(
                              threshold=jnp.asarray([0.1, 0.2])))


def test_legacy_two_arg_decode_fn_still_works():
    """decode_fn(tokens, lengths) callers predate the policy plumbing."""
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    assert not batcher._policy_aware
    batcher.submit(Request(rid=0, prompt=np.asarray([2]), max_new_tokens=2))
    done = batcher.run()
    assert len(done) == 1 and len(done[0].generated) == 2


# ---------------------------------------------------------------------------
# energy governance (the EnergyGovernor control plane)
# ---------------------------------------------------------------------------

def _threshold_driven_decode(n_slots, vocab=16):
    """Governor-visible mock: hops track each lane's threshold (tighter
    threshold -> earlier exit), capped by the lane's hop budget — the same
    monotone response a real forest has."""
    def decode_fn(tokens, lengths, policy):
        thr = np.asarray(policy.lane_thresholds(n_slots))
        bud = np.asarray(policy.lane_budgets(n_slots))
        hops = np.minimum(np.maximum(1, np.round(thr * 10)).astype(np.int64),
                          bud)
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        return jnp.asarray(logits), jnp.asarray(hops)
    return decode_fn


def _governor(budget_nj, base_thresh=0.5, **kw):
    from repro.core import EnergyModel
    from repro.serve.governor import EnergyGovernor, default_ladder
    model = EnergyModel(2, 8, 10, 16)
    ladder = default_ladder(FogPolicy(threshold=base_thresh), model,
                            budget_nj)
    kw.setdefault("window", 4)
    kw.setdefault("patience", 2)
    # long cooldown: a rung measured over budget stays blocked for the
    # whole test run (deterministic steady state)
    kw.setdefault("cooldown", 10_000)
    return EnergyGovernor(ladder, budget_nj, model=model, **kw)


def test_governor_steps_down_ladder_and_holds_budget():
    """The acceptance loop: under a tight budget the governor must walk
    down the ladder (threshold tightening, then the hop-budget rung) until
    the rolling estimate sits under the SLO, and fleet telemetry must show
    priced energy."""
    n = 2
    gov = _governor(budget_nj=0.5)
    batcher = ContinuousBatcher(n, _threshold_driven_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1,
                                governor=gov)
    for rid in range(8):
        batcher.submit(Request(rid=rid, prompt=np.asarray([0]),
                               max_new_tokens=6))
    batcher.run()
    assert gov.transitions, "governor never stepped"
    assert gov.transitions[0][:2] == (0, 1), "first step must tighten"
    assert gov.rolling_nj <= gov.budget_nj          # steady state: under SLO
    assert batcher.stats.has_energy
    assert batcher.stats.n_events > 0


def test_governor_rejects_ungovernable_decode_paths():
    """A governor that can never act must fail loudly, not serve at full
    energy under the illusion of an SLO: legacy two-arg decode_fns are
    rejected at construction, hop-less telemetry on the first step."""
    with pytest.raises(ValueError, match="policy-aware"):
        ContinuousBatcher(2, _mock_decode(2), lambda slot, prompt: 1,
                          governor=_governor(budget_nj=1.0))

    def no_hops(tokens, lengths, policy):
        n = tokens.shape[0]
        logits = np.zeros((n, 16), np.float32)
        return jnp.asarray(logits), None

    b = ContinuousBatcher(2, no_hops, lambda slot, prompt: 1, eos_id=-1,
                          governor=_governor(budget_nj=1.0))
    b.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=2))
    with pytest.raises(ValueError, match="hop telemetry"):
        b.step()


def test_governor_restores_quality_when_headroom_returns():
    from repro.core import EnergyModel, FogPolicy as FP
    from repro.serve.governor import EnergyGovernor
    model = EnergyModel(2, 8, 10, 16)
    gov = EnergyGovernor([FP(threshold=0.5), FP(threshold=0.1)],
                         budget_nj=1.0, model=model, window=4, patience=2,
                         cooldown=8)
    # breach: expensive batches push it down a rung (and the breach is
    # remembered, so an immediate climb is blocked)
    gov.observe(hops=np.full(8, 8)); gov.step()
    assert gov.rung == 1
    # sustained headroom: once the breach evidence goes stale (cooldown),
    # two compliant observations climb back up
    for _ in range(2):
        gov.observe(hops=np.ones(8, np.int64)); gov.step()
    assert gov.rung == 0
    assert len(gov.transitions) == 2


def test_per_request_energy_budget_resolved_via_governor():
    """A Request carrying energy_budget_nj gets the calibrated rung fitting
    that budget, with a hard hop-budget clamp — submitted against a
    governor-less batcher it must fail loudly."""
    n = 2
    gov = _governor(budget_nj=2.0)
    decode_fn = _threshold_driven_decode(n)
    batcher = ContinuousBatcher(n, decode_fn,
                                lambda slot, prompt: len(prompt), eos_id=-1,
                                governor=gov)
    req = Request(rid=0, prompt=np.asarray([0]), max_new_tokens=2,
                  energy_budget_nj=0.4)
    batcher.submit(req)
    assert req.policy is not None
    # 0.4 nJ buys exactly one 271 pJ hop on the 2x8 topology model
    assert int(np.asarray(req.policy.hop_budget)) == 1
    done = batcher.run()
    assert all(h == 1 for h in done[0].hops)        # contract held

    plain = ContinuousBatcher(n, decode_fn, lambda slot, prompt: len(prompt))
    with pytest.raises(ValueError, match="governor"):
        plain.submit(Request(rid=1, prompt=np.asarray([0]),
                             energy_budget_nj=1.0))

    # a ladder built from a fleet default with STATIC knobs (backend,
    # max_hops) must not trip submit()'s static-knob rejection: the
    # resolved per-request contract carries only threshold/budget/precision
    from repro.core import EnergyModel
    from repro.serve.governor import EnergyGovernor, default_ladder
    model = EnergyModel(2, 8, 10, 16)
    base = FogPolicy(threshold=0.5, backend="reference", max_hops=8)
    gov2 = EnergyGovernor(default_ladder(base, model, 0.4), 0.4, model=model)
    b2 = ContinuousBatcher(n, decode_fn, lambda slot, prompt: len(prompt),
                           eos_id=-1, governor=gov2)
    req2 = Request(rid=5, prompt=np.asarray([0]), max_new_tokens=1,
                   energy_budget_nj=0.4)
    b2.submit(req2)                              # no raise
    assert req2.policy.backend is None and req2.policy.max_hops is None
    assert int(np.asarray(req2.policy.hop_budget)) == 1
    with pytest.raises(ValueError, match="not both"):
        batcher.submit(Request(rid=2, prompt=np.asarray([0]),
                               policy=FogPolicy(threshold=0.1),
                               energy_budget_nj=1.0))


# -- serving-layer bug sweep: calling conventions, admission, stats --------

def test_policy_mode_detects_kwonly_partial_and_jit():
    """The positional-count heuristic must not misclassify the common
    wrapper shapes: KEYWORD_ONLY ``*, policy``, functools.partial-bound
    leading args, and jax.jit wrappers (signature follows __wrapped__)."""
    import functools

    import jax as _jax

    from repro.serve.scheduler import _policy_mode, _takes_policy

    def kwonly(tokens, lengths, *, policy):
        return None, None

    def positional(state, tokens, lengths, policy):
        return None, None

    def legacy(tokens, lengths):
        return None, None

    assert _policy_mode(kwonly) == "keyword"
    assert _policy_mode(functools.partial(positional, {})) == "positional"
    assert _policy_mode(_jax.jit(positional, static_argnums=0)) \
        == "positional"
    assert _policy_mode(_jax.jit(legacy)) == "legacy"
    assert _takes_policy(kwonly) and not _takes_policy(legacy)


def test_kwonly_policy_decode_fn_served_policy():
    """A ``decode_fn(tokens, lengths, *, policy)`` must receive the
    assembled per-lane policy (it used to be silently demoted to the
    legacy no-policy path by the 3-positional-params check)."""
    n = 2
    seen = []

    def decode_fn(tokens, lengths, *, policy):
        assert policy is not None
        seen.append(np.asarray(policy.lane_thresholds(n)))
        logits = np.zeros((n, 8), np.float32)
        logits[:, 2] = 1.0
        return jnp.asarray(logits), jnp.ones((n,), jnp.int32)

    b = ContinuousBatcher(n, decode_fn, lambda slot, prompt: len(prompt),
                          eos_id=-1, default_policy=FogPolicy(threshold=0.3))
    b.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=1,
                     policy=FogPolicy(threshold=0.9)))
    b.step()
    assert len(b.completed) == 1
    np.testing.assert_allclose(seen[0], [0.9, 0.3])


def test_admission_reject_sheds_incoming():
    n = 1
    b = ContinuousBatcher(n, _mock_decode(n),
                          lambda slot, prompt: len(prompt), eos_id=-1,
                          max_queue=2, shed_policy="reject")
    admitted = [b.submit(Request(rid=rid, prompt=np.asarray([0]),
                                 max_new_tokens=1)) for rid in range(5)]
    assert admitted == [True, True, False, False, False]
    assert b.stats.n_offered == 5 and b.stats.n_shed == 3
    assert b.stats.shed_rate == pytest.approx(0.6)
    assert [r.rid for r in b.shed_requests] == [2, 3, 4]
    assert all(r.shed for r in b.shed_requests)
    done = b.run()
    assert sorted(r.rid for r in done) == [0, 1]


def test_admission_oldest_evicts_queue_head():
    n = 1
    b = ContinuousBatcher(n, _mock_decode(n),
                          lambda slot, prompt: len(prompt), eos_id=-1,
                          max_queue=2, shed_policy="oldest")
    admitted = [b.submit(Request(rid=rid, prompt=np.asarray([0]),
                                 max_new_tokens=1)) for rid in range(4)]
    assert admitted == [True, True, True, True]    # newcomers always admitted
    assert [r.rid for r in b.shed_requests] == [0, 1]
    done = b.run()
    assert sorted(r.rid for r in done) == [2, 3]


def test_admission_validation():
    n = 1
    with pytest.raises(ValueError, match="max_queue"):
        ContinuousBatcher(n, _mock_decode(n), lambda s, p: len(p),
                          max_queue=0)
    with pytest.raises(ValueError, match="shed_policy"):
        ContinuousBatcher(n, _mock_decode(n), lambda s, p: len(p),
                          shed_policy="drop-newest")


def test_shed_requests_stamp_t_submit_and_tier():
    """Shedding is part of the latency story: a rejected request must still
    carry its submit timestamp, and the shed must land in its own QoS
    tier's counters, not just the fleet total."""
    n = 1
    b = ContinuousBatcher(n, _mock_decode(n),
                          lambda slot, prompt: len(prompt), eos_id=-1,
                          max_queue=2, shed_policy="reject")
    b.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=1,
                     tier="std"))
    b.submit(Request(rid=1, prompt=np.asarray([0]), max_new_tokens=1,
                     tier="std"))
    shed = Request(rid=2, prompt=np.asarray([0]), max_new_tokens=1,
                   tier="bulk")
    assert not b.submit(shed)
    assert shed.shed and shed.t_submit is not None
    done = b.run()
    assert all(r.t_done is not None and r.t_done >= r.t_submit for r in done)
    ts = b.stats.tier_summary()
    assert ts["std"]["n_done"] == 2 and ts["std"]["n_shed"] == 0
    assert ts["bulk"]["n_shed"] == 1 and ts["bulk"]["n_done"] == 0


def test_tier_breakdown_prices_per_tier():
    """Per-tier energy means: the gold tier's expensive threshold must show
    up in ITS tier row, not be averaged away into the fleet mean."""
    n = 2
    gov = _governor(budget_nj=None)
    b = ContinuousBatcher(n, _threshold_driven_decode(n),
                          lambda slot, prompt: len(prompt), eos_id=-1,
                          governor=gov)
    b.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=3,
                     tier="gold", policy=FogPolicy(threshold=0.9)))
    b.submit(Request(rid=1, prompt=np.asarray([0]), max_new_tokens=3,
                     tier="bulk", policy=FogPolicy(threshold=0.1)))
    b.run()
    ts = b.stats.tier_summary()
    assert set(ts) == {"gold", "bulk"}
    for tier in ("gold", "bulk"):
        assert ts[tier]["n_done"] == 1 and ts[tier]["n_events"] == 3
    assert ts["gold"]["mean_energy_nj"] > ts["bulk"]["mean_energy_nj"] > 0
    # the fleet mean sits between the tier means
    fleet = b.stats.mean_energy_nj
    assert ts["bulk"]["mean_energy_nj"] < fleet < ts["gold"]["mean_energy_nj"]


def test_mean_energy_nj_divides_by_priced_events_only():
    """Mixing priced and unpriced updates must not deflate the mean: 4
    events at 2000 pJ plus 4 hops-only events is 2 nJ/event, not 1."""
    from repro.serve.scheduler import ServeStats
    stats = ServeStats()
    stats.update(np.full(4, 3), energy_pj=np.full(4, 2000.0))
    stats.update(np.full(4, 3))                    # unpriced telemetry
    assert stats.n_events == 8 and stats.n_priced == 4
    assert stats.mean_energy_nj == pytest.approx(2.0)
    stats.reset()
    assert stats.n_priced == 0 and stats.mean_energy_nj == 0.0
