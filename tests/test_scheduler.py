"""Continuous-batching scheduler (serve/scheduler.py)."""
import jax.numpy as jnp
import numpy as np

from repro.serve.scheduler import ContinuousBatcher, Request


def _mock_decode(n_slots, vocab=16, eos=1):
    """Deterministic mock: token t -> (t+1) % vocab; hops = 1 + slot%3."""
    def decode_fn(tokens, lengths):
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        hops = 1 + np.arange(n_slots) % 3
        return jnp.asarray(logits), jnp.asarray(hops)
    return decode_fn


def test_all_requests_complete():
    n = 4
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    for rid in range(10):
        batcher.submit(Request(rid=rid, prompt=np.asarray([2, 3]),
                               max_new_tokens=5))
    done = batcher.run()
    assert len(done) == 10
    assert all(len(r.generated) == 5 for r in done)
    # deterministic generation: 3 -> 4 -> 5 ...
    assert done[0].generated[:3] == [4, 5, 6]


def test_eos_terminates_early():
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n, eos=1),
                                lambda slot, prompt: len(prompt), eos_id=4)
    batcher.submit(Request(rid=0, prompt=np.asarray([3]), max_new_tokens=50))
    done = batcher.run()
    assert done[0].generated == [4]          # 3 -> 4 == eos


def test_slots_refilled_continuously():
    """More requests than slots: every request still finishes, and the
    batcher never runs more than n_slots concurrently."""
    n = 2
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    for rid in range(7):
        batcher.submit(Request(rid=rid, prompt=np.asarray([0]),
                               max_new_tokens=3))
    steps = 0
    while batcher.queue or batcher.active:
        assert batcher.active <= n
        batcher.step()
        steps += 1
        assert steps < 100
    assert len(batcher.completed) == 7


def test_hops_metering_accumulates():
    n = 3
    batcher = ContinuousBatcher(n, _mock_decode(n),
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([0]), max_new_tokens=4))
    done = batcher.run()
    assert len(done[0].hops) == 4
    assert all(h >= 1 for h in done[0].hops)
