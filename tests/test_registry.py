"""ModelRegistry + PackCache (repro/registry/): versioned artifacts,
atomic publish/rollback, deterministic canary routing, the VMEM-budgeted
resident pack set, and the serving integration (version pinning across a
hot-swap, per-tenant governor independence behind one dispatcher)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnergyModel, FogPolicy, split
from repro.forest import ForestPack
from repro.registry import ModelRegistry, PackCache
from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer
from repro.serve.governor import EnergyGovernor, TenantLedger
from repro.serve.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)


@pytest.fixture(scope="module")
def pack(gc):
    return ForestPack.from_groves(gc)


# ---------------------------------------------------------------------------
# ModelRegistry: publish / rollback / canary lifecycle
# ---------------------------------------------------------------------------

def test_publish_is_monotonic_and_hot_swaps(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    assert reg.publish("t", pack) == 1
    assert reg.publish("t", pack) == 2          # hot-swap: live flips
    assert reg.tenants() == ["t"]
    assert reg.versions("t") == [1, 2]
    assert reg.live_version("t") == 2
    assert reg.canary("t") is None
    for v in (1, 2):                            # artifacts kept for rollback
        assert reg.artifact_path("t", v).is_file()
    assert (tmp_path / "reg" / "t" / "MANIFEST.json").is_file()


def test_fresh_instance_reloads_manifests(tmp_path, pack):
    root = tmp_path / "reg"
    reg = ModelRegistry(root)
    reg.publish("t", pack)
    reg.publish("t", pack)
    reg2 = ModelRegistry(root)                  # a new serving process
    assert reg2.live_version("t") == 2
    assert reg2.versions("t") == [1, 2]
    loaded, _ = reg2.load("t")
    assert loaded.precision == pack.precision
    np.testing.assert_array_equal(np.asarray(loaded.threshold),
                                  np.asarray(pack.threshold))


def test_tenant_name_and_canary_validation(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    for bad in ("", "a/b", ".hidden", "-x"):
        with pytest.raises(ValueError, match="invalid tenant"):
            reg.publish(bad, pack)
    with pytest.raises(ValueError, match="full publish"):
        reg.publish("t", pack, canary=0.1)      # no live to canary against
    reg.publish("t", pack)
    for frac in (0.0, 1.0, -0.2, 2.0):
        with pytest.raises(ValueError, match="fraction"):
            reg.publish("t", pack, canary=frac)
    with pytest.raises(ValueError, match="unknown tenant"):
        reg.route("ghost", 0)


def test_rollback_default_explicit_and_errors(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    for _ in range(3):
        reg.publish("t", pack)
    assert reg.rollback("t") == 2               # default: previous version
    assert reg.rollback("t", 1) == 1            # explicit target
    with pytest.raises(ValueError, match="nothing older"):
        reg.rollback("t")
    with pytest.raises(ValueError, match="no version"):
        reg.rollback("t", 99)
    # a rollback aborts any active canary: it is a judgment that the
    # newest code path misbehaves
    reg.publish("t", pack, canary=0.5)
    assert reg.canary("t") is not None
    reg.rollback("t", 3)
    assert reg.canary("t") is None
    assert reg.live_version("t") == 3


def test_canary_routing_deterministic_then_promote(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    reg.publish("t", pack, canary=0.3)
    assert reg.live_version("t") == 1           # old live keeps serving
    assert reg.canary("t") == (2, 0.3)
    routes = [reg.route("t", rid) for rid in range(4000)]
    assert set(routes) == {1, 2}
    # pure function of (tenant, rid, manifest): retries never flap
    assert routes == [reg.route("t", rid) for rid in range(4000)]
    frac = np.mean(np.asarray(routes) == 2)
    assert frac == pytest.approx(0.3, abs=0.05)
    assert reg.promote("t") == 2
    assert reg.canary("t") is None
    assert {reg.route("t", rid) for rid in range(100)} == {2}
    with pytest.raises(ValueError, match="no active canary"):
        reg.promote("t")


def test_abort_canary_keeps_artifact(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    reg.publish("t", pack, canary=0.2)
    reg.abort_canary("t")
    assert reg.live_version("t") == 1 and reg.canary("t") is None
    loaded, _ = reg.load("t", 2)                # artifact stays on disk
    assert loaded.table_bytes == pack.table_bytes
    assert reg.publish("t", pack) == 3          # numbering stays monotonic


def test_load_missing_artifact_is_loud(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    reg.artifact_path("t", 1).unlink()
    with pytest.raises(ValueError, match="missing"):
        reg.load("t")


def test_judge_canary_reads_per_version_stats(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    with pytest.raises(ValueError, match="no active canary"):
        reg.judge_canary("t")
    reg.publish("t", pack, canary=0.5)
    reg.stats_for("t", 1).update(np.full(4, 6), energy_pj=np.full(4, 2000.0))
    reg.stats_for("t", 2).update(np.full(4, 2), energy_pj=np.full(4, 500.0))
    j = reg.judge_canary("t")
    assert j["live_version"] == 1 and j["canary_version"] == 2
    assert j["canary_fraction"] == 0.5
    assert j["live"]["n_events"] == 4 and j["canary"]["n_events"] == 4
    assert j["live"]["mean_nj"] == pytest.approx(2.0)
    assert j["canary"]["mean_nj"] == pytest.approx(0.5)
    assert j["delta_nj"] == pytest.approx(-1.5)     # canary is cheaper


# ---------------------------------------------------------------------------
# PackCache: budget, weights, stale-first eviction
# ---------------------------------------------------------------------------

def test_cache_accounting_never_exceeds_budget(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=pack.table_bytes)  # exactly one fp32
    p1 = cache.get("t", 1)
    assert cache.stats.misses == 1
    assert cache.get("t", 1) is p1 and cache.stats.hits == 1
    cache.get("t", 2)                           # overflow: v1 evicted
    assert cache.stats.evictions == 1
    assert cache.keys() == [("t", 2, "fp32")]
    assert cache.bytes_used <= cache.budget_bytes
    assert cache.peak_bytes <= cache.budget_bytes
    # lazy reload after eviction: a miss, not an error
    assert cache.get("t", 1).table_bytes == pack.table_bytes
    assert cache.stats.misses == 3
    assert cache.stats.hit_rate == pytest.approx(1 / 4)


def test_cache_evicts_stale_version_before_hot_weight(tmp_path, pack):
    """A hot-swap's whole point is releasing the old version's tables:
    the demoted version must be the first eviction candidate even when its
    historical traffic weight dwarfs the live version's."""
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=2 * pack.table_bytes)
    for _ in range(10):
        cache.get("t", 1)
    reg.publish("t", pack)                      # v1 is now stale
    cache.get("t", 2)
    for _ in range(5):
        cache.get("t", 1)                       # stale but historically hot
    assert cache.weight_of("t", 1, "fp32") > cache.weight_of("t", 2, "fp32")
    got = cache.get("t", 2, "int8")             # overflow forces eviction
    assert got.precision == "int8"              # astype on the way in
    assert ("t", 1, "fp32") not in cache.keys()
    assert ("t", 2, "fp32") in cache.keys()     # live survives, stale went
    assert cache.stats.evictions == 1
    assert cache.bytes_used <= cache.budget_bytes


def test_cache_seeds_new_entries_at_mean_weight(tmp_path, pack):
    """A fresh entry must compete fairly: seeded at weight 1.0 it would be
    the guaranteed eviction minimum against incumbents' accumulated hit
    counts, thrashing every newly-published version in and out forever."""
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=3 * pack.table_bytes)
    for _ in range(9):
        cache.get("t", 1)                       # weight 9 (1 miss + 8 hits)
    cache.get("t", 2)
    w1, w2 = cache.weight_of("t", 1, "fp32"), cache.weight_of("t", 2, "fp32")
    assert w2 == pytest.approx(w1)              # mean of {v1} = v1's weight
    assert w2 > 5.0                             # not the old 1.0 seeding


def test_cache_oversized_pack_and_ctor_validation(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=pack.table_bytes - 1)
    with pytest.raises(ValueError, match="cache budget"):
        cache.get("t", 1)
    with pytest.raises(ValueError, match="budget_bytes"):
        PackCache(reg, budget_bytes=0)
    for decay in (0.0, 1.5):
        with pytest.raises(ValueError, match="decay"):
            PackCache(reg, budget_bytes=1024, decay=decay)


def test_cache_device_pack_committed_once_dropped_at_eviction(tmp_path, pack):
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=2 * pack.table_bytes)
    dev = jax.devices()[0]
    c1 = cache.device_pack("t", 1, "fp32", 0, dev)
    assert cache.device_pack("t", 1, "fp32", 0, dev) is c1   # cached copy
    assert next(iter(c1.threshold.devices())) == dev
    assert cache.evict("t", 1, "fp32")
    assert not cache.evict("t", 1, "fp32")      # already gone
    c2 = cache.device_pack("t", 1, "fp32", 0, dev)           # fresh placement
    assert c2 is not c1


# ---------------------------------------------------------------------------
# serving integration: buckets, version pinning, ledger independence
# ---------------------------------------------------------------------------

def _bucket_decode(n_slots, vocab=16):
    """Bucket-aware mock: records each dispatch's (model, version) bucket;
    hops track each lane's threshold like the policy mocks do."""
    calls = []

    def decode_fn(tokens, lengths, policy, bucket=None):
        calls.append(bucket)
        thr = np.asarray(policy.lane_thresholds(n_slots))
        nxt = (np.asarray(tokens) + 1) % vocab
        logits = np.zeros((n_slots, vocab), np.float32)
        logits[np.arange(n_slots), nxt] = 1.0
        hops = np.maximum(1, np.round(thr * 10)).astype(np.int64)
        return jnp.asarray(logits), jnp.asarray(hops)

    return decode_fn, calls


def test_request_model_validated_at_submit():
    decode_fn, _ = _bucket_decode(2)
    b = ContinuousBatcher(2, decode_fn, lambda s, p: len(p), eos_id=-1)
    with pytest.raises(ValueError, match="registry"):
        b.submit(Request(rid=0, prompt=np.asarray([0]), model="t"))
    # a pre-set version bypasses routing (no registry needed)
    assert b.submit(Request(rid=1, prompt=np.asarray([0]), model="t",
                            version=1))

    def plain(tokens, lengths, policy):
        return None, None

    b2 = ContinuousBatcher(2, plain, lambda s, p: len(p), eos_id=-1)
    with pytest.raises(ValueError, match="bucket-aware"):
        b2.submit(Request(rid=0, prompt=np.asarray([0]), model="t",
                          version=1))


def test_hot_swap_pins_inflight_versions(tmp_path, pack):
    """Zero-downtime hot-swap: a publish mid-decode must not migrate
    in-flight requests (version pinned at slot assignment) while new
    arrivals route to the new live version — and per-version ServeStats
    split the telemetry accordingly."""
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", pack)
    n = 2
    decode_fn, calls = _bucket_decode(n)
    b = ContinuousBatcher(n, decode_fn, lambda s, p: len(p), eos_id=-1,
                          registry=reg)
    for rid in range(2):
        b.submit(Request(rid=rid, prompt=np.asarray([0]), max_new_tokens=3,
                         model="t"))
    b.step()                                    # slots assigned: pinned to v1
    assert all(s.request.version == 1 for s in b.slots)
    reg.publish("t", pack)                      # hot-swap mid-flight
    for rid in range(2, 4):
        b.submit(Request(rid=rid, prompt=np.asarray([0]), max_new_tokens=3,
                         model="t"))
    done = b.run()
    versions = {r.rid: r.version for r in done}
    assert versions[0] == versions[1] == 1      # in-flight stayed put
    assert versions[2] == versions[3] == 2      # new arrivals on new live
    assert {("t", 1), ("t", 2)} <= set(calls)
    assert reg.stats_for("t", 1).n_events == 6
    assert reg.stats_for("t", 2).n_events == 6


def test_tenant_governors_independent_behind_one_dispatcher():
    """Two ledgered tenants share ONE data-parallel plane: the expensive
    tenant's breach walks ITS OWN ladder down and must neither move the
    frugal tenant's rung nor pollute its rolling estimate; both governors
    still get device-labeled telemetry from the shared dispatcher."""
    model = EnergyModel(2, 8, 10, 16)
    ladder = [FogPolicy(threshold=0.8), FogPolicy(threshold=0.1)]
    def mk(budget):
        return EnergyGovernor(list(ladder), budget, model=model,
                              window=4, patience=2, cooldown=10_000)

    eight_hop_nj = float(np.asarray(model.lane_pj(np.asarray([8]))[0])) * 1e-3
    gov_a = mk(eight_hop_nj * 0.5)              # rung 0 unaffordable
    gov_b = mk(eight_hop_nj * 4.0)              # comfortable at rung 0
    ledger = TenantLedger()
    ledger.add("a", gov_a)
    ledger.add("b", gov_b)

    def factory(index, device, span):
        def decode(tokens, lengths, policy, bucket=None):
            thr = np.asarray(policy.lane_thresholds(span))
            nxt = (np.asarray(tokens) + 1) % 16
            logits = np.zeros((span, 16), np.float32)
            logits[np.arange(span), nxt] = 1.0
            hops = np.maximum(1, np.round(thr * 10)).astype(np.int64)
            return jnp.asarray(logits), jnp.asarray(hops)
        return decode

    disp = DeviceDispatcher(factory, [jax.devices()[0]] * 2)
    b = ContinuousBatcher(4, None, lambda s, p: len(p), eos_id=-1,
                          governor=ledger, dispatcher=disp)
    for rid in range(8):
        b.submit(Request(rid=rid, prompt=np.asarray([0]), max_new_tokens=4,
                         model="a" if rid % 2 == 0 else "b", version=1))
    done = b.run()
    assert len(done) == 8
    # tenant a breached and settled one rung down; tenant b never moved
    assert [t[:2] for t in gov_a.transitions] == [(0, 1)]
    assert gov_a.rung == 1
    assert gov_b.transitions == [] and gov_b.rung == 0
    # b's estimate reflects ONLY its own 8-hop traffic (no cross-tenant
    # averaging with a's post-step-down 1-hop lanes)
    assert gov_b.rolling_nj == pytest.approx(eight_hop_nj)
    # the shared dispatcher labeled both tenants' telemetry per device
    for gov in (gov_a, gov_b):
        summary = gov.device_summary()
        assert {0, 1} <= set(summary)
        assert summary[None]["spread_nj"] == pytest.approx(0.0, abs=1e-12)


def test_registry_mode_server_serves_through_cache(trained, tmp_path):
    """The real thing, small: a registry-mode ForestReplicaServer (no
    built-in model) classifies a tenant's traffic through the VMEM-budgeted
    cache at forest quality, one artifact load for the whole run."""
    ds, rf = trained
    gc2 = split(rf, 2)
    p = ForestPack.from_groves(gc2)
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish("t", p, extra={"n_features_in": ds.x_test.shape[1]})
    cache = PackCache(reg, budget_bytes=2 * p.table_bytes)
    server = ForestReplicaServer(None, ds.x_test.shape[1], backend="fused",
                                 registry=reg, cache=cache)
    disp = DeviceDispatcher(server.factory, [jax.devices()[0]])
    b = ContinuousBatcher(8, None, server.prefill, eos_id=-1,
                          default_policy=FogPolicy(threshold=0.7),
                          dispatcher=disp, registry=reg)
    n = 24
    for rid in range(n):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid], max_new_tokens=1,
                         model="t"))
    done = b.run()
    assert len(done) == n
    assert all(r.version == 1 for r in done)
    preds = np.array([r.generated[0]
                      for r in sorted(done, key=lambda r: r.rid)])
    acc = float((preds == ds.y_test[:n]).mean())
    assert acc > 0.7
    assert all(r.hops[0] >= 1 for r in done)
    assert cache.stats.misses == 1              # one load, then resident
    assert cache.stats.hits >= 2
    assert reg.stats_for("t", 1).n_events == n
