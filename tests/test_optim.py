"""Optimizers, clipping, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.optim import (
    adamw, clip_by_global_norm, ef_compress_grads, global_norm,
    linear_warmup_cosine, sgd,
)
from repro.optim.compression import compress_int8, decompress_int8


def _rosenbrock_min(opt_init, opt_update, steps=400):
    params = {"x": jnp.asarray(-1.0), "y": jnp.asarray(1.5)}
    state = opt_init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: (1 - p["x"]) ** 2 + 5 * (p["y"] - p["x"] ** 2) ** 2)(params)
        params, state = opt_update(g, state, params)
        return params, state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges():
    init, update = adamw(lr=3e-2)
    assert _rosenbrock_min(init, update) < 1e-2


def test_sgd_converges():
    init, update = sgd(lr=2e-3, momentum=0.9)
    assert _rosenbrock_min(init, update, steps=800) < 0.3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below the cap: untouched
    g2 = {"a": jnp.asarray([0.1])}
    out, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), [0.1])


def test_warmup_cosine_schedule():
    lr = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(60)) < 1.0
    assert float(lr(1000)) <= float(lr(60))


@pytest.mark.parametrize("seed,n", [(0, 1), (1, 3), (2, 17), (3, 64), (4, 200)])
def test_int8_roundtrip_bounded_error(seed, n):
    """Deterministic slice of the hypothesis sweep in test_properties.py."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.01, 100))
    q, scale = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-9   # half-ULP of the grid


def test_error_feedback_reduces_bias():
    """EF: averaged over steps, compressed grads converge to true grads."""
    rng = np.random.default_rng(0)
    true = {"w": jnp.asarray(rng.normal(size=(64,)))}
    state = None
    acc = np.zeros(64)
    n = 50
    for _ in range(n):
        deq, state = ef_compress_grads(true, state)
        acc += np.asarray(deq["w"])
    np.testing.assert_allclose(acc / n, np.asarray(true["w"]),
                               rtol=2e-2, atol=2e-3)
