"""Per-kernel allclose vs the pure-jnp oracle, across shape/dtype sweeps.

Randomized property sweeps live in test_properties.py (hypothesis-gated).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _random_forest_arrays(rng, t, depth, C, F):
    n_nodes = 2**depth - 1
    feature = rng.integers(0, F, size=(t, n_nodes)).astype(np.int32)
    threshold = rng.normal(size=(t, n_nodes)).astype(np.float32)
    leaf = rng.dirichlet(np.ones(C), size=(t, 2**depth)).astype(np.float32)
    return feature, threshold, leaf


@pytest.mark.parametrize("t,depth,C,F,B", [
    (1, 1, 2, 3, 4),
    (4, 3, 5, 10, 32),
    (8, 6, 10, 64, 128),
    (16, 8, 26, 617, 256),
    (2, 4, 7, 19, 64),
])
def test_tree_traverse_matches_ref(t, depth, C, F, B):
    rng = np.random.default_rng(42 + t)
    feature, threshold, leaf = _random_forest_arrays(rng, t, depth, C, F)
    x = rng.normal(size=(B, F)).astype(np.float32)
    got = ops.tree_traverse(feature, threshold, leaf, x, block_b=min(64, B))
    want = ref.tree_traverse_ref(jnp.asarray(feature), jnp.asarray(threshold),
                                 jnp.asarray(leaf), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B", [37, 127, 257])
def test_tree_traverse_unaligned_batch(B):
    """B % block_b != 0 (prime batches): the kernel dead-pads the tail block
    and slices back — was a hard `assert B % block_b == 0` before."""
    rng = np.random.default_rng(B)
    feature, threshold, leaf = _random_forest_arrays(rng, 4, 5, 7, 16)
    x = rng.normal(size=(B, 16)).astype(np.float32)
    got = ops.tree_traverse(feature, threshold, leaf, x, block_b=64)
    want = ref.tree_traverse_ref(jnp.asarray(feature), jnp.asarray(threshold),
                                 jnp.asarray(leaf), jnp.asarray(x))
    assert got.shape == (B, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# VMEM budget rejection: oversized forests must raise a clear error, never
# silently miscompile
# ---------------------------------------------------------------------------

def test_tree_traverse_rejects_vmem_oversized_forest():
    """Leaf tables just over the ~16 MB budget: t * 2**d * C * 4 = 15.7 MB
    for t=32, d=12, C=30."""
    from repro.kernels.tree_traverse import tree_traverse_pallas
    t, depth, C, F, B = 32, 12, 30, 8, 128
    feature = jnp.zeros((t, 2**depth - 1), jnp.int32)
    threshold = jnp.zeros((t, 2**depth - 1), jnp.float32)
    leaf = jnp.zeros((t, 2**depth, C), jnp.float32)
    x = jnp.zeros((B, F), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        tree_traverse_pallas(feature, threshold, leaf, x, block_b=128)


def test_fused_fog_rejects_vmem_oversized_field():
    """The fused kernel pins EVERY grove table; the whole field must clear
    the budget (8 groves x 4 trees x 2**10 leaves x 120 classes = 15.7 MB)."""
    from repro.kernels.fused_fog import fused_fog_pallas
    O, G, t, depth, C, F, B = 1, 8, 4, 10, 120, 8, 64
    feature = jnp.zeros((O, G, t, 2**depth - 1), jnp.int32)
    threshold = jnp.zeros((O, G, t, 2**depth - 1), jnp.float32)
    leaf = jnp.zeros((O, G, t, 2**depth, C), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        fused_fog_pallas(feature, threshold, leaf,
                         jnp.zeros((B, F), jnp.float32),
                         jnp.zeros((B,), jnp.int32),
                         jnp.full((B,), 0.3, jnp.float32),
                         jnp.full((B,), 2**31 - 1, jnp.int32),
                         max_hops=G, block_b=64)


def test_fused_fog_matches_engine_reference():
    """Direct kernel-level check on random tables (no trained forest): one
    launch == the reference backend, bit-exact hops."""
    from repro.core.grove import GroveCollection
    from repro.core.engine import FogEngine
    from repro.core.policy import FogPolicy
    rng = np.random.default_rng(21)
    G, t, depth, C, F, B = 6, 3, 4, 5, 12, 83
    feature = rng.integers(0, F, size=(G, t, 2**depth - 1)).astype(np.int32)
    threshold = rng.normal(size=(G, t, 2**depth - 1)).astype(np.float32)
    leaf = rng.dirichlet(np.ones(C), size=(G, t, 2**depth)).astype(np.float32)
    gc = GroveCollection(jnp.asarray(feature), jnp.asarray(threshold),
                         jnp.asarray(leaf))
    x = jnp.asarray(rng.normal(size=(B, F)).astype(np.float32))
    key = jax.random.key(0)
    pol = FogPolicy(threshold=0.25, max_hops=G)
    want = FogEngine(gc).eval(x, key, policy=pol)
    got = FogEngine(gc, backend="fused", block_b=32).eval(x, key, policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(got.label),
                                  np.asarray(want.label))
    np.testing.assert_allclose(np.asarray(got.proba), np.asarray(want.proba),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# packed (bf16/int8) tables: in-kernel dequantize must match the dequantize-
# up-front oracle bit-for-bit, and the VMEM rejection must name the remedies
# ---------------------------------------------------------------------------

def _packed_grove(rng, t, depth, C, F, precision):
    from repro.core.grove import GroveCollection
    from repro.forest.pack import ForestPack
    n_nodes = 2**depth - 1
    feature = rng.integers(0, F, size=(1, t, n_nodes)).astype(np.int32)
    threshold = rng.normal(size=(1, t, n_nodes)).astype(np.float32)
    # sprinkle the complete-tree padding sentinel (+inf = always go left)
    threshold[0, :, n_nodes // 2:] = np.inf
    leaf = rng.dirichlet(np.ones(C), size=(1, t, 2**depth)).astype(np.float32)
    gc = GroveCollection(jnp.asarray(feature), jnp.asarray(threshold),
                         jnp.asarray(leaf))
    return ForestPack.from_groves(gc, precision)


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_tree_traverse_packed_matches_dequantized_ref(precision):
    rng = np.random.default_rng(17)
    pack = _packed_grove(rng, t=4, depth=5, C=7, F=16, precision=precision)
    x = rng.normal(size=(83, 16)).astype(np.float32)
    got = ops.tree_traverse(pack.feature[0, 0], pack.threshold[0, 0],
                            pack.leaf[0, 0], x,
                            pack.thr_scale[0, 0], pack.leaf_scale[0, 0],
                            block_b=32)
    feat, thr, leaf = pack.dequantize()
    want = ref.tree_traverse_ref(feat[0, 0], thr[0, 0], leaf[0, 0],
                                 jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_fused_fog_packed_matches_reference_backend(precision):
    """One packed launch == the reference backend evaluating the same pack:
    bit-identical hops/labels, equal probabilities."""
    from repro.core.engine import FogEngine
    from repro.core.grove import GroveCollection
    from repro.core.policy import FogPolicy
    from repro.forest.pack import ForestPack
    rng = np.random.default_rng(23)
    G = 6
    feature = rng.integers(0, 12, size=(G, 3, 15)).astype(np.int32)
    threshold = rng.normal(size=(G, 3, 15)).astype(np.float32)
    threshold[:, :, 10:] = np.inf
    leaf = rng.dirichlet(np.ones(5), size=(G, 3, 16)).astype(np.float32)
    gc = GroveCollection(jnp.asarray(feature), jnp.asarray(threshold),
                         jnp.asarray(leaf))
    pack = ForestPack.from_groves(gc, precision)
    x = jnp.asarray(rng.normal(size=(83, 12)).astype(np.float32))
    key = jax.random.key(0)
    pol = FogPolicy(threshold=0.25, max_hops=G)
    want = FogEngine(pack).eval(x, key, policy=pol)
    got = FogEngine(pack, backend="fused", block_b=32).eval(x, key,
                                                            policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(got.label),
                                  np.asarray(want.label))
    np.testing.assert_allclose(np.asarray(got.proba), np.asarray(want.proba),
                               rtol=1e-6, atol=1e-7)


def test_vmem_rejection_reports_bytes_and_remedies():
    """Satellite contract: the over-budget ValueError states required vs
    available bytes and suggests chunk_b and precision=\"int8\"."""
    from repro.kernels.fused_fog import fused_fog_pallas
    from repro.kernels.tree_traverse import tree_traverse_pallas
    O, G, t, depth, C, F, B = 1, 8, 4, 10, 120, 8, 64
    feature = jnp.zeros((O, G, t, 2**depth - 1), jnp.int32)
    threshold = jnp.zeros((O, G, t, 2**depth - 1), jnp.float32)
    leaf = jnp.zeros((O, G, t, 2**depth, C), jnp.float32)
    with pytest.raises(ValueError) as ei:
        fused_fog_pallas(feature, threshold, leaf,
                         jnp.zeros((B, F), jnp.float32),
                         jnp.zeros((B,), jnp.int32),
                         jnp.full((B,), 0.3, jnp.float32),
                         jnp.full((B,), 2**31 - 1, jnp.int32),
                         max_hops=G, block_b=64)
    msg = str(ei.value)
    for needle in ["MiB", "usable", "chunk_b", 'precision="int8"']:
        assert needle in msg, (needle, msg)
    with pytest.raises(ValueError) as ei:
        tree_traverse_pallas(jnp.zeros((32, 2**12 - 1), jnp.int32),
                             jnp.zeros((32, 2**12 - 1), jnp.float32),
                             jnp.zeros((32, 2**12, 30), jnp.float32),
                             jnp.zeros((B, F), jnp.float32), block_b=64)
    msg = str(ei.value)
    for needle in ["MiB", "usable", 'precision="int8"']:
        assert needle in msg, (needle, msg)


def test_int8_field_fits_where_fp32_does_not():
    """The acceptance scenario: a field whose fp32 tables exceed the VMEM
    budget evaluates un-chunked through the fused kernel once packed int8."""
    from repro.core.engine import FogEngine
    from repro.core.grove import GroveCollection
    from repro.core.policy import FogPolicy
    from repro.kernels.tree_traverse import VMEM_BUDGET
    rng = np.random.default_rng(5)
    G, t, depth, C, F, B = 8, 4, 10, 120, 8, 48
    gc = GroveCollection(
        jnp.asarray(rng.integers(0, F, size=(G, t, 2**depth - 1)),
                    jnp.int32),
        jnp.asarray(rng.normal(size=(G, t, 2**depth - 1)), jnp.float32),
        jnp.asarray(rng.dirichlet(np.ones(C), size=(G, t, 2**depth)),
                    jnp.float32))
    eng = FogEngine(gc, backend="fused", block_b=16)
    assert eng.tables.pack("fp32").table_bytes >= VMEM_BUDGET
    assert eng.tables.pack("int8").table_bytes < VMEM_BUDGET
    x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    pol = FogPolicy(threshold=0.25, max_hops=G, precision="int8")
    got = eng.eval(x, jax.random.key(1), policy=pol)
    want = FogEngine(gc).eval(x, jax.random.key(1), policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(got.label),
                                  np.asarray(want.label))


@pytest.mark.parametrize("B,C", [(4, 2), (32, 10), (256, 26), (128, 7), (64, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_top2_confidence_matches_ref(B, C, dtype):
    rng = np.random.default_rng(B + C)
    prob = jnp.asarray(rng.dirichlet(np.ones(C), size=B), dtype)
    got = ops.top2_confidence(prob, block_b=min(64, B))
    want = ref.top2_confidence_ref(prob)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-6)


def test_top2_confidence_unaligned_batch():
    """B % block_b != 0: zero-padded tail blocks, margins sliced back."""
    rng = np.random.default_rng(9)
    prob = jnp.asarray(rng.dirichlet(np.ones(6), size=45), jnp.float32)
    got = ops.top2_confidence(prob, block_b=16)
    want = ref.top2_confidence_ref(prob)
    assert got.shape == (45,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_top2_handles_ties():
    prob = jnp.asarray([[0.4, 0.4, 0.2], [1.0, 0.0, 0.0], [1 / 3] * 3])
    got = ops.top2_confidence(prob, block_b=3)
    np.testing.assert_allclose(np.asarray(got), [0.0, 1.0, 0.0], atol=1e-7)


@pytest.mark.parametrize("B,C", [(8, 3), (64, 10), (256, 26)])
def test_grove_aggregate_matches_ref(B, C):
    rng = np.random.default_rng(7)
    prob_acc = jnp.asarray(rng.random((B, C)), jnp.float32)
    contrib = jnp.asarray(rng.dirichlet(np.ones(C), size=B), jnp.float32)
    live = jnp.asarray(rng.random(B) > 0.3)
    hops = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    thresh = jnp.float32(0.15)
    got = ops.grove_aggregate(prob_acc, contrib, live, hops, thresh,
                              block_b=min(64, B))
    want = ref.grove_aggregate_ref(prob_acc, contrib, live, hops, thresh)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), rtol=1e-6, atol=1e-6)


def test_grove_aggregate_unaligned_batch():
    """B that does not divide block_b: the kernel dead-pads the tail block
    and slices back — was a hard assert before the engine unification."""
    rng = np.random.default_rng(3)
    B, C = 37, 5
    prob_acc = jnp.asarray(rng.random((B, C)), jnp.float32)
    contrib = jnp.asarray(rng.dirichlet(np.ones(C), size=B), jnp.float32)
    live = jnp.asarray(rng.random(B) > 0.5)
    hops = jnp.asarray(rng.integers(0, 4, B), jnp.int32)
    got = ops.grove_aggregate(prob_acc, contrib, live, hops,
                              jnp.float32(0.2), block_b=16)
    want = ref.grove_aggregate_ref(prob_acc, contrib, live, hops,
                                   jnp.float32(0.2))
    for g, w in zip(got, want):
        assert g.shape == w.shape
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_grove_aggregate_tie_and_dead_lanes():
    """m1 == m2 ties must give margin 0 (keep hopping); dead lanes must not
    accumulate, count hops, or resurrect."""
    prob_acc = jnp.asarray([[0.4, 0.4, 0.2],   # exact tie, live
                            [0.8, 0.1, 0.1],   # confident, live
                            [0.5, 0.5, 0.0]],  # dead lane
                           jnp.float32)
    contrib = jnp.zeros((3, 3), jnp.float32)
    live = jnp.asarray([True, True, False])
    hops = jnp.asarray([0, 0, 2], jnp.int32)
    prob, hops2, live2, margin = ops.grove_aggregate(
        prob_acc, contrib, live, hops, jnp.float32(0.3), block_b=3)
    np.testing.assert_allclose(np.asarray(margin[:2]), [0.0, 0.7], atol=1e-6)
    assert bool(live2[0]) is True        # tie -> margin 0 -> keeps hopping
    assert bool(live2[1]) is False       # confident -> exits
    assert bool(live2[2]) is False       # dead stays dead
    np.testing.assert_array_equal(np.asarray(hops2), [1, 1, 2])
    np.testing.assert_allclose(np.asarray(prob[2]), np.asarray(prob_acc[2]))


from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.layers import flash_attention as flash_jnp


@pytest.mark.parametrize("B,Sq,Sk,H,K,D,Dv,causal", [
    (1, 8, 8, 2, 1, 4, 4, True),
    (2, 64, 64, 4, 2, 16, 16, True),
    (2, 128, 128, 8, 8, 32, 32, True),
    (1, 64, 64, 4, 1, 32, 16, True),    # MQA + Dv != D (MLA-style)
    (2, 64, 64, 4, 2, 16, 16, False),
])
def test_flash_attention_pallas_matches_ref(B, Sq, Sk, H, K, D, Dv, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, K, Dv)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, blk_q=32, blk_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_jnp_matches_ref():
    """The pure-JAX blocked path (used in the dry-run) vs the oracle."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    got = flash_jnp(q, k, v, causal=True, blk_q=16, blk_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


from repro.kernels.ssd_chunk import ssd_chunk_pallas


@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 1, 8, 1, 4, 4),
    (2, 2, 16, 3, 8, 8),
    (1, 4, 32, 5, 16, 16),
    (2, 2, 64, 2, 32, 32),
])
def test_ssd_chunk_matches_ref(B, nc, Q, H, P, N):
    rng = np.random.default_rng(B * 100 + Q)
    xbar = jnp.asarray(rng.normal(size=(B, nc, Q, H, P)), jnp.float32)
    # negative log-decays, like softplus(dt) * (-exp(A_log))
    a = jnp.asarray(-rng.uniform(0.01, 0.5, size=(B, nc, H, Q)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, nc, Q, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, nc, Q, N)), jnp.float32)
    y, st = ssd_chunk_pallas(xbar, a, Bm, Cm)
    y_ref, st_ref = ref.ssd_chunk_ref(xbar, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-5, atol=1e-5)


def test_ssd_chunk_consistent_with_mamba_layer():
    """Kernel output plugged into the inter-chunk recurrence must equal the
    pure-jnp ssd_chunked end to end."""
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(5)
    B, S, H, P, N, Q = 2, 64, 3, 8, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y_want, final_want = ssd_chunked(x, dt, A, Bm, Cm, Q)

    nc = S // Q
    a = (dt * A[None, None, :]).reshape(B, nc, Q, H).transpose(0, 1, 3, 2)
    xbar = (x * dt[..., None]).reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    y_diag, states = ssd_chunk_pallas(xbar, a, Bc, Cc)

    # inter-chunk recurrence (same as models/mamba2.py)
    cum = jnp.cumsum(a, axis=-1)
    chunk_decay = jnp.exp(cum[..., -1])
    def step(s_prev, inp):
        st, dec = inp
        return s_prev * dec[:, :, None, None] + st, s_prev
    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    final, prev = jax.lax.scan(step, s0,
                               (states.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", Cc, jnp.exp(cum), prev)
    y = (y_diag + y_off).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_kernel_backend_equivalence():
    """ssd_chunked(use_kernels=True) == jnp path, including final state."""
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(11)
    B, S, H, P, N, Q = 2, 64, 4, 8, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y0, s0 = ssd_chunked(x, dt, A, Bm, Cm, Q, use_kernels=False)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, Q, use_kernels=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused-kernel VMEM accounting, interpret resolution, live-lane compaction
# ---------------------------------------------------------------------------

def test_vmem_lane_bytes_accounting():
    """Byte-exact per-lane model: fp32 row + two [O, C] fp32 accumulators +
    [t] x (depth + 2) int32 walk state + five 4-byte scalars + the int8
    live mask at ONE byte (the historical bug charged it four)."""
    from repro.kernels.fused_fog import vmem_lane_bytes
    got = vmem_lane_bytes(n_heads=2, n_classes=10, grove_size=3, depth=6,
                          n_features=16)
    words = 16 + 2 * 2 * 10 + 3 * (6 + 2) + 5
    assert got == 4 * words + 1
    # one extra lane of an int8-masked field must cost an ODD byte count —
    # a multiple of 4 would mean the mask is charged at scalar width again
    assert got % 4 == 1


def test_fit_block_b_aligned():
    """fit_block_b rounds DOWN to a lane-tiling multiple of 8 (731-style
    raw quotients defeat TPU sublane tiling), keeps sub-8 slivers
    unrounded, and its modeled footprint stays under the budget."""
    from repro.kernels.fused_fog import (LANE_ALIGN, fit_block_b,
                                         vmem_working_set)
    from repro.kernels.tree_traverse import VMEM_BUDGET
    rng = np.random.default_rng(5)
    pack = _packed_grove(rng, t=4, depth=5, C=7, F=16, precision="fp32")
    tables = pack.layout("fused")
    fit = fit_block_b(*tables, n_features=16)
    assert fit > 0 and fit % LANE_ALIGN == 0
    assert vmem_working_set(*tables, block_b=fit,
                            n_features=16) < VMEM_BUDGET
    # the next aligned size up must NOT fit (the fit is maximal)
    assert vmem_working_set(*tables, block_b=fit + LANE_ALIGN,
                            n_features=16) >= VMEM_BUDGET


def test_resolve_interpret_derives_from_backend(monkeypatch):
    """None derives from jax.default_backend(): interpreted off-TPU,
    compiled Mosaic on TPU; an explicit bool always wins."""
    import repro.kernels.tree_traverse as tt
    assert tt.resolve_interpret(True) is True
    assert tt.resolve_interpret(False) is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert tt.resolve_interpret(None) is True
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert tt.resolve_interpret(None) is False


def test_fused_fog_interpret_default_not_hardcoded(monkeypatch):
    """fused_fog_pallas(interpret=None) must consult the runtime backend —
    the historical interpret=True default would silently serve the
    interpreted kernel on a real TPU.  On this CPU container the derived
    flag is True, and pallas_call must receive exactly that."""
    import repro.kernels.fused_fog as ff
    seen = {}
    real = ff.pl.pallas_call

    def spy(*a, **kw):
        seen["interpret"] = kw.get("interpret")
        return real(*a, **kw)

    monkeypatch.setattr(ff.pl, "pallas_call", spy)
    rng = np.random.default_rng(7)
    pack = _packed_grove(rng, t=3, depth=4, C=5, F=10, precision="fp32")
    x = jnp.asarray(rng.normal(size=(16, 10)).astype(np.float32))
    ff.fused_fog_pallas(*pack.layout("fused")[:3], x,
                        jnp.zeros((16,), jnp.int32),
                        jnp.full((16,), 0.3, jnp.float32),
                        jnp.full((16,), 2**31 - 1, jnp.int32),
                        *pack.layout("fused")[3:], max_hops=1, block_b=16)
    assert seen["interpret"] is True          # derived: CPU container
    ff.fused_fog_pallas(*pack.layout("fused")[:3], x,
                        jnp.zeros((16,), jnp.int32),
                        jnp.full((16,), 0.3, jnp.float32),
                        jnp.full((16,), 2**31 - 1, jnp.int32),
                        *pack.layout("fused")[3:], max_hops=1, block_b=16,
                        interpret=True)
    assert seen["interpret"] is True          # explicit override honored


def test_fused_compaction_bit_identical_kernel_level():
    """Live-lane compaction is a pure relocation: hops AND probabilities
    must be bit-identical with it on vs off, at a prime batch size that
    forces dead-lane padding, across precisions."""
    from repro.core.grove import GroveCollection
    from repro.core.policy import NO_BUDGET
    from repro.forest.pack import ForestPack
    rng = np.random.default_rng(31)
    G, t, depth, C, F, B = 6, 3, 4, 5, 12, 149   # prime B
    feature = rng.integers(0, F, size=(G, t, 2**depth - 1)).astype(np.int32)
    threshold = rng.normal(size=(G, t, 2**depth - 1)).astype(np.float32)
    leaf = rng.dirichlet(np.ones(C), size=(G, t, 2**depth)).astype(np.float32)
    gc = GroveCollection(jnp.asarray(feature), jnp.asarray(threshold),
                         jnp.asarray(leaf))
    x = jnp.asarray(rng.normal(size=(B, F)).astype(np.float32))
    start = jax.random.randint(jax.random.key(2), (B,), 0, G)
    thresh = jnp.full((B,), 0.25, jnp.float32)
    budget = jnp.full((B,), NO_BUDGET, jnp.int32)
    for precision in ("fp32", "int8"):
        pack = ForestPack.from_groves(gc, precision)
        tables = pack.layout("fused")
        p0, h0 = ops.fused_fog(*tables[:3], x, start, thresh, budget,
                               *tables[3:], max_hops=G, block_b=32,
                               compact=False)
        p1, h1 = ops.fused_fog(*tables[:3], x, start, thresh, budget,
                               *tables[3:], max_hops=G, block_b=32,
                               compact=True)
        np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
