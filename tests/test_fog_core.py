"""Behaviour tests for the paper's core claims (Algorithms 1-2, §3.2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    fog_eval, fog_eval_lazy, fog_energy, gc_train, maxdiff,
    maxdiff_multioutput, rf_report, split, top2,
)
from repro.core.grove import grove_predict_proba
from repro.data import make_dataset
from repro.forest import (
    TensorForest, TrainConfig, forest_proba, rf_predict, train_random_forest,
)


# the (dataset, forest) pair comes from the session-scoped ``trained``
# fixture in conftest.py — trained once for the whole suite.


# --------------------------------------------------------------- MaxDiff ---
def test_maxdiff_basic():
    ar = jnp.asarray([[0.32, 0.35, 0.33]])
    np.testing.assert_allclose(maxdiff(ar), [0.35 - 0.33], atol=1e-7)


def test_maxdiff_paper_example():
    # §3.2.2 worked example: G0+G1 averaged -> {0.3, 0.4, 0.3}, conf 0.1
    p0 = jnp.asarray([0.32, 0.35, 0.33])
    p1 = jnp.asarray([0.28, 0.45, 0.27])
    avg = (p0 + p1) / 2
    assert float(maxdiff(avg[None])[0]) >= 0.1 - 1e-6
    assert int(jnp.argmax(avg)) == 1


def test_maxdiff_multioutput_min_rule():
    ar = jnp.asarray([[[0.9, 0.1], [0.55, 0.45]]])  # margins 0.8, 0.1
    np.testing.assert_allclose(maxdiff_multioutput(ar), [0.1], atol=1e-6)


@pytest.mark.parametrize("C,B,seed", [(2, 1, 0), (5, 16, 1), (40, 64, 2),
                                      (3, 33, 3), (26, 7, 4)])
def test_top2_sorted_oracle(C, B, seed):
    """Deterministic slice of the hypothesis sweep in test_properties.py."""
    rng = np.random.default_rng(seed)
    ar = jnp.asarray(rng.normal(size=(B, C)).astype(np.float32))
    m1, m2 = top2(ar)
    srt = np.sort(np.asarray(ar), axis=-1)
    np.testing.assert_allclose(np.asarray(m1), srt[:, -1], atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), srt[:, -2], atol=1e-6)


# ------------------------------------------------------------ Algorithm 1 ---
def test_split_partition(trained):
    """Groves are disjoint and cover the forest (Algorithm 1)."""
    _, rf = trained
    gc = split(rf, 4)
    assert gc.n_groves == 4 and gc.grove_size == 4
    back = gc.as_forest()
    np.testing.assert_array_equal(np.asarray(back.feature), np.asarray(rf.feature))
    np.testing.assert_array_equal(np.asarray(back.leaf), np.asarray(rf.leaf))


def test_grove_predict_proba_matches_subforest(trained):
    ds, rf = trained
    gc = split(rf, 4)
    x = jnp.asarray(ds.x_test[:32])
    for g in range(gc.n_groves):
        want = forest_proba(gc.grove(g), x)
        got = grove_predict_proba(gc, jnp.full((32,), g, jnp.int32), x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------ Algorithm 2 ---
def test_fog_max_threshold_uses_every_grove(trained):
    """thresh > 1 forces every input through every grove (FoG_max == RF-like)."""
    ds, rf = trained
    gc = split(rf, 2)
    res = fog_eval(gc, jnp.asarray(ds.x_test[:256]), jax.random.key(0),
                   1.1, gc.n_groves)
    assert (np.asarray(res.hops) == gc.n_groves).all()
    # FoG_max probability == full-forest predict_proba (grove mean of means,
    # equal grove sizes => same as forest mean)
    want = forest_proba(rf, jnp.asarray(ds.x_test[:256]))
    np.testing.assert_allclose(np.asarray(res.proba), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fog_hops_monotone_in_threshold(trained):
    """Higher confidence demand => more groves per input (Fig 5 mechanism)."""
    ds, rf = trained
    gc = split(rf, 2)
    x = jnp.asarray(ds.x_test[:512])
    hops = []
    for thr in [0.05, 0.2, 0.5, 0.9]:
        res = fog_eval(gc, x, jax.random.key(0), thr, gc.n_groves)
        hops.append(float(np.asarray(res.hops).mean()))
    assert hops == sorted(hops), hops
    assert hops[0] < hops[-1]


def test_fog_energy_below_rf_at_moderate_threshold(trained):
    """The paper's headline: FoG_opt ~1.5x below conventional RF energy."""
    ds, rf = trained
    gc = split(rf, 2)
    res = fog_eval(gc, jnp.asarray(ds.x_test), jax.random.key(0), 0.3, gc.n_groves)
    e_fog = fog_energy(np.asarray(res.hops), gc.grove_size, gc.depth,
                       gc.n_classes, ds.n_features)
    e_rf = rf_report(len(ds.y_test), rf.n_trees, rf.depth, gc.n_classes)
    assert e_fog.per_example_nj < e_rf.per_example_nj
    # and accuracy must stay comparable (within 3.2% per paper)
    rf_acc = float(np.mean(np.asarray(rf_predict(rf, jnp.asarray(ds.x_test))) == ds.y_test))
    fog_acc = float(np.mean(np.asarray(res.label) == ds.y_test))
    assert fog_acc >= rf_acc - 0.05


def test_fog_lazy_matches_scan(trained):
    ds, rf = trained
    gc = split(rf, 4)
    x = jnp.asarray(ds.x_test[:128])
    a = fog_eval(gc, x, jax.random.key(3), 0.25, gc.n_groves)
    b = fog_eval_lazy(gc, x, jax.random.key(3), 0.25, gc.n_groves)
    np.testing.assert_array_equal(np.asarray(a.hops), np.asarray(b.hops))
    np.testing.assert_allclose(np.asarray(a.proba), np.asarray(b.proba),
                               rtol=1e-6, atol=1e-6)


def test_max_hops_cap(trained):
    ds, rf = trained
    gc = split(rf, 2)
    res = fog_eval(gc, jnp.asarray(ds.x_test[:64]), jax.random.key(0), 1.1, 3)
    assert (np.asarray(res.hops) == 3).all()


def test_gc_train_end_to_end():
    ds = make_dataset("segmentation")
    gc = gc_train(8, 2, ds.x_train, ds.y_train, ds.n_classes,
                  TrainConfig(max_depth=6, seed=2))
    assert gc.n_groves == 4
    res = fog_eval(gc, jnp.asarray(ds.x_test), jax.random.key(0), 0.3, 4)
    acc = float(np.mean(np.asarray(res.label) == ds.y_test))
    assert acc > 0.7, acc


# ------------------------------------------------------- budgeted training ---
def test_budgeted_training_prefers_cheap_features():
    ds = make_dataset("penbased")
    cost = np.ones(ds.n_features)
    cost[: ds.n_features // 2] = 100.0   # first half expensive
    cfg = dataclasses.replace(TrainConfig(n_trees=8, max_depth=5, seed=3),
                              feature_cost=cost, cost_weight=0.002)
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes, cfg)
    used = np.asarray(rf.feature).ravel()
    thr = np.asarray(rf.threshold).ravel()
    real = used[np.isfinite(thr)]        # padded nodes have thr=inf
    frac_expensive = (real < ds.n_features // 2).mean()
    assert frac_expensive < 0.35, frac_expensive


def test_fog_multioutput_min_rule_gates_on_weakest_output(
        ds_penbased, rf8_penbased, rf8_noisy_penbased):
    """Paper footnote 1: confidence = Min over outputs of the margins; a
    single uncertain output must keep the input hopping."""
    from repro.core import fog_eval_multioutput
    ds = ds_penbased
    # output 0: the real labels; output 1: noisy labels (hard task)
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:256])

    res_mo = fog_eval_multioutput(gcs, x, jax.random.key(0), 0.3, 4)
    assert res_mo.proba.shape == (256, 2, ds.n_classes)
    assert res_mo.label.shape == (256, 2)
    # single-output on the easy head alone exits earlier than the joint
    res_easy = fog_eval(gcs[0], x, jax.random.key(0), 0.3, 4)
    assert float(np.asarray(res_mo.hops).mean()) >= \
        float(np.asarray(res_easy.hops).mean())
    # easy-head accuracy survives the joint gating
    acc = float(np.mean(np.asarray(res_mo.label[:, 0]) == ds.y_test[:256]))
    assert acc > 0.8, acc
