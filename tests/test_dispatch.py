"""Data-parallel serving plane (serve/dispatch.py).

In-process tests drive a DeviceDispatcher over N logical replicas (which
may share the host's single physical CPU device — the routing/scatter
contract is device-count-agnostic); the subprocess test forces 4 real XLA
host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count`` and
asserts each replica's outputs were actually computed on its own device.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FogPolicy, split
from repro.serve.dispatch import (DeviceDispatcher, ForestReplicaServer,
                                  replicate)
from repro.serve.scheduler import ContinuousBatcher, Request


def _stamp_factory(calls=None):
    """Mock replica: logits one-hot on the replica index (argmax == which
    device served the lane), hops = index + 1, and an optional record of
    (index, thresholds, precision) per decode call."""
    def factory(index, device, span):
        def decode(tokens, lengths, policy):
            if calls is not None:
                calls.append((index,
                              np.array(policy.threshold, np.float32,
                                       copy=True),
                              policy.precision))
            logits = np.zeros((span, 8), np.float32)
            logits[:, index] = 1.0
            hops = np.full((span,), index + 1)
            return jnp.asarray(logits), jnp.asarray(hops)
        return decode
    return factory


def _four_replicas():
    dev = jax.devices()[0]
    return [dev] * 4


def test_replicate_puts_one_copy_per_device():
    devs = _four_replicas()
    copies = replicate({"w": jnp.arange(3)}, devs)
    assert len(copies) == 4
    for c in copies:
        assert next(iter(c["w"].devices())) == devs[0]


def test_bind_span_and_rebind_rules():
    disp = DeviceDispatcher(_stamp_factory(), _four_replicas())
    with pytest.raises(ValueError, match="divide evenly"):
        disp.bind(10)
    disp.bind(8)
    assert disp.span == 2
    disp.bind(8)                       # idempotent
    with pytest.raises(ValueError, match="cannot rebind"):
        disp.bind(16)
    assert disp.device_of(0) == 0 and disp.device_of(7) == 3
    np.testing.assert_array_equal(disp.lane_devices([0, 3, 6]), [0, 1, 3])


def test_dispatch_routes_only_intersecting_devices():
    calls = []
    disp = DeviceDispatcher(_stamp_factory(calls), _four_replicas())
    disp.bind(8)
    tokens = np.zeros(8, np.int32)
    lengths = np.ones(8, np.int32)
    pend = disp.dispatch(tokens, lengths, FogPolicy(threshold=0.5), [0, 1, 5])
    # lanes 0,1 -> device 0; lane 5 -> device 2; devices 1,3 untouched
    assert sorted(p.device for p in pend) == [0, 2]
    assert sorted(i for i, _, _ in calls) == [0, 2]
    logits, hops, drained = disp.harvest(8)
    assert isinstance(logits, np.ndarray) and isinstance(hops, np.ndarray)
    assert len(drained) == 2
    # only the group's lanes are scattered; untouched lanes stay zero
    np.testing.assert_allclose(logits[0], logits[1])
    assert logits[0].argmax() == 0 and logits[5].argmax() == 2
    assert hops[0] == 1 and hops[5] == 3 and hops[2] == 0


def test_per_lane_policy_vectors_sliced_per_span():
    calls = []
    disp = DeviceDispatcher(_stamp_factory(calls), _four_replicas())
    disp.bind(8)
    thr = np.linspace(0.1, 0.8, 8, dtype=np.float32)
    pol = FogPolicy(threshold=thr,
                    hop_budget=np.arange(1, 9, dtype=np.int32))
    disp.dispatch(np.zeros(8, np.int32), np.ones(8, np.int32), pol,
                  list(range(8)))
    disp.harvest(8)
    assert len(calls) == 4
    for index, seen_thr, _ in calls:
        np.testing.assert_allclose(seen_thr, thr[2 * index:2 * index + 2])


def test_harvest_without_dispatch_raises():
    disp = DeviceDispatcher(_stamp_factory(), _four_replicas())
    disp.bind(8)
    with pytest.raises(ValueError, match="nothing dispatched"):
        disp.harvest(8)


def test_inconsistent_hop_telemetry_raises():
    def factory(index, device, span):
        def decode(tokens, lengths, policy):
            logits = jnp.zeros((span, 4))
            return logits, (None if index == 1 else jnp.ones((span,)))
        return decode
    disp = DeviceDispatcher(factory, _four_replicas())
    disp.bind(8)
    disp.dispatch(np.zeros(8, np.int32), np.ones(8, np.int32),
                  FogPolicy(), list(range(8)))
    with pytest.raises(ValueError, match="inconsistent"):
        disp.harvest(8)


def test_batcher_dispatch_mode_groups_precisions_across_devices():
    """Three precision groups in one step: each group dispatches once per
    intersecting device, and every lane harvests logits/hops from its OWN
    group's replica call."""
    calls = []
    disp = DeviceDispatcher(_stamp_factory(calls), _four_replicas())
    b = ContinuousBatcher(8, None, lambda slot, prompt: len(prompt),
                          eos_id=-1, default_policy=FogPolicy(threshold=0.5),
                          dispatcher=disp)
    precs = [None, "int8", "bf16", None, "int8", "bf16", None, None]
    for rid, p in enumerate(precs):
        pol = None if p is None else FogPolicy(threshold=0.5, precision=p)
        b.submit(Request(rid=rid, prompt=np.asarray([3]), max_new_tokens=1,
                         policy=pol))
    b.step()
    assert len(b.completed) == 8
    # span=2: None lanes {0,3,6,7} -> devices {0,1,3}; int8 {1,4} ->
    # {0,2}; bf16 {2,5} -> {1,2} — one call per (group, touched device)
    by_prec = {}
    for _, _, prec in calls:
        by_prec[prec] = by_prec.get(prec, 0) + 1
    assert by_prec == {None: 3, "int8": 2, "bf16": 2}
    # harvest attribution: lane i is served by device i // span
    for r in b.completed:
        assert r.generated == [r.rid // 2]
        assert r.hops == [r.rid // 2 + 1]
    devs = {p.device for p in b.last_dispatches}
    assert devs == {0, 1, 2, 3}


def test_empty_lane_none_group_folds_into_real_group():
    """When every default-precision lane is EMPTY, the batcher must not
    spend decode dispatches on the None group — the empty lanes fold into
    a real precision group and their outputs are discarded."""
    calls = []
    disp = DeviceDispatcher(_stamp_factory(calls), [jax.devices()[0]] * 2)
    b = ContinuousBatcher(4, None, lambda slot, prompt: len(prompt),
                          eos_id=-1, dispatcher=disp)
    for rid in range(2):
        b.submit(Request(rid=rid, prompt=np.asarray([1]), max_new_tokens=1,
                         policy=FogPolicy(precision="int8")))
    b.step()
    # slots 0,1 int8; slots 2,3 empty+None -> folded: one group, and only
    # the devices the folded lane set touches are dispatched
    assert {prec for _, _, prec in calls} == {"int8"}
    assert len(b.completed) == 2


def test_forest_replica_server_end_to_end(trained):
    """The paper's serving workload against logical replicas: every request
    classified, hop telemetry positive, predictions match the plain
    single-program forest evaluation's quality."""
    ds, rf = trained
    gc = split(rf, 2)
    server = ForestReplicaServer(gc, ds.x_test.shape[1], backend="fused",
                                 precisions=("fp32", "int8"))
    devs = [jax.devices()[0]] * 2
    disp = DeviceDispatcher(server.factory, devs)
    n = 32
    b = ContinuousBatcher(n, None, server.prefill, eos_id=-1,
                          default_policy=FogPolicy(threshold=0.7),
                          dispatcher=disp)
    rows = ds.x_test[:n]
    labels = ds.y_test[:n]
    for rid in range(n):
        pol = (FogPolicy(threshold=0.7, precision="int8") if rid % 4 == 0
               else None)
        b.submit(Request(rid=rid, prompt=rows[rid], max_new_tokens=1,
                         policy=pol))
    done = b.run()
    assert len(done) == n
    preds = np.array([r.generated[0] for r in sorted(done,
                                                     key=lambda r: r.rid)])
    acc = float((preds == labels).mean())
    assert acc > 0.7                    # forest-quality, not token noise
    assert all(r.hops[0] >= 1 for r in done)
    # both replicas served their own spans
    assert {p.device for p in b.last_dispatches} <= {0, 1}


def test_forest_replica_server_validates_rows(trained):
    ds, rf = trained
    server = ForestReplicaServer(split(rf, 2), ds.x_test.shape[1])
    with pytest.raises(ValueError, match="not bound"):
        server.prefill(0, ds.x_test[0])
    disp = DeviceDispatcher(server.factory, [jax.devices()[0]])
    disp.bind(4)
    with pytest.raises(ValueError, match="features"):
        server.prefill(0, ds.x_test[0][:3])


def test_forest_replica_server_energy_models(trained):
    ds, rf = trained
    server = ForestReplicaServer(split(rf, 2), ds.x_test.shape[1],
                                 precisions=("fp32", "int8"))
    m32 = server.energy_model("fp32")
    m8 = server.energy_model("int8")
    assert server.energy_model() is m32            # default + cached
    hops = np.full(8, 3)
    assert float(np.asarray(m8.lane_pj(hops)).sum()) < float(
        np.asarray(m32.lane_pj(hops)).sum())


_SUBPROC = r"""
import jax, numpy as np
import jax.numpy as jnp
from repro.core import FogPolicy
from repro.launch.mesh import serve_devices
from repro.serve.dispatch import DeviceDispatcher

devs = serve_devices(4)
assert len({d.id for d in devs}) == 4

def factory(index, device, span):
    def decode(tokens, lengths, policy):
        base = jax.device_put(jnp.asarray(tokens, jnp.float32), device)
        logits = jnp.stack([base, jnp.full((span,), float(index))], axis=1)
        hops = jax.device_put(jnp.full((span,), index + 1), device)
        return logits, hops
    return decode

disp = DeviceDispatcher(factory, devs)
disp.bind(16)
tokens = np.arange(16, dtype=np.int32)
disp.dispatch(tokens, np.ones(16, np.int32), FogPolicy(threshold=0.5),
              list(range(16)))
logits, hops, pend = disp.harvest(16)
assert sorted({p.device for p in pend}) == [0, 1, 2, 3]
for p in pend:
    assert next(iter(p.hops.devices())) == devs[p.device]
np.testing.assert_allclose(logits[:, 0], np.arange(16))
np.testing.assert_array_equal(hops, np.repeat([1, 2, 3, 4], 4))
print("MULTIDEV-OK")
"""


def test_real_four_device_dispatch_subprocess():
    """The real thing: 4 forced XLA host devices, each replica's outputs
    computed (and verified resident) on its own device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    repo = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(repo / "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "MULTIDEV-OK" in proc.stdout
