"""Fused-kernel autotuner: analytic seeding, measured sweep, cache."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grove import split
from repro.core.policy import NO_BUDGET
from repro.forest.pack import ForestPack
from repro.forest.train import TrainConfig, train_random_forest
from repro.kernels import autotune
from repro.kernels.fused_fog import LANE_ALIGN, fit_block_b


@pytest.fixture(scope="module")
def tiny():
    """(pack, x, start, thresh, budget) on a small synthetic forest."""
    rng = np.random.default_rng(3)
    X = rng.standard_normal((200, 10)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.int32) + 2 * (X[:, 2] > 0).astype(np.int32)
    rf = train_random_forest(X, y, 4, TrainConfig(n_trees=8, max_depth=4,
                                                  seed=0))
    gc = split(rf, 2)
    pack = ForestPack.from_groves(gc, "fp32")
    B = 96
    x = jnp.asarray(X[:B])
    start = jax.random.randint(jax.random.key(0), (B,), 0, gc.n_groves)
    thresh = jnp.full((B,), 0.3, jnp.float32)
    budget = jnp.full((B,), NO_BUDGET, jnp.int32)
    return pack, x, start, thresh, budget


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_analytic_seed_is_aligned_and_capped(tiny):
    pack, x, *_ = tiny
    seed = autotune.analytic_block_b(pack, x.shape[1])
    assert seed % LANE_ALIGN == 0
    assert LANE_ALIGN <= seed <= autotune.SEED_CAP
    fit = fit_block_b(*pack.layout("fused"), n_features=x.shape[1])
    assert seed <= max(fit, LANE_ALIGN)


def test_best_config_untuned_returns_analytic(tiny):
    pack, x, *_ = tiny
    cfg = autotune.best_config(pack, x.shape[1])
    assert cfg.source == "analytic"
    assert cfg.measured_s is None
    assert cfg.block_b == autotune.analytic_block_b(pack, x.shape[1])


def test_tune_measures_and_caches(tiny):
    pack, x, start, thresh, budget = tiny
    won = autotune.tune(pack, x, start, thresh, budget,
                        max_hops=pack.n_groves, repeats=1,
                        blocks=[32, 64], persist=False)
    assert won.source == "measured"
    assert won.measured_s > 0
    assert won.block_b in (32, 64)
    # the engine-facing lookup now returns the measured winner
    hit = autotune.best_config(pack, x.shape[1])
    assert hit == won
    # a different field signature is unaffected
    other = autotune.best_config(pack, x.shape[1] + 1)
    assert other.source == "analytic"


def test_candidate_blocks_aligned_and_descending(tiny):
    pack, x, *_ = tiny
    blocks = autotune.candidate_blocks(pack, x.shape[1], int(x.shape[0]))
    assert blocks, "feasible pack must yield candidates"
    assert all(b % LANE_ALIGN == 0 for b in blocks)
    assert blocks == sorted(blocks, reverse=True)
    assert blocks[-1] >= LANE_ALIGN


def test_cache_file_roundtrip(tiny, tmp_path, monkeypatch):
    pack, x, start, thresh, budget = tiny
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    won = autotune.tune(pack, x, start, thresh, budget,
                        max_hops=pack.n_groves, repeats=1, blocks=[32])
    saved = json.loads(path.read_text())
    assert len(saved) == 1
    (cfg,) = saved.values()
    assert cfg["block_b"] == won.block_b and cfg["compact"] == won.compact
    # a fresh process (cleared in-memory cache) reloads the file winner
    autotune.clear_cache()
    hit = autotune.best_config(pack, x.shape[1])
    assert hit.source == "cache-file"
    assert (hit.block_b, hit.compact) == (won.block_b, won.compact)


def test_hist_analytic_seed_scatter_on_interpret():
    """On an interpreted backend (CPU CI) the analytic histogram seed must
    be scatter-everywhere: matmul_max_r == 0, runnable tile sizes."""
    from repro.kernels.tree_traverse import resolve_interpret
    cfg = autotune.analytic_hist_config(8, 6, 16, 17, 10)
    assert cfg.source == "analytic" and cfg.measured_s is None
    assert cfg.block_n > 0 and cfg.block_r > 0 and cfg.block_f >= 1
    if resolve_interpret(None):
        assert cfg.matmul_max_r == 0
    # untuned lookup answers immediately with the seed
    assert autotune.best_hist_config(8, 6, 16, 17, 10) == cfg


def test_hist_tune_measures_and_caches():
    won = autotune.tune_histogram(2, 3, 4, 5, 3, n_samples=256, repeats=1,
                                  persist=False)
    assert won.source == "measured"
    assert won.measured_s > 0
    hit = autotune.best_hist_config(2, 3, 4, 5, 3)
    assert hit == won
    # a different trainer signature still gets the analytic seed
    assert autotune.best_hist_config(2, 4, 4, 5, 3).source == "analytic"


def test_cache_file_mixed_fused_and_hist_entries(tiny, tmp_path,
                                                 monkeypatch):
    """One cache file holds both entry kinds; each reloads as its own
    config type keyed by its own signature."""
    pack, x, start, thresh, budget = tiny
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    fused = autotune.tune(pack, x, start, thresh, budget,
                          max_hops=pack.n_groves, repeats=1, blocks=[32])
    hist = autotune.tune_histogram(2, 3, 4, 5, 3, n_samples=256, repeats=1)
    assert len(json.loads(path.read_text())) == 2
    autotune.clear_cache()                    # "fresh process"
    h = autotune.best_hist_config(2, 3, 4, 5, 3)
    f = autotune.best_config(pack, x.shape[1])
    assert h.source == "cache-file" and f.source == "cache-file"
    assert (h.block_n, h.matmul_max_r) == (hist.block_n, hist.matmul_max_r)
    assert (f.block_b, f.compact) == (fused.block_b, fused.compact)


def test_grow_consults_best_hist_config(ds_penbased, monkeypatch):
    """grow_forest must route its tile sizes through the shared best-config
    table (the same lookup discipline as the serving engine)."""
    from repro.forest.grow import grow_forest

    calls = []
    real = autotune.best_hist_config

    def spy(*args):
        calls.append(args)
        return real(*args)

    monkeypatch.setattr(autotune, "best_hist_config", spy)
    ds = ds_penbased
    grow_forest(ds.x_train[:400], ds.y_train[:400], ds.n_classes,
                TrainConfig(n_trees=2, max_depth=3, seed=0,
                            trainer="device"))
    assert len(calls) == 1
    n_trees, depth, n_features, n_bins, n_classes = calls[0]
    assert (n_trees, depth, n_features, n_classes) == (2, 3, 16,
                                                       ds.n_classes)
    assert n_bins >= 2


def test_engine_consults_autotune_when_block_b_unset(tiny, monkeypatch):
    """FogEngine(block_b=None) + fused must route through best_config."""
    from repro.core.engine import FogEngine

    pack, x, *_ = tiny
    calls = []
    real = autotune.best_config

    def spy(p, f):
        calls.append((p.precision, f))
        return real(p, f)

    monkeypatch.setattr(autotune, "best_config", spy)
    eng = FogEngine(pack, backend="fused")
    assert eng.block_b is None
    eng.eval(x, jax.random.key(0))
    assert calls == [("fp32", x.shape[1])]
