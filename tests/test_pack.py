"""ForestPack (forest/pack.py): dtype packing, byte accounting, derived
layouts, quantization error bounds, and versioned save/load artifacts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FogEngine, FogPolicy, GroveCollection, split
from repro.core.energy import fog_energy, tree_bytes
from repro.forest import PACK_FORMAT_VERSION, PRECISIONS, ForestPack


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)


def test_fp32_pack_stores_training_arrays_verbatim(gc):
    pack = ForestPack.from_groves(gc)
    assert pack.precision == "fp32"
    assert (pack.n_heads, pack.n_groves, pack.grove_size) == (
        1, gc.n_groves, gc.grove_size)
    assert (pack.depth, pack.n_classes) == (gc.depth, gc.n_classes)
    np.testing.assert_array_equal(np.asarray(pack.feature[0]),
                                  np.asarray(gc.feature))
    np.testing.assert_array_equal(np.asarray(pack.threshold[0]),
                                  np.asarray(gc.threshold))
    np.testing.assert_array_equal(np.asarray(pack.leaf[0]),
                                  np.asarray(gc.leaf))


def test_table_bytes_counts_packed_widths(gc):
    packs = {p: ForestPack.from_groves(gc, p) for p in PRECISIONS}
    for p, pack in packs.items():
        want = sum(int(a.nbytes) for a in (pack.feature, pack.threshold,
                                           pack.leaf, pack.thr_scale,
                                           pack.leaf_scale))
        assert pack.table_bytes == want
    # threshold+leaf shrink 2x / 4x; feature+scales stay fp32/int32
    assert packs["bf16"].table_bytes < packs["fp32"].table_bytes
    assert packs["int8"].table_bytes < packs["bf16"].table_bytes
    assert packs["int8"].threshold.dtype == jnp.int8
    assert packs["bf16"].leaf.dtype == jnp.bfloat16


def test_unknown_precision_rejected(gc):
    with pytest.raises(ValueError, match="precision"):
        ForestPack.from_groves(gc, "fp16")
    with pytest.raises(ValueError, match="precision"):
        FogPolicy(precision="fp64")
    with pytest.raises(ValueError, match="precision"):
        FogEngine(gc, precision="int4")


def test_int8_dequant_error_is_grid_bounded(gc):
    """Half-ULP of the per-tree grid: |dequant - fp32| <= 0.5 * scale for
    leaves and finite thresholds; the ±inf padding sentinels survive
    exactly (the "always go left" complete-tree nodes)."""
    pack = ForestPack.from_groves(gc, "int8")
    _, thr_dq, leaf_dq = pack.dequantize()
    thr = np.asarray(gc.threshold)
    thr_dq = np.asarray(thr_dq[0])
    finite = np.isfinite(thr)
    np.testing.assert_array_equal(thr_dq[~finite], thr[~finite])
    ts = np.broadcast_to(np.asarray(pack.thr_scale[0]), thr.shape)
    assert (np.abs(thr_dq[finite] - thr[finite])
            <= 0.5 * ts[finite] + 1e-7).all()
    leaf_err = np.abs(np.asarray(leaf_dq[0]) - np.asarray(gc.leaf))
    ls = np.broadcast_to(np.asarray(pack.leaf_scale[0]),
                         leaf_err.shape)
    assert (leaf_err <= 0.5 * ls + 1e-7).all()


def test_to_groves_round_trips_fp32(gc):
    back = ForestPack.from_groves(gc).to_groves()
    assert len(back) == 1
    np.testing.assert_array_equal(np.asarray(back[0].threshold),
                                  np.asarray(gc.threshold))
    np.testing.assert_array_equal(np.asarray(back[0].leaf),
                                  np.asarray(gc.leaf))


def test_astype_repack_and_idempotence(gc):
    pack8 = ForestPack.from_groves(gc, "int8")
    assert pack8.astype("int8") is pack8
    again = pack8.astype("fp32").astype("int8")
    np.testing.assert_array_equal(np.asarray(again.threshold),
                                  np.asarray(pack8.threshold))
    np.testing.assert_array_equal(np.asarray(again.leaf),
                                  np.asarray(pack8.leaf))


def test_ring_layout_matches_legacy_reorder_and_caches(gc):
    from repro.core.fog_ring import reorder_tables
    pack = ForestPack.from_groves(gc)
    tables = pack.layout("ring", 2)
    assert tables is pack.layout("ring", 2)        # cached per (name, n)
    legacy = reorder_tables(gc, 2)
    for got, want in zip(tables[:3], legacy):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError, match="layout"):
        pack.layout("torus")


def test_fused_layout_is_canonical_storage(gc):
    pack = ForestPack.from_groves(gc, "int8")
    feat, thr, leaf, ts, ls = pack.layout("fused")
    assert feat is pack.feature and thr is pack.threshold
    assert ts is pack.thr_scale and ls is pack.leaf_scale


def test_mismatched_heads_rejected(gc):
    gc2 = GroveCollection(gc.feature, gc.threshold, gc.leaf[..., :-1])
    with pytest.raises(ValueError, match="identical table shapes"):
        ForestPack.from_groves((gc, gc2))


def test_pack_is_a_pytree(gc):
    pack = ForestPack.from_groves(gc, "int8")
    leaves, treedef = jax.tree.flatten(pack)
    assert len(leaves) == 5
    back = jax.tree.unflatten(treedef, leaves)
    assert back.precision == "int8"
    np.testing.assert_array_equal(np.asarray(back.threshold),
                                  np.asarray(pack.threshold))


def test_engine_adopts_pack_and_its_precision(gc, trained):
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:64])
    key = jax.random.key(0)
    pack = ForestPack.from_groves(gc, "int8")
    eng = FogEngine(pack, backend="fused")
    assert eng.precision == "int8"
    assert eng.tables.pack("int8") is pack         # adopted, not rebuilt
    want = FogEngine(gc, precision="int8").eval(x, key, 0.3)
    got = eng.eval(x, key, 0.3)
    np.testing.assert_array_equal(np.asarray(got.label),
                                  np.asarray(want.label))
    np.testing.assert_array_equal(np.asarray(got.hops),
                                  np.asarray(want.hops))


@pytest.mark.parametrize("precision", list(PRECISIONS))
def test_save_load_round_trip_bitwise(gc, tmp_path, precision):
    pack = ForestPack.from_groves(gc, precision)
    path = pack.save(tmp_path / f"m_{precision}.npz", extra={"note": "hi"})
    loaded, extra = ForestPack.load_with_meta(path)
    assert extra == {"note": "hi"}
    assert loaded.precision == precision
    assert loaded.threshold.dtype == pack.threshold.dtype
    for a, b in zip(jax.tree.leaves(pack), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_rejects_future_and_foreign_artifacts(gc, tmp_path):
    pack = ForestPack.from_groves(gc)
    path = pack.save(tmp_path / "m.npz")
    with np.load(path) as z:
        fields = dict(z)
    fields["format_version"] = np.int64(PACK_FORMAT_VERSION + 1)
    future = tmp_path / "future.npz"
    with open(future, "wb") as f:
        np.savez(f, **fields)
    with pytest.raises(ValueError, match="format"):
        ForestPack.load(future)
    foreign = tmp_path / "foreign.npz"
    with open(foreign, "wb") as f:
        np.savez(f, whatever=np.zeros(3))
    with pytest.raises(ValueError, match="format_version"):
        ForestPack.load(foreign)


def test_load_rejects_truncated_and_mislabeled_artifacts(gc, tmp_path):
    """A corrupt artifact must fail with a schema error naming the missing
    fields (and the required list), never a raw KeyError deep in unpacking;
    an unknown precision label is equally loud."""
    pack = ForestPack.from_groves(gc)
    path = pack.save(tmp_path / "m.npz")
    with np.load(path) as z:
        fields = dict(z)
    truncated = dict(fields)
    del truncated["leaf"], truncated["thr_scale"]
    trunc = tmp_path / "trunc.npz"
    with open(trunc, "wb") as f:
        np.savez(f, **truncated)
    with pytest.raises(ValueError, match=r"missing fields.*leaf.*thr_scale"):
        ForestPack.load(trunc)
    mislabeled = dict(fields)
    mislabeled["precision"] = np.str_("fp64")
    bad = tmp_path / "badprec.npz"
    with open(bad, "wb") as f:
        np.savez(f, **mislabeled)
    with pytest.raises(ValueError, match="supported table dtype"):
        ForestPack.load(bad)


def test_energy_model_reads_packed_bytes():
    """int8 node entries are 5 bytes vs fp32's 8: the energy report must
    fall accordingly (and fp32 must reproduce the original accounting)."""
    assert tree_bytes(6, 10, "fp32") == (2**6 - 1) * 8.0 + 2**6 * 10
    assert tree_bytes(6, 10, "int8") < tree_bytes(6, 10, "bf16") < \
        tree_bytes(6, 10, "fp32")
    hops = np.full(64, 3)
    e = {p: fog_energy(hops, 2, 6, 10, 16, p).per_example_nj
         for p in PRECISIONS}
    assert e["int8"] < e["bf16"] < e["fp32"]


def test_policy_precision_is_static_metadata(gc):
    """precision must live in the pytree aux (jit cache key), not the
    traced data, and survive replace()."""
    pol = FogPolicy(threshold=0.3, precision="int8")
    _, treedef = jax.tree.flatten(pol)
    assert "int8" in str(treedef)
    assert pol.replace(threshold=0.5).precision == "int8"
    assert "precision" in pol.static_overrides
