"""FogClassifier facade (repro/sklearn.py): fit/predict round trip, policy
overrides, and the profile() energy accounting."""
import numpy as np
import pytest

from repro.core import FogPolicy
from repro.sklearn import FogClassifier


@pytest.fixture(scope="module")
def fitted(ds_penbased):
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    return ds, clf.fit(ds.x_train, ds.y_train)


def test_fit_predict_round_trip(fitted):
    """The acceptance contract: fit(X, y).predict(X) round-trips on the
    quickstart dataset and profile() reports mean hops + nJ/classification."""
    ds, clf = fitted
    labels = clf.predict(ds.x_test)
    assert labels.shape == (len(ds.y_test),)
    acc = float((labels == ds.y_test).mean())
    assert acc > 0.85, acc
    prof = clf.profile()
    assert prof["n_classified"] == len(ds.y_test)
    assert prof["mean_hops"] >= 1.0
    assert prof["energy_nj_per_classification"] > 0.0
    assert sum(prof["hops_histogram"].values()) == prof["n_classified"]


def test_predict_proba_and_score(fitted):
    ds, clf = fitted
    proba = clf.predict_proba(ds.x_test[:64])
    assert proba.shape == (64, ds.n_classes)
    np.testing.assert_allclose(proba.sum(axis=-1), 1.0, rtol=1e-5)
    assert clf.score(ds.x_test, ds.y_test) > 0.85


def test_predict_is_deterministic(fitted):
    ds, clf = fitted
    a = clf.predict(ds.x_test[:128])
    b = clf.predict(ds.x_test[:128])
    np.testing.assert_array_equal(a, b)


def test_fused_backend_facade_parity(fitted):
    """backend='fused' through the sklearn facade: identical labels and
    per-call policies still override (the engine pass-through contract)."""
    ds, clf = fitted
    fused = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1,
                          backend="fused")
    fused.fit(ds.x_train, ds.y_train)
    np.testing.assert_array_equal(fused.predict(ds.x_test[:200]),
                                  clf.predict(ds.x_test[:200]))
    # per-call policy override may itself re-select the backend
    a = fused.predict(ds.x_test[:64],
                      policy=FogPolicy(threshold=0.3, backend="reference"))
    b = clf.predict(ds.x_test[:64], policy=FogPolicy(threshold=0.3))
    np.testing.assert_array_equal(a, b)


def test_policy_override_trades_energy(fitted):
    """A cheaper per-call policy must lower hops (the paper's Fig-5 knob),
    without retraining or rebuilding anything."""
    ds, clf = fitted
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=0.05))
    cheap = clf.profile()["mean_hops"]
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=0.9))
    rich = clf.profile()["mean_hops"]
    assert cheap < rich


def test_hop_budget_policy_caps_energy(fitted):
    ds, clf = fitted
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=1.1, hop_budget=2))
    prof = clf.profile()
    assert prof["mean_hops"] == 2.0               # budget binds every lane
    assert set(prof["hops_histogram"]) == {2}


def test_reset_profile(fitted):
    ds, clf = fitted
    clf.predict(ds.x_test[:32])
    assert clf.profile()["n_classified"] > 0
    clf.reset_profile()
    assert clf.profile()["n_classified"] == 0


def test_quantize_switches_default_precision(fitted):
    """quantize('int8') swaps packed tables without retraining, stays
    within 1% of fp32 accuracy, and reports a cheaper energy profile."""
    ds = fitted[0]
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    clf.fit(ds.x_train, ds.y_train)
    acc32 = clf.score(ds.x_test, ds.y_test)
    clf.reset_profile()
    clf.predict(ds.x_test)
    nj32 = clf.profile()["energy_nj_per_classification"]
    assert clf.quantize("int8") is clf
    assert clf.engine_.precision == "int8"
    acc8 = clf.score(ds.x_test, ds.y_test)
    assert acc8 >= acc32 - 0.01
    clf.reset_profile()
    clf.predict(ds.x_test)
    nj8 = clf.profile()["energy_nj_per_classification"]
    assert nj8 < nj32
    with pytest.raises(ValueError):
        clf.quantize("fp64")


def test_save_load_serves_identically(fitted, tmp_path):
    """The acceptance contract: save/load round-trips a trained model and
    the loaded estimator serves — identical labels at the saved precision,
    working score/profile, no retraining."""
    ds, clf = fitted
    path = clf.save(tmp_path / "model.npz")
    clf2 = FogClassifier.load(path)
    np.testing.assert_array_equal(clf2.predict(ds.x_test[:256]),
                                  clf.predict(ds.x_test[:256]))
    assert clf2.score(ds.x_test, ds.y_test) > 0.85
    assert clf2.profile()["n_classified"] > 0

    path8 = clf.save(tmp_path / "model8.npz", precision="int8")
    clf8 = FogClassifier.load(path8)
    assert clf8.precision == "int8"
    assert clf8.engine_.tables.pack("int8").precision == "int8"
    want = clf.predict(ds.x_test[:256],
                       policy=clf.policy.replace(precision="int8"))
    np.testing.assert_array_equal(clf8.predict(ds.x_test[:256]), want)


def test_save_persists_default_policy(ds_penbased, tmp_path):
    """The default FogPolicy travels with the artifact: a loaded model must
    predict under the trained knobs, not FogPolicy() defaults."""
    import jax.numpy as jnp
    ds = ds_penbased
    pol = FogPolicy(threshold=0.9, max_hops=4, hop_budget=3, lazy=True)
    clf = FogClassifier(n_trees=8, grove_size=2, max_depth=5, seed=2,
                        policy=pol)
    clf.fit(ds.x_train, ds.y_train)
    path = clf.save(tmp_path / "pol.npz")
    clf2 = FogClassifier.load(path)
    assert clf2.policy == pol
    np.testing.assert_array_equal(clf2.predict(ds.x_test[:200]),
                                  clf.predict(ds.x_test[:200]))
    clf2.reset_profile(); clf.reset_profile()
    clf.predict(ds.x_test[:200]); clf2.predict(ds.x_test[:200])
    assert clf2.profile()["mean_hops"] == clf.profile()["mean_hops"]
    # per-lane default policies are batch-shaped and must refuse to save
    clf.policy = FogPolicy(threshold=jnp.asarray([0.1, 0.9]))
    with pytest.raises(ValueError, match="per-lane"):
        clf.save(tmp_path / "bad.npz")


def test_quantize_overrides_policy_pinned_precision(ds_penbased):
    """A default policy that pins precision must not silently defeat
    quantize(): the pin is re-pointed at the new precision."""
    ds = ds_penbased
    clf = FogClassifier(n_trees=8, grove_size=2, max_depth=5, seed=2,
                        policy=FogPolicy(threshold=0.3, precision="fp32"))
    clf.fit(ds.x_train, ds.y_train)
    clf.quantize("int8")
    assert clf.policy.precision == "int8"
    assert clf.engine_.resolve(None).precision == "int8"


def test_loaded_model_serves_without_dequantizing(fitted, tmp_path):
    """An int8 artifact must serve from its packed bytes alone: predict()
    never realizes the fp32 grove views (gc_/forest_ stay lazy)."""
    ds, clf = fitted
    path = clf.save(tmp_path / "m8.npz", precision="int8")
    clf8 = FogClassifier.load(path)
    clf8.predict(ds.x_test[:64])
    clf8.profile()
    assert repr(clf8).startswith("FogClassifier(")
    assert clf8.engine_._gcs is None            # never dequantized
    assert getattr(clf8, "_gc", None) is None
    # explicit access still works, lazily
    assert clf8.gc_.n_groves == clf.gc_.n_groves
    assert clf8.engine_._gcs is not None


def test_load_rejects_bare_pack_artifacts(fitted, tmp_path):
    from repro.forest import ForestPack
    ds, clf = fitted
    pack = ForestPack.from_groves(clf.gc_)
    path = pack.save(tmp_path / "bare.npz")
    with pytest.raises(ValueError, match="FogClassifier"):
        FogClassifier.load(path)


def test_load_rejects_truncated_artifacts(fitted, tmp_path):
    """FogClassifier.load rides the pack-level schema validation: a
    truncated save artifact fails with the missing-field error, not a
    KeyError while rebuilding the facade."""
    ds, clf = fitted
    path = clf.save(tmp_path / "m.npz")
    with np.load(path) as z:
        fields = dict(z)
    del fields["extra_json"]
    broken = tmp_path / "trunc.npz"
    with open(broken, "wb") as f:
        np.savez(f, **fields)
    with pytest.raises(ValueError, match="missing fields"):
        FogClassifier.load(broken)


def test_param_protocol_and_errors(ds_penbased):
    clf = FogClassifier(n_trees=8, grove_size=4)
    params = clf.get_params()
    assert params["n_trees"] == 8 and params["grove_size"] == 4
    clf.set_params(n_trees=16)
    assert clf.n_trees == 16
    with pytest.raises(ValueError):
        clf.set_params(bogus=1)
    with pytest.raises(RuntimeError):
        clf.predict(ds_penbased.x_test)            # not fitted
    with pytest.raises(ValueError):
        FogClassifier(n_trees=5, grove_size=2).fit(
            ds_penbased.x_train, ds_penbased.y_train)  # 5 % 2 != 0


# ---------------------------------------------------------------------------
# energy budgets (set_energy_budget / profile budget keys / persistence)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def budgeted(ds_penbased):
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    clf.fit(ds.x_train, ds.y_train)
    clf.set_energy_budget(2.0, ds.x_test[:512], ds.y_test[:512])
    return ds, clf


def test_set_energy_budget_pins_frontier_policy(budgeted):
    ds, clf = budgeted
    assert clf.energy_budget_nj_ == 2.0
    assert len(clf.frontier_) >= 2
    clf.frontier_.check_monotone()
    # the pinned default policy IS the selected frontier point's policy
    assert clf.policy == clf.frontier_.under_budget(2.0).policy
    assert clf.engine_.policy == clf.policy


def test_profile_reports_measured_vs_budget(budgeted):
    ds, clf = budgeted
    clf.reset_profile()
    clf.predict(ds.x_test)           # serves under the pinned policy
    prof = clf.profile()
    assert prof["energy_budget_nj"] == 2.0
    assert prof["within_budget"] is True
    assert prof["energy_nj_per_classification"] <= 2.0


def test_set_energy_budget_restarts_accounting(ds_penbased):
    """Batches evaluated BEFORE the budget existed must not pollute
    measured-vs-budget: pinning resets the profile, so within_budget
    describes only traffic served under the pinned policy."""
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    clf.fit(ds.x_train, ds.y_train)
    clf.predict(ds.x_test, policy=FogPolicy(threshold=1.1))   # expensive
    expensive = clf.profile()["energy_nj_per_classification"]
    clf.set_energy_budget(expensive * 0.8, ds.x_test[:256], ds.y_test[:256])
    assert clf.profile()["n_classified"] == 0                 # restarted
    clf.predict(ds.x_test)
    assert clf.profile()["within_budget"] is True


def test_unmeetable_budget_raises(ds_penbased):
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    clf.fit(ds.x_train, ds.y_train)
    with pytest.raises(ValueError, match="below the cheapest"):
        clf.set_energy_budget(1e-6, ds.x_test[:128], ds.y_test[:128])
    # a failed pin is atomic: no half-committed frontier/budget/policy
    assert getattr(clf, "frontier_", None) is None
    assert getattr(clf, "energy_budget_nj_", None) is None

    clf.set_energy_budget(2.0, ds.x_test[:128], ds.y_test[:128])
    before = (clf.frontier_, clf.energy_budget_nj_, clf.policy)
    with pytest.raises(ValueError, match="below the cheapest"):
        clf.set_energy_budget(1e-6, ds.x_test[:128], ds.y_test[:128])
    assert (clf.frontier_, clf.energy_budget_nj_, clf.policy) == before


def test_budget_round_trips_through_save_load(budgeted, tmp_path):
    ds, clf = budgeted
    path = tmp_path / "budgeted.npz"
    clf.save(path)
    clf2 = FogClassifier.load(path)
    assert clf2.energy_budget_nj_ == 2.0
    assert clf2.policy == clf.policy
    assert len(clf2.frontier_) == len(clf.frontier_)
    for a, b in zip(clf.frontier_.points, clf2.frontier_.points):
        assert a.policy == b.policy and a.energy_nj == b.energy_nj
    # the loaded model serves under the trained budget
    np.testing.assert_array_equal(clf2.predict(ds.x_test[:128]),
                                  clf.predict(ds.x_test[:128]))
    assert clf2.profile()["energy_budget_nj"] == 2.0


def test_governor_from_calibrated_facade(budgeted):
    ds, clf = budgeted
    gov = clf.governor()
    assert gov.budget_nj == 2.0
    assert gov.frontier is clf.frontier_
    # the governor starts on the best rung PREDICTED to fit the budget
    assert gov.current == clf.frontier_.under_budget(2.0).policy

    fresh = FogClassifier(n_trees=8, grove_size=2, max_depth=4)
    fresh.fit(ds.x_train[:512], ds.y_train[:512])
    with pytest.raises(RuntimeError, match="no calibrated frontier"):
        fresh.governor()


def test_set_energy_budget_respects_configured_knobs(ds_penbased):
    """The calibration grid sweeps ON TOP OF the estimator's default
    policy: knobs the grid does not vary (hop_budget here) must survive
    into the pinned policy, and a per-lane default must be refused."""
    import jax.numpy as jnp
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1,
                        policy=FogPolicy(hop_budget=3))
    clf.fit(ds.x_train, ds.y_train)
    clf.set_energy_budget(2.0, ds.x_test[:256], ds.y_test[:256])
    assert clf.policy.hop_budget == 3            # the user's knob survived

    lane = FogClassifier(n_trees=8, grove_size=2, max_depth=4)
    lane.fit(ds.x_train[:512], ds.y_train[:512])
    lane.policy = FogPolicy(threshold=jnp.asarray([0.1] * 4))
    with pytest.raises(ValueError, match="per-lane"):
        lane.set_energy_budget(2.0, ds.x_test[:4], ds.y_test[:4])


def test_explicit_precision_save_cannot_strand_frontier_rungs(
        budgeted, tmp_path):
    """save(precision='int8') with a frontier carrying higher-fidelity
    rungs must refuse: after load those rungs' tables could only be
    rebuilt from the lossier pack, silently invalidating their stored
    calibration."""
    ds, clf = budgeted
    precs = {p.policy.precision for p in clf.frontier_.points}
    if "fp32" not in precs:
        pytest.skip("frontier calibrated all-int8; nothing to strand")
    with pytest.raises(ValueError, match="cannot reconstruct"):
        clf.save(tmp_path / "stranded.npz", precision="int8")
    clf.save(tmp_path / "full.npz")          # automatic rule: fine


def test_load_rejects_corrupt_frontier(budgeted, tmp_path):
    """A tampered artifact whose frontier violates the Pareto invariant
    fails at load — under_budget would otherwise resolve budgets to a
    lower-accuracy point silently."""
    from repro.forest.pack import ForestPack
    ds, clf = budgeted
    path = clf.save(tmp_path / "ok.npz")
    pack, extra = ForestPack.load_with_meta(path)
    # sabotage: make accuracy DROP along the energy-ascending order
    pts = extra["frontier"]["points"]
    if len(pts) < 2:
        pytest.skip("frontier too small to corrupt meaningfully")
    pts[-1]["accuracy"] = pts[0]["accuracy"] - 0.5
    bad = tmp_path / "bad.npz"
    pack.save(bad, extra=extra)
    with pytest.raises(ValueError, match="frontier is corrupt"):
        FogClassifier.load(bad)
