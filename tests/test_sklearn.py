"""FogClassifier facade (repro/sklearn.py): fit/predict round trip, policy
overrides, and the profile() energy accounting."""
import numpy as np
import pytest

from repro.core import FogPolicy
from repro.sklearn import FogClassifier


@pytest.fixture(scope="module")
def fitted(ds_penbased):
    ds = ds_penbased
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    return ds, clf.fit(ds.x_train, ds.y_train)


def test_fit_predict_round_trip(fitted):
    """The acceptance contract: fit(X, y).predict(X) round-trips on the
    quickstart dataset and profile() reports mean hops + nJ/classification."""
    ds, clf = fitted
    labels = clf.predict(ds.x_test)
    assert labels.shape == (len(ds.y_test),)
    acc = float((labels == ds.y_test).mean())
    assert acc > 0.85, acc
    prof = clf.profile()
    assert prof["n_classified"] == len(ds.y_test)
    assert prof["mean_hops"] >= 1.0
    assert prof["energy_nj_per_classification"] > 0.0
    assert sum(prof["hops_histogram"].values()) == prof["n_classified"]


def test_predict_proba_and_score(fitted):
    ds, clf = fitted
    proba = clf.predict_proba(ds.x_test[:64])
    assert proba.shape == (64, ds.n_classes)
    np.testing.assert_allclose(proba.sum(axis=-1), 1.0, rtol=1e-5)
    assert clf.score(ds.x_test, ds.y_test) > 0.85


def test_predict_is_deterministic(fitted):
    ds, clf = fitted
    a = clf.predict(ds.x_test[:128])
    b = clf.predict(ds.x_test[:128])
    np.testing.assert_array_equal(a, b)


def test_fused_backend_facade_parity(fitted):
    """backend='fused' through the sklearn facade: identical labels and
    per-call policies still override (the engine pass-through contract)."""
    ds, clf = fitted
    fused = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1,
                          backend="fused")
    fused.fit(ds.x_train, ds.y_train)
    np.testing.assert_array_equal(fused.predict(ds.x_test[:200]),
                                  clf.predict(ds.x_test[:200]))
    # per-call policy override may itself re-select the backend
    a = fused.predict(ds.x_test[:64],
                      policy=FogPolicy(threshold=0.3, backend="reference"))
    b = clf.predict(ds.x_test[:64], policy=FogPolicy(threshold=0.3))
    np.testing.assert_array_equal(a, b)


def test_policy_override_trades_energy(fitted):
    """A cheaper per-call policy must lower hops (the paper's Fig-5 knob),
    without retraining or rebuilding anything."""
    ds, clf = fitted
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=0.05))
    cheap = clf.profile()["mean_hops"]
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=0.9))
    rich = clf.profile()["mean_hops"]
    assert cheap < rich


def test_hop_budget_policy_caps_energy(fitted):
    ds, clf = fitted
    clf.reset_profile()
    clf.predict(ds.x_test, policy=FogPolicy(threshold=1.1, hop_budget=2))
    prof = clf.profile()
    assert prof["mean_hops"] == 2.0               # budget binds every lane
    assert set(prof["hops_histogram"]) == {2}


def test_reset_profile(fitted):
    ds, clf = fitted
    clf.predict(ds.x_test[:32])
    assert clf.profile()["n_classified"] > 0
    clf.reset_profile()
    assert clf.profile()["n_classified"] == 0


def test_param_protocol_and_errors(ds_penbased):
    clf = FogClassifier(n_trees=8, grove_size=4)
    params = clf.get_params()
    assert params["n_trees"] == 8 and params["grove_size"] == 4
    clf.set_params(n_trees=16)
    assert clf.n_trees == 16
    with pytest.raises(ValueError):
        clf.set_params(bogus=1)
    with pytest.raises(RuntimeError):
        clf.predict(ds_penbased.x_test)            # not fitted
    with pytest.raises(ValueError):
        FogClassifier(n_trees=5, grove_size=2).fit(
            ds_penbased.x_train, ds_penbased.y_train)  # 5 % 2 != 0
