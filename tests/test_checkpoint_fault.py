"""Checkpoint atomicity/restore + failure detection + deterministic data."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.lm_data import DataConfig, batch_at_step
from repro.train import checkpoint as ckpt
from repro.train.fault import FleetMonitor, Heartbeat, deterministic_data_key


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "opt": {"mu": jnp.zeros((8, 4)), "step": jnp.int32(7)},
            "stack": [jnp.ones((3,)), jnp.zeros((2, 2))]}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(10, tree, tmp_path)
    restored, step = ckpt.restore(tree, tmp_path)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_restore_picks_newest_committed(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(5, t1, tmp_path)
    ckpt.save(9, t2, tmp_path)
    _, step = ckpt.restore(t1, tmp_path)
    assert step == 9


def test_uncommitted_checkpoint_ignored(tmp_path):
    """A writer that died before COMMIT must be invisible + cleaned up."""
    tree = _tree()
    ckpt.save(5, tree, tmp_path)
    # simulate a crash mid-write at step 6: directory without COMMIT
    bad = tmp_path / "step_00000006"
    bad.mkdir()
    (bad / "shard_0.npz").write_bytes(b"garbage")
    restored, step = ckpt.restore(tree, tmp_path)
    assert step == 5
    assert not bad.exists()          # gc'd


def test_gc_keeps_k(tmp_path):
    tree = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(s, tree, tmp_path, keep=2)
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_async_save(tmp_path):
    tree = _tree()
    t = ckpt.save(3, tree, tmp_path, async_write=True)
    t.join(timeout=30)
    _, step = ckpt.restore(tree, tmp_path)
    assert step == 3


def test_fleet_monitor_detects_death_and_stragglers(tmp_path):
    now = time.time()
    for host, (age, step) in {"h0": (0, 100), "h1": (0, 100),
                              "h2": (999, 100), "h3": (0, 20)}.items():
        hb = Heartbeat(tmp_path, host)
        hb.beat(step)
        if age:
            # backdate h2's heartbeat
            p = Path(tmp_path) / f"hb_{host}.json"
            d = json.loads(p.read_text())
            d["time"] = now - age
            p.write_text(json.dumps(d))
    mon = FleetMonitor(tmp_path, timeout=60)
    plan = mon.plan(now=now, model_extent=4, chips_per_host=4)
    assert plan.dead_hosts == ["h2"]
    assert "h3" in plan.stragglers            # step 20 < 0.5 * median 100
    assert plan.new_data_extent == 3          # 3 alive hosts * 4 chips / 4 model


def test_restart_plan_includes_latest_checkpoint(tmp_path):
    ckpt.save(42, _tree(), tmp_path)
    Heartbeat(tmp_path, "h0").beat(42)
    plan = FleetMonitor(tmp_path).plan(model_extent=1, chips_per_host=1)
    assert plan.restore_step == 42


def test_data_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=128, global_batch=8)
    b1 = batch_at_step(cfg, step=17, host=0, n_hosts=2)
    b2 = batch_at_step(cfg, step=17, host=0, n_hosts=2)
    b3 = batch_at_step(cfg, step=17, host=1, n_hosts=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])   # reproducible
    assert not np.array_equal(b1["tokens"], b3["tokens"])       # host-disjoint
    assert b1["tokens"].shape == (4, 128)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_key_step_indexed():
    assert deterministic_data_key(0, 5) != deterministic_data_key(0, 6)
    assert deterministic_data_key(0, 5) == deterministic_data_key(0, 5)
