"""EnergyModel regression wall (core/energy.py).

The refactor's contract: the fp32 :class:`EnergyModel` reproduces the
pre-EnergyModel ``fog_energy`` accounting *bit-for-bit* on the Table-1
topologies (the inline legacy formula below is a frozen copy of the
pre-refactor arithmetic, plus hard golden floats), and quantized packs are
strictly cheaper — as BOUNDS, never cross-precision bit-identity (see the
cross-compile ULP flakiness note: quantized comparisons assert ordering and
tolerances only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AffineEnergy, EnergyModel, EvalReport, FogEngine,
                        FogPolicy, fog_energy, split)
from repro.core.energy import (E_CMP8, E_INT8_ADD, E_SRAM_R32, E_SRAM_W32,
                               grove_energy_pj, hop_transfer_energy_pj)
from repro.forest.pack import PRECISION_BYTES

HOPS = np.array([1, 1, 2, 3, 5, 8, 8, 16])

# (grove_size, depth, n_classes, n_features, golden per_example_pj @ HOPS)
# — goldens computed from the pre-refactor fog_energy and frozen here
TABLE1_TOPOLOGIES = {
    "isolet": (2, 12, 26, 617, 13790.048101780703),
    "penbased": (2, 9, 10, 16, 2031.0327186969062),
    "mnist": (2, 12, 10, 784, 13747.210150421675),
    "letter": (2, 11, 26, 16, 5024.898240865146),
    "segmentation": (2, 8, 7, 19, 1750.115),
}


def _legacy_fog_energy_per_example(hops, grove_size, depth, n_classes,
                                   n_features, precision="fp32"):
    """Frozen copy of the pre-EnergyModel fog_energy arithmetic."""
    hops = np.asarray(hops, np.float64)
    per_grove = grove_energy_pj(grove_size, depth, n_classes, precision)
    transfer = hop_transfer_energy_pj(n_features, n_classes)
    per_ex = hops * per_grove + np.maximum(hops - 1, 0) * transfer
    return float(per_ex.mean()), float(per_ex.sum())


@pytest.mark.parametrize("name", sorted(TABLE1_TOPOLOGIES))
def test_fp32_model_reproduces_legacy_fog_energy_bit_for_bit(name):
    k, d, C, F, golden = TABLE1_TOPOLOGIES[name]
    model = EnergyModel(k, d, C, F)
    rep = model.report(HOPS)
    mean, total = _legacy_fog_energy_per_example(HOPS, k, d, C, F)
    assert rep.per_example_pj == mean          # bit-for-bit, not allclose
    assert rep.total_pj == total
    assert rep.per_example_pj == golden        # frozen pre-refactor value
    # and the wrapper is the model
    assert fog_energy(HOPS, k, d, C, F) == rep


@pytest.mark.parametrize("name", sorted(TABLE1_TOPOLOGIES))
@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_quantized_energy_strictly_below_fp32(name, quant):
    """Bounds, not bit-identity: same topology + hops, narrower thresholds
    must cost strictly less (fewer SRAM bytes per node), and int8 <= bf16."""
    k, d, C, F, _ = TABLE1_TOPOLOGIES[name]
    fp32 = EnergyModel(k, d, C, F, "fp32").report(HOPS).per_example_pj
    q = EnergyModel(k, d, C, F, quant).report(HOPS).per_example_pj
    assert q < fp32
    if quant == "int8":
        bf16 = EnergyModel(k, d, C, F, "bf16").report(HOPS).per_example_pj
        assert q < bf16


def test_precision_scales_only_the_tree_walk_term():
    """The quantized saving is exactly the per-node byte difference: the
    accumulate/MaxDiff and transfer terms are precision-independent."""
    m32 = EnergyModel(2, 9, 10, 16, "fp32")
    m8 = EnergyModel(2, 9, 10, 16, "int8")
    assert m32.transfer_pj == m8.transfer_pj
    # per-hop difference is entirely inside the k tree walks
    words = max(1, (10 + 3) // 4)
    agg_conf = (10 * E_INT8_ADD + words * (E_SRAM_R32 + E_SRAM_W32)
                + 10 * E_CMP8 + E_INT8_ADD)
    assert m32.per_hop_pj - agg_conf > m8.per_hop_pj - agg_conf > 0
    assert PRECISION_BYTES["int8"] < PRECISION_BYTES["fp32"]


def test_hops_within_inverts_lane_pj():
    m = EnergyModel(2, 8, 10, 16)
    for budget_pj in [100.0, 500.0, 2000.0, 10_000.0]:
        h = m.hops_within(budget_pj)
        assert h >= 1
        if h > 1:   # affordable: h hops fit, h+1 would overspend
            assert float(m.lane_pj(np.asarray([h]))[0]) <= budget_pj
        assert float(m.lane_pj(np.asarray([h + 1]))[0]) > budget_pj
    # a budget below one hop still buys the mandatory first hop
    assert m.hops_within(0.0) == 1


def test_affine_energy_same_contract():
    m = EnergyModel(2, 8, 10, 16)
    a = AffineEnergy(per_hop_pj=m.per_hop_pj, transfer_pj=m.transfer_pj)
    assert a.report(HOPS) == m.report(HOPS)
    assert a.hops_within(1234.5) == m.hops_within(1234.5)


def test_mean_pj_matches_report_mean():
    m = EnergyModel(2, 8, 10, 16)
    hops = np.array([2, 3, 4, 7])   # all >= 1: affinity is exact
    assert m.mean_pj(float(hops.mean())) == pytest.approx(
        m.report(hops).per_example_pj, rel=1e-12)


def test_energy_report_str_uses_nj():
    rep = EnergyModel(2, 8, 10, 16).report(HOPS)
    assert "nJ" in str(rep) and "pJ" not in str(rep)


# ---------------------------------------------------------------------------
# EvalReport: the engine's own telemetry replaces HopMeter + fog_energy
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine(trained):
    _, rf = trained
    return FogEngine(split(rf, 2))


def test_eval_returns_report_with_consistent_energy(engine, trained):
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:128])
    res = engine.eval(x, jax.random.key(0), policy=FogPolicy(threshold=0.3))
    assert isinstance(res, EvalReport)
    assert res.energy_pj.shape == res.hops.shape
    assert res.precision == "fp32"
    # the device-side estimate agrees with the model's pricing
    np.testing.assert_allclose(
        np.asarray(res.energy_pj),
        np.asarray(res.model.lane_pj(np.asarray(res.hops))), rtol=1e-6)
    # and the float64 report is bit-identical to the legacy call
    gc = engine.gcs[0]
    assert res.energy_report() == fog_energy(
        np.asarray(res.hops), gc.grove_size, gc.depth, gc.n_classes,
        ds.x_test.shape[1])


def test_eval_report_precision_follows_policy(engine, trained):
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:64])
    res8 = engine.eval(x, jax.random.key(0),
                       policy=FogPolicy(threshold=0.3, precision="int8"))
    assert res8.precision == "int8"
    # same hops would be strictly cheaper at int8 (bounds only)
    m32 = engine.energy_model("fp32")
    assert res8.model.report(np.asarray(res8.hops)).per_example_pj < \
        m32.report(np.asarray(res8.hops)).per_example_pj


def test_chunked_eval_carries_energy_too(engine, trained):
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:97])     # prime-ish: forces a padded tail
    pol = FogPolicy(threshold=0.3, chunk_b=32)
    res = engine.eval(x, jax.random.key(1), policy=pol)
    want = engine.eval(x, jax.random.key(1), policy=FogPolicy(threshold=0.3))
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_allclose(np.asarray(res.energy_pj),
                               np.asarray(want.energy_pj), rtol=1e-6)


def test_energy_model_cached_per_precision(engine, trained):
    ds, _ = trained
    engine.eval(jnp.asarray(ds.x_test[:32]), jax.random.key(0),
                policy=FogPolicy(threshold=0.3))
    assert engine.energy_model("fp32") is engine.energy_model("fp32")
    assert engine.energy_model("fp32") != engine.energy_model("int8")
