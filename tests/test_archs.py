"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs; decode==forward
consistency for every mixer type."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, param_count, smoke_config
from repro.models import transformer as T
from repro.optim import adamw

ALL = sorted(ARCHS)

# Fast tier covers one arch per mixer family (dense attention, SSM); the
# full 10-arch sweep (~8 min on CPU) runs under -m "" / make test-all.
FAST = {"tinyllama-1.1b", "mamba2-2.7b"}
SWEEP = [pytest.param(n, marks=() if n in FAST else (pytest.mark.slow,))
         for n in ALL]


def _inputs(cfg, key, B=2, S=64):
    if cfg.frontend:
        embeds = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                    cfg.vocab_size)
        return dict(embeds=embeds, labels=labels)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return dict(tokens=tokens, labels=jnp.roll(tokens, -1, 1))


@pytest.mark.parametrize("name", SWEEP)
def test_smoke_forward(name):
    cfg = smoke_config(name)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    inp = _inputs(cfg, jax.random.key(1))
    h, aux = T.forward(params, cfg, tokens=inp.get("tokens"),
                       embeds=inp.get("embeds"))
    B, S = (2, 64)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    logits = T.unembed(params, cfg, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", SWEEP)
def test_smoke_train_step(name):
    cfg = smoke_config(name)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    inp = _inputs(cfg, jax.random.key(1))
    init, update = adamw(lr=1e-3)
    state = init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, **inp))(params)
        params, state = update(grads, state, params)
        return params, state, loss

    l0 = None
    for _ in range(3):
        params, state, loss = step(params, state)
        assert not bool(jnp.isnan(loss)), name
        if l0 is None:
            l0 = float(loss)
    assert float(loss) < l0, (name, l0, float(loss))   # it learns


@pytest.mark.parametrize("name", SWEEP)
def test_decode_matches_forward(name):
    """Teacher-forced decode must reproduce the training-path logits —
    exercises KV caches, MLA absorbed decode, and SSD state recurrence."""
    cfg = smoke_config(name)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    B, S = 2, 32
    inp = _inputs(cfg, jax.random.key(1), B=B, S=S)

    h, _ = T.forward(params, cfg, tokens=inp.get("tokens"),
                     embeds=inp.get("embeds"), remat=False)
    want = np.asarray(T.unembed(params, cfg, h))      # [B, S, V]

    split = S // 2
    max_seq = S + 4
    if cfg.frontend:
        logits_p, cache = T.prefill(params, cfg,
                                    embeds=inp["embeds"][:, :split],
                                    max_seq=max_seq)
    else:
        logits_p, cache = T.prefill(params, cfg,
                                    tokens=inp["tokens"][:, :split],
                                    max_seq=max_seq)
    np.testing.assert_allclose(np.asarray(logits_p), want[:, split - 1],
                               rtol=2e-2, atol=2e-2)

    for t in range(split, S):
        if cfg.frontend:
            logits_d, cache = T.decode_step(
                params, cfg, None, cache, jnp.int32(t),
                embeds=inp["embeds"][:, t : t + 1])
        else:
            logits_d, cache = T.decode_step(
                params, cfg, inp["tokens"][:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_d), want[:, t],
                                   rtol=2e-2, atol=2e-2, err_msg=f"{name}@{t}")


@pytest.mark.parametrize("name", ALL)
def test_param_count_sane(name):
    """Analytic 6ND inputs: total within 20% of the advertised size."""
    advertised = {
        "tinyllama-1.1b": 1.1e9, "minicpm3-4b": 4e9, "granite-34b": 34e9,
        "gemma-2b": 2.5e9, "mamba2-2.7b": 2.7e9, "musicgen-large": 2.4e9,
        "grok-1-314b": 314e9, "deepseek-v3-671b": 671e9,
        "chameleon-34b": 34e9, "jamba-1.5-large-398b": 398e9,
    }
    total, active = param_count(ARCHS[name])
    assert abs(total - advertised[name]) / advertised[name] < 0.20, total
    assert active <= total
