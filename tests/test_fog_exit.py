"""FoG layer-grove early exit (models/fog_exit.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.fog_exit import decode_step_fog, grove_boundaries


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("tinyllama-1.1b").scaled(n_layers=4, fog_groups=4)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    _, cache = T.prefill(params, cfg, tokens=tokens, max_seq=S + 8)
    return cfg, params, tokens, cache, S


def test_grove_boundaries_cover_stack():
    cfg = smoke_config("tinyllama-1.1b").scaled(n_layers=4, fog_groups=4)
    sizes = grove_boundaries(cfg)
    _, _, n_rep = T.layer_plan(cfg)
    assert sum(sizes) == n_rep
    assert all(s > 0 for s in sizes)


def test_fog_exit_max_threshold_matches_full_decode(setup):
    """thresh > 1: no lane exits -> logits identical to plain decode_step."""
    cfg, params, tokens, cache, S = setup
    tok = tokens[:, -1]
    want, cache_w = T.decode_step(params, cfg, tok, cache, jnp.int32(S))
    got, cache_g, hops = decode_step_fog(params, cfg, tok, cache,
                                         jnp.int32(S), 2.0)
    assert (np.asarray(hops) == len(grove_boundaries(cfg))).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # caches updated identically when nothing exits
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        cache_w, cache_g)


def test_fog_exit_low_threshold_uses_one_grove(setup):
    cfg, params, tokens, cache, S = setup
    got, _, hops = decode_step_fog(params, cfg, tokens[:, -1], cache,
                                   jnp.int32(S), 0.0)
    assert (np.asarray(hops) == 1).all()
    assert not np.isnan(np.asarray(got)).any()


def test_fog_exit_hops_monotone_in_threshold(setup):
    cfg, params, tokens, cache, S = setup
    means = []
    for thr in [0.0, 0.01, 0.5, 2.0]:
        _, _, hops = decode_step_fog(params, cfg, tokens[:, -1], cache,
                                     jnp.int32(S), thr)
        means.append(float(np.asarray(hops).mean()))
    assert means == sorted(means), means


def test_fog_exit_gates_on_policy(setup):
    """decode_step_fog accepts a FogPolicy: per-lane thresholds must match
    the corresponding scalar-threshold runs, and hop budgets cap groves."""
    from repro.core import FogPolicy
    cfg, params, tokens, cache, S = setup
    tok = tokens[:, -1]
    tvec = jnp.asarray([0.0, 2.0], jnp.float32)     # lane 0 exits, lane 1 runs
    _, _, hops = decode_step_fog(params, cfg, tok, cache, jnp.int32(S),
                                 FogPolicy(threshold=tvec))
    _, _, hops_lo = decode_step_fog(params, cfg, tok, cache, jnp.int32(S), 0.0)
    _, _, hops_hi = decode_step_fog(params, cfg, tok, cache, jnp.int32(S), 2.0)
    assert int(hops[0]) == int(hops_lo[0])
    assert int(hops[1]) == int(hops_hi[1])
    # per-lane budget: the unconfident lane is capped at 2 groves
    _, _, hops_b = decode_step_fog(
        params, cfg, tok, cache, jnp.int32(S),
        FogPolicy(threshold=2.0, hop_budget=jnp.asarray([2, 4])))
    np.testing.assert_array_equal(np.asarray(hops_b), [2, 4])


def test_fog_exit_kv_propagation_keeps_decoding_sane(setup):
    """After an early-exit step, later full steps must still work (the
    skipped groves' caches were filled from the propagated state)."""
    cfg, params, tokens, cache, S = setup
    tok = tokens[:, -1]
    logits, cache, hops = decode_step_fog(params, cfg, tok, cache,
                                          jnp.int32(S), 0.0)
    assert (np.asarray(hops) == 1).all()
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache, _ = decode_step_fog(params, cfg, nxt, cache,
                                        jnp.int32(S + 1), 2.0)
    assert not np.isnan(np.asarray(logits2)).any()
