"""Distributed FoG ring (shard_map + ppermute) — needs >1 device, so the
actual check runs in a subprocess with forced host devices (the 1-device
ring conformance lives in test_engine_conformance.py)."""
import subprocess
import sys
import textwrap
from pathlib import Path

RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import split, fog_eval
    from repro.core.fog_ring import fog_ring_eval
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=6, seed=1))
    gc = split(rf, 2)   # 8 groves -> 8 shards
    assert gc.n_groves == 8
    mesh = jax.make_mesh((8,), ("grove",))
    x = jnp.asarray(ds.x_test[:512])

    proba, hops = fog_ring_eval(gc, x, jax.random.key(0), 0.3, 8, mesh)
    label = np.argmax(np.asarray(proba), axis=-1)
    acc = (label == ds.y_test[:512]).mean()
    assert acc > 0.8, acc

    # FoG_max on the ring == full forest probabilities for every lane
    proba_max, hops_max = fog_ring_eval(gc, x, jax.random.key(0), 1.1, 8, mesh)
    assert (np.asarray(hops_max) == 8).all()
    from repro.forest import forest_proba
    want = np.asarray(forest_proba(rf, x))
    np.testing.assert_allclose(np.asarray(proba_max), want, rtol=1e-5, atol=1e-6)

    # ring statistics match the batched evaluator distributionally: the
    # mean hop count is a property of (forest, data, threshold), not of
    # which grove an example starts at
    res = fog_eval(gc, x, jax.random.key(0), 0.3, 8)
    m_ring = float(np.asarray(hops).mean())
    m_batch = float(np.asarray(res.hops).mean())
    assert abs(m_ring - m_batch) / m_batch < 0.15, (m_ring, m_batch)

    # max_hops NOT a multiple of n_shards: lane state ends mid-ring and must
    # be rotated back to its home shard; per-lane hops/proba must equal the
    # reference engine run with identical start groves
    from repro.core.policy import NO_BUDGET
    from repro.core.engine import _eval_core, sample_starts
    from repro.forest.pack import ForestPack
    pack = ForestPack.from_groves(gc)
    from repro.core.fog_ring import ring_eval
    start = sample_starts(jax.random.key(0), 512, 8, 8)
    no_budget = jnp.full((512,), NO_BUDGET, jnp.int32)
    pr, hr = ring_eval(gc, x, start, 0.3, 5, mesh)
    want = _eval_core(pack, x, start, jnp.float32(0.3), no_budget, 5,
                      "reference", 256, False)
    np.testing.assert_array_equal(np.asarray(hr), np.asarray(want.hops))
    np.testing.assert_allclose(np.asarray(pr), np.asarray(want.proba),
                               rtol=1e-6, atol=1e-7)

    # per-lane thresholds + hop budgets rotate WITH the queue entries over
    # the multi-device ring: results must match the batched reference with
    # the same per-lane policy
    tvec = jnp.where(jnp.arange(512) < 256, 0.05, 0.6)
    bvec = jnp.where(jnp.arange(512) % 2 == 0, 2, NO_BUDGET).astype(jnp.int32)
    pr2, hr2 = ring_eval(gc, x, start, tvec, 8, mesh, hop_budget=bvec)
    want2 = _eval_core(pack, x, start, tvec, bvec, 8, "reference",
                       256, False)
    np.testing.assert_array_equal(np.asarray(hr2), np.asarray(want2.hops))
    np.testing.assert_allclose(np.asarray(pr2), np.asarray(want2.proba),
                               rtol=1e-6, atol=1e-7)
    assert (np.asarray(hr2)[::2] <= 2).all()
    print("RING-OK", acc, m_ring, m_batch)
""")


def test_fog_ring_subprocess():
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", RING_SCRIPT],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # forced-host-device scripts must not probe a real TPU: the
             # libtpu worker handshake hangs ~8 min before falling back
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RING-OK" in proc.stdout


KERNEL_RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import split
    from repro.core.fog_ring import fog_ring_eval
    from repro.data import make_dataset
    from repro.forest import TrainConfig, train_random_forest

    ds = make_dataset("penbased")
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=6, seed=1))
    gc = split(rf, 2)
    mesh = jax.make_mesh((8,), ("grove",))
    x = jnp.asarray(ds.x_test[:512])

    # Pallas tree-traversal PE inside the ring == jnp path, bit-for-bit hops
    pk, hk = fog_ring_eval(gc, x, jax.random.key(0), 0.3, 8, mesh,
                           use_kernels=True)
    pj, hj = fog_ring_eval(gc, x, jax.random.key(0), 0.3, 8, mesh,
                           use_kernels=False)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hj))
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pj),
                               rtol=1e-5, atol=1e-6)
    print("KERNEL-RING-OK")
""")


def test_fog_ring_kernel_backend_subprocess():
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", KERNEL_RING_SCRIPT],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # forced-host-device scripts must not probe a real TPU: the
             # libtpu worker handshake hangs ~8 min before falling back
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "KERNEL-RING-OK" in proc.stdout
