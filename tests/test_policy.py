"""FogPolicy: the runtime-knob contract (core/policy.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NO_BUDGET, FogPolicy, assemble, fog_eval, split
from repro.core.policy import BACKENDS


def test_defaults_and_replace():
    p = FogPolicy()
    assert p.threshold == 0.3 and p.max_hops is None
    assert p.hop_budget is None and p.backend is None
    q = p.replace(threshold=0.1, backend="pallas")
    assert q.threshold == 0.1 and q.backend == "pallas"
    assert p.threshold == 0.3                      # frozen: original intact
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.threshold = 0.5


def test_validation():
    with pytest.raises(ValueError):
        FogPolicy(backend="asic")
    with pytest.raises(ValueError):
        FogPolicy(max_hops=0)
    with pytest.raises(ValueError):
        FogPolicy(chunk_b=0)
    # the first hop is always spent: a budget below 1 is unsatisfiable
    with pytest.raises(ValueError):
        FogPolicy(hop_budget=0)
    with pytest.raises(ValueError):
        FogPolicy(hop_budget=jnp.asarray([2, 0]))
    FogPolicy(hop_budget=1)
    for b in BACKENDS:
        FogPolicy(backend=b)                       # all real backends OK


def test_lane_vectors_broadcast_and_check():
    p = FogPolicy(threshold=0.2, hop_budget=3)
    np.testing.assert_allclose(np.asarray(p.lane_thresholds(4)), [0.2] * 4)
    np.testing.assert_array_equal(np.asarray(p.lane_budgets(4)), [3] * 4)
    q = FogPolicy(threshold=jnp.asarray([0.1, 0.2]))
    np.testing.assert_allclose(np.asarray(q.lane_thresholds(2)), [0.1, 0.2])
    with pytest.raises(ValueError):
        q.lane_thresholds(3)                       # wrong batch size
    # no budget -> NO_BUDGET sentinel (never binds under any max_hops)
    np.testing.assert_array_equal(np.asarray(FogPolicy().lane_budgets(2)),
                                  [NO_BUDGET] * 2)


def test_per_lane_property():
    assert not FogPolicy().per_lane
    assert FogPolicy(threshold=jnp.asarray([0.1, 0.2])).per_lane
    assert FogPolicy(hop_budget=jnp.asarray([1, 2])).per_lane


def test_policy_is_a_pytree():
    """threshold/hop_budget are data (traceable); the rest is static."""
    p = FogPolicy(threshold=jnp.asarray([0.1, 0.2]), hop_budget=3,
                  max_hops=8, backend="pallas")
    leaves, treedef = jax.tree.flatten(p)
    assert len(leaves) == 2                        # threshold + hop_budget
    p2 = jax.tree.unflatten(treedef, leaves)
    assert p2.backend == "pallas" and p2.max_hops == 8

    @jax.jit
    def thresh_sum(pol):
        return pol.lane_thresholds(2).sum()

    np.testing.assert_allclose(float(thresh_sum(p)), 0.3, atol=1e-6)


def test_assemble_mixed_requests():
    """Scheduler contract: per-slot scalar policies -> one per-lane policy."""
    default = FogPolicy(threshold=0.3, backend="pallas")
    lanes = assemble([FogPolicy(threshold=0.1),
                      None,                         # empty/defaulted slot
                      FogPolicy(threshold=0.9, hop_budget=2)],
                     default=default)
    np.testing.assert_allclose(np.asarray(lanes.threshold), [0.1, 0.3, 0.9])
    np.testing.assert_array_equal(np.asarray(lanes.hop_budget),
                                  [NO_BUDGET, NO_BUDGET, 2])
    assert lanes.backend == "pallas"               # static knobs from default


def test_assemble_no_budgets_stays_none():
    lanes = assemble([FogPolicy(threshold=0.1), None])
    assert lanes.hop_budget is None


def test_fog_eval_shims_warn(trained):
    ds, rf = trained
    gc = split(rf, 2)
    x = jnp.asarray(ds.x_test[:16])
    with pytest.warns(DeprecationWarning, match="fog_eval is deprecated"):
        fog_eval(gc, x, jax.random.key(0), 0.3, 4)


def test_fog_ring_eval_shim_warns(trained):
    ds, rf = trained
    gc = split(rf, 2)
    from repro.core.fog_ring import fog_ring_eval
    mesh = jax.make_mesh((1,), ("grove",))
    x = jnp.asarray(ds.x_test[:16])
    with pytest.warns(DeprecationWarning, match="fog_ring_eval"):
        fog_ring_eval(gc, x, jax.random.key(0), 0.3, 4, mesh)
