"""Elastic re-mesh: a checkpoint written under one mesh restores onto a
mesh with a different data extent (the fault.py shrink path)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import checkpoint as ckpt

    tmp = sys.argv[1]
    devs = jax.devices()
    mesh8 = jax.make_mesh((8,), ("data",))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
    tree = {"w": xs, "step": jnp.int32(3)}
    ckpt.save(3, tree, tmp)

    # "two hosts died": restore onto a 4-device data mesh
    import numpy as _np
    mesh4 = jax.sharding.Mesh(_np.array(devs[:4]), ("data",))
    shardings = {"w": NamedSharding(mesh4, P("data", None)),
                 "step": NamedSharding(mesh4, P())}
    restored, step = ckpt.restore(tree, tmp, shardings=shardings)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
    assert restored["w"].sharding.mesh.shape["data"] == 4
    print("ELASTIC-OK")
""")


def test_elastic_remesh(tmp_path):
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path)],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # forced-host-device scripts must not probe a real TPU: the
             # libtpu worker handshake hangs ~8 min before falling back
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC-OK" in proc.stdout
