"""FogEngine backend conformance: every backend must reproduce the legacy
``fog_eval`` / ``fog_eval_lazy`` results — identical labels AND identical
per-example hop counts (the paper's energy quantity) — for fixed seeds.

The multi-device ring path is covered in test_fog_ring.py (subprocess with
forced host devices); here the ring backend runs on a 1-device mesh, which
exercises the shard_map + ppermute + strided-placement machinery with
multiple groves per shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (NO_BUDGET, FogEngine, FogPolicy, ForestPack,
                        fog_eval, fog_eval_lazy, fog_eval_multioutput, split)


THRESHES = [0.1, 0.3, 1.1]


def _assert_conforms(res, want, *, exact_proba=False):
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(res.label),
                                  np.asarray(want.label))
    kw = {} if exact_proba else {"rtol": 1e-6, "atol": 1e-7}
    np.testing.assert_allclose(np.asarray(res.proba), np.asarray(want.proba),
                               **kw)


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)          # 8 groves x 2 trees


@pytest.fixture(scope="module")
def x257(trained):
    # 257 is prime: never divisible by block_b/chunk_b -> exercises both the
    # kernel's dead-lane block padding and the engine's chunk padding
    ds, _ = trained
    return jnp.asarray(ds.x_test[:257])


@pytest.mark.parametrize("thresh", THRESHES)
@pytest.mark.parametrize("backend", ["reference", "pallas", "fused"])
def test_backend_matches_legacy(gc, x257, backend, thresh):
    key = jax.random.key(7)
    want = fog_eval(gc, x257, key, thresh, gc.n_groves)
    res = FogEngine(gc, backend=backend, block_b=64).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    _assert_conforms(res, want)
    lazy = FogEngine(gc, backend=backend, block_b=64, lazy=True).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    want_lazy = fog_eval_lazy(gc, x257, key, thresh, gc.n_groves)
    _assert_conforms(lazy, want_lazy)
    _assert_conforms(lazy, want)     # lazy == fixed-trip, any backend


@pytest.mark.parametrize("thresh", THRESHES)
def test_ring_backend_matches_legacy_on_one_device_mesh(gc, x257, thresh):
    # B must divide the shard count; 1-device mesh accepts the prime batch
    mesh = jax.make_mesh((1,), ("grove",))
    key = jax.random.key(7)
    want = fog_eval(gc, x257, key, thresh, gc.n_groves)
    res = FogEngine(gc, backend="ring", mesh=mesh).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    _assert_conforms(res, want)


@pytest.mark.parametrize("chunk_b", [64, 100])
def test_chunked_eval_matches_unchunked(gc, x257, chunk_b):
    """B % chunk_b != 0: the tail chunk is dead-padded; results must be
    bit-identical to the whole-batch evaluation."""
    key = jax.random.key(3)
    want = fog_eval(gc, x257, key, 0.3, gc.n_groves)
    for backend in ["reference", "pallas", "fused"]:
        res = FogEngine(gc, backend=backend, chunk_b=chunk_b,
                        block_b=32).eval(x257, key, 0.3,
                                         max_hops=gc.n_groves)
        _assert_conforms(res, want)


@pytest.mark.parametrize("backend", ["reference", "pallas", "fused"])
def test_multioutput_matches_legacy(trained, rf8_penbased,
                                    rf8_noisy_penbased, backend):
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:130])          # 130 % 64 != 0
    key = jax.random.key(11)
    want = fog_eval_multioutput(gcs, x, key, 0.3, 4)
    res = FogEngine(gcs, backend=backend, block_b=64).eval(
        x, key, 0.3, max_hops=4)
    assert res.proba.shape == (130, 2, ds.n_classes)
    assert res.label.shape == (130, 2)
    _assert_conforms(res, want)


def test_unaligned_kernel_block(gc, trained):
    """The old `assert B % block_b == 0` case: a batch smaller than and not
    divisible by the pallas block must work and agree with reference."""
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:37])
    key = jax.random.key(0)
    ref_res = FogEngine(gc).eval(x, key, 0.3)
    pal_res = FogEngine(gc, backend="pallas", block_b=256).eval(x, key, 0.3)
    _assert_conforms(pal_res, ref_res)
    fus_res = FogEngine(gc, backend="fused", block_b=256).eval(x, key, 0.3)
    _assert_conforms(fus_res, ref_res)


def test_default_max_hops_is_n_groves(gc, x257):
    key = jax.random.key(1)
    a = FogEngine(gc).eval(x257, key, 1.1)
    assert (np.asarray(a.hops) == gc.n_groves).all()


def test_engine_rejects_bad_config(gc):
    with pytest.raises(ValueError):
        FogEngine(gc, backend="asic")
    with pytest.raises(ValueError):
        FogEngine(gc, backend="ring")        # no mesh
    mesh = jax.make_mesh((1,), ("grove",))
    with pytest.raises(NotImplementedError):
        FogEngine((gc, gc), backend="ring", mesh=mesh)


def test_fused_rejects_mismatched_head_tables(gc, x257):
    """The fused backend stacks all heads' tables into one VMEM-resident
    launch; heads with different table shapes must be rejected clearly."""
    from repro.core import GroveCollection
    gc2 = GroveCollection(gc.feature, gc.threshold, gc.leaf[..., :-1])
    eng = FogEngine((gc, gc2), backend="fused")
    with pytest.raises(ValueError, match="identical table shapes"):
        eng.eval(x257, jax.random.key(0), policy=FogPolicy(threshold=0.3))


# ---------------------------------------------------------------------------
# FogPolicy: per-lane thresholds and hop budgets — the runtime-knob contract
# ---------------------------------------------------------------------------

def _engine_for(gc, backend):
    if backend == "ring":
        return FogEngine(gc, backend="ring",
                         mesh=jax.make_mesh((1,), ("grove",)))
    return FogEngine(gc, backend=backend, block_b=64)


@pytest.fixture(scope="module")
def x256(trained):
    ds, _ = trained
    return jnp.asarray(ds.x_test[:256])


@pytest.mark.parametrize("backend", ["reference", "pallas", "fused", "ring"])
def test_per_lane_threshold_matches_scalar_evals(gc, x256, backend):
    """The acceptance contract: a batch under [t_lo]*B/2 + [t_hi]*B/2 must
    reproduce, per lane, the labels AND hop counts of two scalar-threshold
    evaluations at t_lo and t_hi (same key -> same start groves)."""
    key = jax.random.key(7)
    B = x256.shape[0]
    t_lo, t_hi = 0.1, 0.6
    tvec = jnp.concatenate([jnp.full((B // 2,), t_lo),
                            jnp.full((B - B // 2,), t_hi)])
    eng = _engine_for(gc, backend)
    mixed = eng.eval(x256, key, policy=FogPolicy(threshold=tvec,
                                                 max_hops=gc.n_groves))
    lo = eng.eval(x256, key, policy=FogPolicy(threshold=t_lo,
                                              max_hops=gc.n_groves))
    hi = eng.eval(x256, key, policy=FogPolicy(threshold=t_hi,
                                              max_hops=gc.n_groves))
    h = B // 2
    np.testing.assert_array_equal(np.asarray(mixed.hops[:h]),
                                  np.asarray(lo.hops[:h]))
    np.testing.assert_array_equal(np.asarray(mixed.hops[h:]),
                                  np.asarray(hi.hops[h:]))
    np.testing.assert_array_equal(np.asarray(mixed.label[:h]),
                                  np.asarray(lo.label[:h]))
    np.testing.assert_array_equal(np.asarray(mixed.label[h:]),
                                  np.asarray(hi.label[h:]))


def test_per_lane_threshold_backend_conformance(gc, x256):
    """reference vs pallas vs ring under one per-lane policy: bit-identical
    labels + hops (the energy quantity is backend-invariant even per-QoS)."""
    key = jax.random.key(13)
    B = x256.shape[0]
    rng = np.random.default_rng(5)
    tvec = jnp.asarray(rng.choice([0.05, 0.2, 0.5, 0.9], size=B), jnp.float32)
    pol = FogPolicy(threshold=tvec, max_hops=gc.n_groves)
    want = _engine_for(gc, "reference").eval(x256, key, policy=pol)
    for backend in ["pallas", "fused", "ring"]:
        res = _engine_for(gc, backend).eval(x256, key, policy=pol)
        _assert_conforms(res, want)


@pytest.mark.parametrize("backend", ["reference", "pallas", "fused", "ring"])
def test_per_lane_hop_budget(gc, x256, backend):
    """A lane's hop count never exceeds its budget, unbudgeted lanes run to
    the max_hops cap at thresh>1, and budgets are backend-conformant."""
    key = jax.random.key(3)
    B = x256.shape[0]
    bvec = jnp.asarray(np.tile([1, 3, NO_BUDGET, 5], B // 4), jnp.int32)
    pol = FogPolicy(threshold=1.1, max_hops=gc.n_groves, hop_budget=bvec)
    res = _engine_for(gc, backend).eval(x256, key, policy=pol)
    hops = np.asarray(res.hops)
    cap = np.minimum(np.asarray(bvec, np.int64), gc.n_groves)
    np.testing.assert_array_equal(hops, cap)   # thresh>1: budget binds exactly
    want = _engine_for(gc, "reference").eval(x256, key, policy=pol)
    _assert_conforms(res, want)


def test_budget_with_confidence_gate_backend_conformance(gc, x256):
    """Budget AND confidence gates active at once: whichever fires first
    kills the lane; all backends must agree bit-for-bit."""
    key = jax.random.key(11)
    B = x256.shape[0]
    bvec = jnp.where(jnp.arange(B) % 2 == 0, 2, NO_BUDGET).astype(jnp.int32)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves, hop_budget=bvec)
    want = _engine_for(gc, "reference").eval(x256, key, policy=pol)
    assert (np.asarray(want.hops)[::2] <= 2).all()
    unbudgeted = _engine_for(gc, "reference").eval(
        x256, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    # odd lanes carry no budget -> identical to the unbudgeted run
    np.testing.assert_array_equal(np.asarray(want.hops)[1::2],
                                  np.asarray(unbudgeted.hops)[1::2])
    for backend in ["pallas", "fused", "ring"]:
        res = _engine_for(gc, backend).eval(x256, key, policy=pol)
        _assert_conforms(res, want)


@pytest.mark.parametrize("chunk_b", [64, 100])
def test_chunked_per_lane_policy_tail_padding(gc, x257, chunk_b):
    """B=257 is prime: the tail chunk is dead-padded and the per-lane
    threshold/budget vectors must be padded alongside x — results must be
    bit-identical to the unchunked whole-batch evaluation."""
    key = jax.random.key(9)
    B = x257.shape[0]
    tvec = jnp.where(jnp.arange(B) < B // 2, 0.1, 0.6)
    bvec = jnp.where(jnp.arange(B) % 3 == 0, 2, NO_BUDGET).astype(jnp.int32)
    pol = FogPolicy(threshold=tvec, max_hops=gc.n_groves, hop_budget=bvec)
    want = FogEngine(gc).eval(x257, key, policy=pol)
    for backend in ["reference", "pallas", "fused"]:
        res = FogEngine(gc, backend=backend, chunk_b=chunk_b,
                        block_b=32).eval(x257, key, policy=pol)
        _assert_conforms(res, want)


def test_multioutput_per_lane_policy(trained, rf8_penbased,
                                     rf8_noisy_penbased):
    """Per-lane thresholds compose with the min-over-outputs rule."""
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:128])
    key = jax.random.key(17)
    tvec = jnp.where(jnp.arange(128) < 64, 0.1, 0.5)
    pol = FogPolicy(threshold=tvec, max_hops=4)
    want = FogEngine(gcs).eval(x, key, policy=pol)
    res = FogEngine(gcs, backend="pallas", block_b=64).eval(x, key,
                                                            policy=pol)
    _assert_conforms(res, want)
    fused = FogEngine(gcs, backend="fused", block_b=64).eval(x, key,
                                                             policy=pol)
    _assert_conforms(fused, want)
    lo = FogEngine(gcs).eval(x, key, policy=FogPolicy(threshold=0.1,
                                                      max_hops=4))
    np.testing.assert_array_equal(np.asarray(want.hops[:64]),
                                  np.asarray(lo.hops[:64]))


# ---------------------------------------------------------------------------
# ForestPack precision axis: every backend evaluates packed fp32/bf16/int8
# tables; fp32/bf16 reproduce the legacy results bit-exactly, int8 stays
# within the quantization gates and is backend-conformant with itself.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas", "fused", "ring"])
def test_fp32_pack_bit_identical_to_legacy(gc, x256, backend):
    """fp32 packs store the training arrays verbatim: hops, labels and
    probabilities must equal the legacy path bit-for-bit on every backend."""
    key = jax.random.key(7)
    want = FogEngine(gc).eval(
        x256, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves, precision="fp32")
    res = _engine_for(gc, backend).eval(x256, key, policy=pol)
    _assert_conforms(res, want)


@pytest.mark.parametrize("backend", ["reference", "pallas", "fused", "ring"])
def test_bf16_cross_backend_bit_identical_and_near_fp32(gc, x256, backend):
    """Every backend dequantizes the SAME bf16 pack to the same fp32
    values: hops/labels/proba agree bit-for-bit across backends.  Against
    fp32, bf16 rounding (~2^-8 relative on leaves) shifts margins by up to
    ~2e-3, so lanes sitting that close to the confidence gate or an argmax
    tie may flip — >= 97% of hops and labels must still match."""
    key = jax.random.key(7)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves, precision="bf16")
    want16 = _engine_for(gc, "reference").eval(x256, key, policy=pol)
    res = _engine_for(gc, backend).eval(x256, key, policy=pol)
    _assert_conforms(res, want16)
    want32 = FogEngine(gc).eval(
        x256, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    assert (np.asarray(res.hops)
            == np.asarray(want32.hops)).mean() >= 0.97
    assert (np.asarray(res.label)
            == np.asarray(want32.label)).mean() >= 0.97


def test_int8_cross_backend_bit_identical(gc, x256):
    """All four backends dequantize the SAME int8 pack to the same fp32
    values, so hops, labels and probabilities agree bit-for-bit — the
    energy accounting stays backend-invariant at every precision."""
    key = jax.random.key(13)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves, precision="int8")
    want = _engine_for(gc, "reference").eval(x256, key, policy=pol)
    for backend in ["pallas", "fused", "ring"]:
        res = _engine_for(gc, backend).eval(x256, key, policy=pol)
        _assert_conforms(res, want)


def test_int8_label_agreement_gate(gc, x257, trained):
    """The quantization gate: with every grove voting (no confidence gate
    in play) int8 labels agree with fp32 on >= 99% of examples; under the
    default gated policy, lanes whose margin sits within the quantization
    error of the threshold may flip hops, but labels still agree >= 97%
    and accuracy stays within 1% of fp32 (the CI gate)."""
    ds, _ = trained
    y = ds.y_test[:x257.shape[0]]
    key = jax.random.key(7)
    full = FogPolicy(threshold=1.1, max_hops=gc.n_groves)
    want_f = FogEngine(gc).eval(x257, key, policy=full)
    res_f = FogEngine(gc, precision="int8").eval(x257, key, policy=full)
    agree = (np.asarray(res_f.label) == np.asarray(want_f.label)).mean()
    assert agree >= 0.99, agree

    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    want = FogEngine(gc).eval(x257, key, policy=pol)
    res = FogEngine(gc, precision="int8").eval(x257, key, policy=pol)
    agree = (np.asarray(res.label) == np.asarray(want.label)).mean()
    assert agree >= 0.97, agree
    acc32 = (np.asarray(want.label) == y).mean()
    acc8 = (np.asarray(res.label) == y).mean()
    assert acc8 >= acc32 - 0.01, (acc8, acc32)


def test_int8_margin_error_bound(gc, x257):
    """Leaf quantization error is grid-bounded: against a hybrid forest
    that walks the SAME paths (int8-dequantized thresholds) but keeps fp32
    leaves, the full-hop int8 probabilities differ by at most half an int8
    grid step, and MaxDiff margins by at most a full step."""
    from repro.core import GroveCollection
    pack = ForestPack.from_groves(gc, "int8")
    feat, thr_dq, leaf_dq = pack.dequantize()
    hybrid = GroveCollection(feat[0], thr_dq[0], gc.leaf)
    key = jax.random.key(3)
    pol = FogPolicy(threshold=1.1, max_hops=gc.n_groves)   # full hops
    want = FogEngine(hybrid).eval(x257, key, policy=pol)
    got = FogEngine(gc, precision="int8").eval(x257, key, policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops),
                                  np.asarray(want.hops))
    bound = 0.5 * float(np.asarray(pack.leaf_scale).max()) + 1e-6
    err = np.abs(np.asarray(got.proba) - np.asarray(want.proba)).max()
    assert err <= bound, (err, bound)
    from repro.core import maxdiff
    m_got = np.asarray(maxdiff(got.proba))
    m_want = np.asarray(maxdiff(want.proba))
    assert np.abs(m_got - m_want).max() <= 2 * bound


def test_pack_save_load_eval_round_trip(gc, x257, tmp_path):
    """A saved pack reloads to bit-identical tables: every backend's
    evaluation of the loaded pack equals the pre-save evaluation."""
    key = jax.random.key(11)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    for precision in ["fp32", "bf16", "int8"]:
        pack = ForestPack.from_groves(gc, precision)
        path = pack.save(tmp_path / f"pack_{precision}.npz")
        loaded = ForestPack.load(path)
        assert loaded.precision == precision
        want = FogEngine(pack).eval(x257, key, policy=pol)
        for backend in ["reference", "pallas", "fused"]:
            res = FogEngine(loaded, backend=backend,
                            block_b=64).eval(x257, key, policy=pol)
            _assert_conforms(res, want)


def test_auto_chunk_only_when_pack_exceeds_vmem(gc, x256):
    """The fused backend's chunk_b=None/'auto' must NOT chunk a pack that
    fits VMEM (the BENCH_engine fused-chunked regression), and an int8 pack
    of a field whose fp32 pack is over budget must run un-chunked where the
    fp32 evaluation raises the VMEM ValueError."""
    eng = FogEngine(gc, backend="fused")
    small = eng.tables.pack("fp32")
    assert eng._resolve_chunk("fused", small, x256.shape[0], 256, None,
                              x256.shape[1]) is None
    assert eng._resolve_chunk("fused", small, x256.shape[0], 256, "auto",
                              x256.shape[1]) is None
    # explicit chunking is always respected
    assert eng._resolve_chunk("fused", small, 256, 256, 64, 16) == 64

    from repro.core import GroveCollection
    rng = np.random.default_rng(0)
    G, t, depth, C, F, B = 8, 4, 10, 120, 8, 32    # fp32 field ~15.2 MiB
    gc_big = GroveCollection(
        jnp.asarray(rng.integers(0, F, size=(G, t, 2**depth - 1)),
                    jnp.int32),
        jnp.asarray(rng.normal(size=(G, t, 2**depth - 1)), jnp.float32),
        jnp.asarray(rng.dirichlet(np.ones(C), size=(G, t, 2**depth)),
                    jnp.float32))
    x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    key = jax.random.key(0)
    pol = FogPolicy(threshold=0.25, max_hops=G)
    big = FogEngine(gc_big, backend="fused", block_b=16)
    with pytest.raises(ValueError, match="usable"):
        big.eval(x, key, policy=pol)               # fp32 tables alone > VMEM
    got = big.eval(x, key, policy=pol.replace(precision="int8"))
    assert big._resolve_chunk(
        "fused", big.tables.pack("int8"), B, 16, None, F) is None
    want = FogEngine(gc_big, precision="int8").eval(x, key, policy=pol)
    _assert_conforms(got, want)


def test_auto_chunk_sizes_from_pack_footprint(gc):
    """When the packed tables fit but the batch block state would push the
    working set over budget, auto-chunking picks the largest lane count
    that fits beside the resident tables and the chunked evaluation matches
    the reference bit-for-bit."""
    from repro.core import GroveCollection
    from repro.kernels.fused_fog import fit_block_b
    rng = np.random.default_rng(1)
    G, t, depth, C, F = 4, 4, 9, 250, 2000         # tables ~7.9 MiB fp32
    gc_mid = GroveCollection(
        jnp.asarray(rng.integers(0, F, size=(G, t, 2**depth - 1)),
                    jnp.int32),
        jnp.asarray(rng.normal(size=(G, t, 2**depth - 1)), jnp.float32),
        jnp.asarray(rng.dirichlet(np.ones(C), size=(G, t, 2**depth)),
                    jnp.float32))
    B = 700
    x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    eng = FogEngine(gc_mid, backend="fused", block_b=1024)
    pack = eng.tables.pack("fp32")
    cb = eng._resolve_chunk("fused", pack, B, 1024, None, F)
    fit = fit_block_b(*pack.layout("fused"), n_features=F)
    assert cb is not None and cb <= fit < B
    key = jax.random.key(2)
    pol = FogPolicy(threshold=0.3, max_hops=G)
    want = FogEngine(gc_mid).eval(x, key, policy=pol)
    got = eng.eval(x, key, policy=pol)
    _assert_conforms(got, want)


def test_deprecated_positional_eval_warns_and_matches(gc, x256):
    key = jax.random.key(1)
    eng = FogEngine(gc)
    with pytest.warns(DeprecationWarning):
        legacy = eng.eval(x256, key, 0.3, max_hops=gc.n_groves)
    res = eng.eval(x256, key, policy=FogPolicy(threshold=0.3,
                                               max_hops=gc.n_groves))
    _assert_conforms(res, legacy, exact_proba=True)


def test_policy_and_positional_args_conflict(gc, x256):
    with pytest.raises(TypeError):
        FogEngine(gc).eval(x256, jax.random.key(0), 0.3,
                           policy=FogPolicy())
    with pytest.raises(TypeError):
        FogEngine(gc).eval(x256, jax.random.key(0), FogPolicy(),
                           policy=FogPolicy())


def test_positional_policy_is_canonical(gc, x256):
    """eval(x, key, FogPolicy(...)) — the decode_step_fog calling style —
    must work, warning-free, identical to the keyword form."""
    import warnings as _w
    key = jax.random.key(5)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        res = FogEngine(gc).eval(x256, key, pol)
    want = FogEngine(gc).eval(x256, key, policy=pol)
    _assert_conforms(res, want, exact_proba=True)


# ---------------------------------------------------------------------------
# adversarial fused shapes: prime batches x auto-chunk x int8 under a tiny
# monkeypatched VMEM budget, and engine-level live-lane compaction
# ---------------------------------------------------------------------------

def test_prime_batch_auto_chunk_int8_tiny_vmem(gc, x257, monkeypatch):
    """Prime batch x chunk_b="auto" x int8 with VMEM_BUDGET squeezed until
    the real forest's pack must chunk: the auto-chunker must pick a
    LANE_ALIGN-aligned chunk whose modeled footprint stays under the tiny
    budget, and the chunked+padded evaluation must stay bit-identical to
    the unconstrained reference."""
    import repro.kernels.fused_fog as ff
    import repro.kernels.tree_traverse as tt
    from repro.kernels.fused_fog import (LANE_ALIGN, fit_block_b,
                                         vmem_working_set)

    key = jax.random.key(11)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves, precision="int8",
                    chunk_b="auto")
    want = FogEngine(gc, precision="int8").eval(x257, key,
                                                policy=pol.replace(
                                                    chunk_b=None))

    eng = FogEngine(gc, backend="fused", block_b=256)
    pack = eng.tables.pack("int8")
    tables = pack.layout("fused")
    # a budget that admits the int8 tables plus ~40 lanes, far below B=257
    lane = (vmem_working_set(*tables, block_b=1, n_features=x257.shape[1])
            - vmem_working_set(*tables, block_b=0,
                               n_features=x257.shape[1]))
    tiny_budget = vmem_working_set(*tables, block_b=0,
                                   n_features=x257.shape[1]) + 40 * lane
    # fused_fog imports VMEM_BUDGET by value: patch BOTH module globals
    monkeypatch.setattr(ff, "VMEM_BUDGET", tiny_budget)
    monkeypatch.setattr(tt, "VMEM_BUDGET", tiny_budget)

    fit = fit_block_b(*tables, n_features=x257.shape[1])
    assert 0 < fit < x257.shape[0]
    assert fit % LANE_ALIGN == 0, "auto-chunk fit must be lane-aligned"
    assert vmem_working_set(*tables, block_b=fit,
                            n_features=x257.shape[1]) < tiny_budget
    cb = eng._resolve_chunk("fused", pack, x257.shape[0], 256, "auto",
                            x257.shape[1])
    assert cb == fit

    got = eng.eval(x257, key, policy=pol)
    _assert_conforms(got, want)


@pytest.mark.parametrize("B", [97, 257])
def test_engine_compaction_bit_identical(gc, trained, B):
    """compact on vs off through the full engine path (chunking, padding,
    autotuned block_b) — bit-identical hops, labels and probabilities."""
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:B])
    key = jax.random.key(13)
    pol = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    off = FogEngine(gc, backend="fused", compact=False).eval(x, key,
                                                             policy=pol)
    on = FogEngine(gc, backend="fused", compact=True).eval(x, key,
                                                           policy=pol)
    _assert_conforms(on, off, exact_proba=True)
    # and via the policy knob, overriding the engine default
    pol_on = pol.replace(compact=True)
    via_pol = FogEngine(gc, backend="fused", compact=False).eval(
        x, key, policy=pol_on)
    _assert_conforms(via_pol, off, exact_proba=True)
