"""FogEngine backend conformance: every backend must reproduce the legacy
``fog_eval`` / ``fog_eval_lazy`` results — identical labels AND identical
per-example hop counts (the paper's energy quantity) — for fixed seeds.

The multi-device ring path is covered in test_fog_ring.py (subprocess with
forced host devices); here the ring backend runs on a 1-device mesh, which
exercises the shard_map + ppermute + strided-placement machinery with
multiple groves per shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FogEngine, fog_eval, fog_eval_lazy,
                        fog_eval_multioutput, split)


THRESHES = [0.1, 0.3, 1.1]


def _assert_conforms(res, want, *, exact_proba=False):
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(res.label),
                                  np.asarray(want.label))
    kw = {} if exact_proba else {"rtol": 1e-6, "atol": 1e-7}
    np.testing.assert_allclose(np.asarray(res.proba), np.asarray(want.proba),
                               **kw)


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)          # 8 groves x 2 trees


@pytest.fixture(scope="module")
def x257(trained):
    # 257 is prime: never divisible by block_b/chunk_b -> exercises both the
    # kernel's dead-lane block padding and the engine's chunk padding
    ds, _ = trained
    return jnp.asarray(ds.x_test[:257])


@pytest.mark.parametrize("thresh", THRESHES)
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_backend_matches_legacy(gc, x257, backend, thresh):
    key = jax.random.key(7)
    want = fog_eval(gc, x257, key, thresh, gc.n_groves)
    res = FogEngine(gc, backend=backend, block_b=64).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    _assert_conforms(res, want)
    lazy = FogEngine(gc, backend=backend, block_b=64, lazy=True).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    want_lazy = fog_eval_lazy(gc, x257, key, thresh, gc.n_groves)
    _assert_conforms(lazy, want_lazy)
    _assert_conforms(lazy, want)     # lazy == fixed-trip, any backend


@pytest.mark.parametrize("thresh", THRESHES)
def test_ring_backend_matches_legacy_on_one_device_mesh(gc, x257, thresh):
    # B must divide the shard count; 1-device mesh accepts the prime batch
    mesh = jax.make_mesh((1,), ("grove",))
    key = jax.random.key(7)
    want = fog_eval(gc, x257, key, thresh, gc.n_groves)
    res = FogEngine(gc, backend="ring", mesh=mesh).eval(
        x257, key, thresh, max_hops=gc.n_groves)
    _assert_conforms(res, want)


@pytest.mark.parametrize("chunk_b", [64, 100])
def test_chunked_eval_matches_unchunked(gc, x257, chunk_b):
    """B % chunk_b != 0: the tail chunk is dead-padded; results must be
    bit-identical to the whole-batch evaluation."""
    key = jax.random.key(3)
    want = fog_eval(gc, x257, key, 0.3, gc.n_groves)
    for backend in ["reference", "pallas"]:
        res = FogEngine(gc, backend=backend, chunk_b=chunk_b,
                        block_b=32).eval(x257, key, 0.3,
                                         max_hops=gc.n_groves)
        _assert_conforms(res, want)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_multioutput_matches_legacy(trained, rf8_penbased,
                                    rf8_noisy_penbased, backend):
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:130])          # 130 % 64 != 0
    key = jax.random.key(11)
    want = fog_eval_multioutput(gcs, x, key, 0.3, 4)
    res = FogEngine(gcs, backend=backend, block_b=64).eval(
        x, key, 0.3, max_hops=4)
    assert res.proba.shape == (130, 2, ds.n_classes)
    assert res.label.shape == (130, 2)
    _assert_conforms(res, want)


def test_unaligned_kernel_block(gc, trained):
    """The old `assert B % block_b == 0` case: a batch smaller than and not
    divisible by the pallas block must work and agree with reference."""
    ds, _ = trained
    x = jnp.asarray(ds.x_test[:37])
    key = jax.random.key(0)
    ref_res = FogEngine(gc).eval(x, key, 0.3)
    pal_res = FogEngine(gc, backend="pallas", block_b=256).eval(x, key, 0.3)
    _assert_conforms(pal_res, ref_res)


def test_default_max_hops_is_n_groves(gc, x257):
    key = jax.random.key(1)
    a = FogEngine(gc).eval(x257, key, 1.1)
    assert (np.asarray(a.hops) == gc.n_groves).all()


def test_engine_rejects_bad_config(gc):
    with pytest.raises(ValueError):
        FogEngine(gc, backend="asic")
    with pytest.raises(ValueError):
        FogEngine(gc, backend="ring")        # no mesh
    mesh = jax.make_mesh((1,), ("grove",))
    with pytest.raises(NotImplementedError):
        FogEngine((gc, gc), backend="ring", mesh=mesh)
